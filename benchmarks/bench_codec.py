"""Codec throughput (software paths).  The paper's §VII-B area/power/
throughput numbers are 65nm-ASIC facts with no TPU analogue; what matters
for the TPU adaptation is that the lane-vectorized codec keeps up with HBM
when replicated (DESIGN.md §2) — here we measure the CPU software paths
(jnp ref codec, golden) for regression tracking, and the per-value step
counts that map to TPU cycles.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ac_golden, distributions, format as fmt, tables
from repro.kernels import ref


def main(emit) -> None:
    n = 1 << 21
    v = distributions.gaussian_weights(n)
    table = tables.table_for(v[:1 << 18])
    ta = ref.TableArrays.from_table(table)
    streams, _ = fmt.split_streams(v.astype(np.int64), 512)
    sj = jnp.asarray(streams)

    sp, op, sb, ob, st = ref.encode(sj, ta, 512)          # compile
    t0 = time.perf_counter()
    sp, op, sb, ob, st = ref.encode(sj, ta, 512)
    sp.block_until_ready()
    enc_dt = time.perf_counter() - t0

    out = ref.decode(sp.astype(jnp.uint32), op.astype(jnp.uint32), st, ta, 512)
    out.block_until_ready()
    t0 = time.perf_counter()
    out = ref.decode(sp.astype(jnp.uint32), op.astype(jnp.uint32), st, ta, 512)
    out.block_until_ready()
    dec_dt = time.perf_counter() - t0

    emit("codec/ref_encode", enc_dt * 1e6,
         f"{n / enc_dt / 1e6:.1f} Mvals/s ({streams.shape[0]} streams)")
    emit("codec/ref_decode", dec_dt * 1e6,
         f"{n / dec_dt / 1e6:.1f} Mvals/s")

    # golden (pure python) on a small slice, for scale
    t0 = time.perf_counter()
    ac_golden.encode_stream(v[:8192].astype(np.int64), table)
    g_dt = time.perf_counter() - t0
    emit("codec/golden_encode", g_dt * 1e6, f"{8192 / g_dt / 1e3:.1f} Kvals/s")

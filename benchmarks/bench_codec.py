"""Codec throughput (software paths).  The paper's §VII-B area/power/
throughput numbers are 65nm-ASIC facts with no TPU analogue; what matters
for the TPU adaptation is that the lane-vectorized codec keeps up with HBM
when replicated (DESIGN.md §2) — here we measure the CPU software paths
(jnp ref codec, Pallas-interpret kernels, golden) for regression tracking,
plus the fused decompress+matmul against its decode-then-matmul oracle.
The M-sweep of ``compressed_matmul`` documents the decode-once property:
decode cost must stay flat as M grows (DESIGN.md §2.3).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import ac_golden, distributions, format as fmt, tables
from repro.kernels import ref
from repro.kernels import decompress_matmul as dm
from repro.kernels.apack_decode import decode_pallas
from repro.kernels.apack_encode import encode_pallas


def _timeit(fn, repeats: int = 3):
    """Run once for compile (blocking), then ``repeats`` timed runs;
    returns the minimum in seconds (min is the noise-robust statistic for
    a committed perf trajectory)."""
    warm = fn()
    if hasattr(warm, "block_until_ready"):
        warm.block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def main(emit) -> None:
    n = 1 << 21
    v = distributions.gaussian_weights(n)
    table = tables.table_for(v[:1 << 18])
    ta = ref.TableArrays.from_table(table)
    streams, _ = fmt.split_streams(v.astype(np.int64), 512)
    sj = jnp.asarray(streams)

    enc_dt = _timeit(lambda: ref.encode(sj, ta, 512)[0])
    emit("codec/ref_encode", enc_dt * 1e6,
         f"{n / enc_dt / 1e6:.1f} Mvals/s ({streams.shape[0]} streams)")

    sp, op, sb, ob, st = ref.encode(sj, ta, 512)
    sp32, op32 = sp.astype(jnp.uint32), op.astype(jnp.uint32)
    dec_dt = _timeit(lambda: ref.decode(sp32, op32, st, ta, 512))
    emit("codec/ref_decode", dec_dt * 1e6, f"{n / dec_dt / 1e6:.1f} Mvals/s")

    # Pallas kernels in interpret mode (the CPU-validation path; on TPU the
    # same kernels compile).  Smaller block: interpret is ~100x slower.
    np_small = 1 << 15
    streams_p = streams[: np_small // 512]
    spj = jnp.asarray(streams_p)
    penc_dt = _timeit(lambda: encode_pallas(
        jnp.tile(spj, (128 // spj.shape[0] + 1, 1))[:128], ta.v_min, ta.ol,
        ta.cum, n_steps=512, bits=8, interpret=True)[0])
    emit("codec/pallas_interpret_encode", penc_dt * 1e6,
         f"{128 * 512 / penc_dt / 1e3:.1f} Kvals/s (128 streams)")

    sp_p, op_p, sb_p, ob_p, ovf_p = encode_pallas(
        jnp.tile(spj, (128 // spj.shape[0] + 1, 1))[:128], ta.v_min, ta.ol,
        ta.cum, n_steps=512, bits=8, interpret=True)
    stored_p = jnp.zeros((128,), jnp.int32)
    pdec_dt = _timeit(lambda: decode_pallas(
        sp_p, op_p, stored_p, ta.v_min, ta.ol, ta.cum, n_steps=512, bits=8,
        interpret=True))
    emit("codec/pallas_interpret_decode", pdec_dt * 1e6,
         f"{128 * 512 / pdec_dt / 1e3:.1f} Kvals/s")

    # fused decompress+matmul vs decode-then-dense oracle, with an M sweep:
    # decode-once means time must grow far slower than M.
    rng = np.random.default_rng(0)
    k_dim, n_dim = 512, 256
    w = rng.normal(0, 0.05, (k_dim, n_dim)).astype(np.float32)
    cw = dm.compress_linear(w, tile_k=256)
    xs = {m: jnp.asarray(rng.normal(0, 1, (m, k_dim)).astype(np.float32))
          for m in (64, 256)}
    fused = {}
    for m, x in xs.items():
        fused[m] = _timeit(lambda x=x: dm.compressed_matmul(x, cw, block_m=64))
        scaling = ("" if m == 64 else
                   f"; {fused[m] / fused[64]:.2f}x time for {m // 64}x M "
                   "(flat => decode-once)")
        emit(f"codec/fused_matmul_m{m}", fused[m] * 1e6,
             f"{m}x{k_dim}x{n_dim}{scaling}")
    ref_dt = _timeit(lambda: dm.reference_matmul(xs[256], cw))
    emit("codec/reference_matmul_m256", ref_dt * 1e6,
         f"fused speedup vs decode-then-dense oracle: "
         f"{ref_dt / fused[256]:.2f}x")

    # golden (pure python) on a small slice, for scale
    t0 = time.perf_counter()
    ac_golden.encode_stream(v[:8192].astype(np.int64), table)
    g_dt = time.perf_counter() - t0
    emit("codec/golden_encode", g_dt * 1e6, f"{8192 / g_dt / 1e3:.1f} Kvals/s")

"""Shared benchmark utilities: model-zoo tensor sources + timing."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import quant
from repro.models import model as M


_KV_STATS: dict[str, dict] = {}


def measured_kv_stats(arch: str = "qwen3-1.7b") -> dict:
    """One paged-KV serve measurement (``bench_traffic.kv_cache_traffic``)
    shared by the traffic, energy, and roofline sections — the measured
    ``kv_ratio`` feeds the Fig. 6/7 analogues, so the decode KV stream is
    priced from real engine traffic, not a synthetic distribution."""
    if arch not in _KV_STATS:
        from . import bench_traffic
        _KV_STATS[arch] = bench_traffic.kv_cache_traffic(arch)
    return _KV_STATS[arch]


def timed(fn, *args, repeat: int = 3, **kw):
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def zoo_weight_samples(max_vals: int = 1 << 20, seed: int = 0
                       ) -> dict[str, np.ndarray]:
    """Per-arch int8 (uint view) weight samples from full-width single-block
    inits.  Random inits are gaussian (trained-weight distributions are more
    skewed — see bench_traffic's trained-model rows for that case)."""
    out = {}
    for arch in configs.all_arch_ids():
        cfg = configs.get_smoke_config(arch)   # full-width not needed: init
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        leaves = [np.asarray(x) for x in jax.tree.leaves(params)
                  if hasattr(x, "ndim") and x.ndim >= 2 and x.size > 4096]
        flat = np.concatenate([l.reshape(-1)[:max_vals // max(len(leaves), 1)]
                               for l in leaves])[:max_vals]
        q, _ = quant.quantize_symmetric(jnp.asarray(flat, jnp.float32))
        out[arch] = quant.to_unsigned(np.asarray(q))
    return out


def zoo_activation_samples(max_vals: int = 1 << 19, seed: int = 0
                           ) -> dict[str, np.ndarray]:
    """uint8 activation samples: residual-stream + post-nonlinearity values
    from a forward pass of each smoke model on synthetic tokens."""
    out = {}
    rng = np.random.default_rng(seed)
    for arch in configs.all_arch_ids():
        cfg = configs.get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        b, s = 4, 128
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
        if cfg.frontend == "audio":
            batch = {"frame_embeds": jnp.asarray(
                rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)}
        elif cfg.frontend == "vision":
            batch["patch_embeds"] = jnp.asarray(
                rng.normal(0, 1, (b, 16, cfg.d_model)), jnp.float32)
        # capture the residual stream after each block (the inter-layer
        # tensors the paper compresses off-chip)
        h = M.embed_inputs(cfg, params, batch)
        acts = [np.asarray(h, np.float32)]
        for i, kind in enumerate(cfg.cycle):
            p0 = jax.tree.map(lambda x: x[0], params["blocks"][i])
            h, _, _ = M.block_full(cfg, kind, p0, h)
            acts.append(np.asarray(h, np.float32))
        flat = np.concatenate([a.reshape(-1) for a in acts])[:max_vals]
        q, _ = quant.quantize_affine(jnp.asarray(flat), bits=8)
        out[arch] = np.asarray(q)
    return out

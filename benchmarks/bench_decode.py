"""Decode hot-path benchmark: serving steps/sec and per-step host<->device
transfer traffic of the paged APack KV engine — device-resident fused path
(on-device append + fused gather-decode attention) vs the legacy
materialize path (dense cache rebuilt from the pool every step).

One engine per mode serves identical request waves; the first wave warms
the jit caches, the next ``REPEAT`` waves are timed and the *minimum*
per-step time is reported (min-of-3).  Transfer bytes come from the
engine's own ``kv.transfers`` accounting (every KV-path byte crossing the
boundary goes through ``PagedKVCache._fetch``/``_put``), and
``steady_d2h_calls`` is the per-step minimum of ``device_get`` calls — the
fused path must report 0 (its only d2h is the amortized page-seal pull,
absent on non-boundary steps), which is the CI transfer guard.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

REPEAT = 3


def _build_engine(arch: str, fused: bool, *, max_batch: int, max_len: int):
    import jax
    from repro import configs
    from repro.models import model as M
    from repro.serve import ServeEngine

    base = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
    params = M.init_params(base, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_batch=max_batch,
                            max_len=max_len, kv_page_size=4,
                            kv_calib_pages=2, kv_fused=fused)


def _serve_wave(eng, cfg, seed: int, *, requests: int, prompt_len: int,
                max_new: int) -> dict:
    from repro.serve import Request

    rng = np.random.default_rng(seed)
    reqs = [Request(rid=seed * 1000 + i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len)
                    .astype(np.int32), max_new_tokens=max_new)
            for i in range(requests)]
    for r in reqs:
        eng.submit(r)
    # admissions (prefill) happen in the first, untimed step — the row
    # measures the decode hot path, not prompt processing
    eng.step()
    steps0 = eng.stats["steps"]
    tr0 = dict(eng.kv.transfers)
    per_step_d2h = []
    t0 = time.perf_counter()
    for _ in range(500):                     # bounded: a stalled engine
        before = eng.kv.transfers["d2h_calls"]   # must fail, not hang CI
        n = eng.step()
        if n == 0 and not eng.queue:
            break
        per_step_d2h.append(eng.kv.transfers["d2h_calls"] - before)
    else:
        raise RuntimeError("engine failed to drain within 500 steps")
    wall = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    steps = max(eng.stats["steps"] - steps0, 1)
    moved = sum(eng.kv.transfers[k] - tr0[k]
                for k in ("h2d_bytes", "d2h_bytes"))
    return {"s_per_step": wall / steps,
            "bytes_per_step": moved / steps,
            "steady_d2h_calls": min(per_step_d2h) if per_step_d2h else 0,
            "steps": steps}


def decode_throughput(arch: str = "qwen3-1.7b", fused: bool = True, *,
                      requests: int = 2, prompt_len: int = 8,
                      max_new: int = 12, max_batch: int = 2,
                      max_len: int = 32) -> dict:
    """Min-of-``REPEAT`` per-step decode time for one engine mode."""
    cfg, eng = _build_engine(arch, fused, max_batch=max_batch,
                             max_len=max_len)
    kw = dict(requests=requests, prompt_len=prompt_len, max_new=max_new)
    _serve_wave(eng, cfg, 0, **kw)              # warmup: jit compiles
    waves = [_serve_wave(eng, cfg, 1 + i, **kw) for i in range(REPEAT)]
    best = min(waves, key=lambda w: w["s_per_step"])
    return {
        "mode": "fused" if fused else "materialize",
        "us_per_step": best["s_per_step"] * 1e6,
        "steps_per_s": 1.0 / best["s_per_step"],
        "bytes_per_step": best["bytes_per_step"],
        "steady_d2h_calls": min(w["steady_d2h_calls"] for w in waves),
        "kv_ratio": eng.kv_stats()["kv_ratio"],
    }


def drift_scenario(arch: str = "qwen3-1.7b", *, requests: int = 4,
                   prompt_len: int = 9, max_new: int = 24) -> dict:
    """Two-phase drifting workload: diverse prompts, then a repetitive hot
    prompt (serving traffic narrowing onto one workload).  Runs a
    refresh-enabled engine (adaptive table refresh + budgeted page
    re-pack) and a frozen-table control over identical requests and
    reports per-phase *windowed* KV read ratios — read + shipped-table
    bytes over raw bytes moved inside the phase, so the pre-refresh window
    is not averaged away by cumulative accounting — plus the re-pack
    overhead per decode step and the steady-state d2h-call floor with
    refresh active (must stay 0: sketches are fed at page-seal time and
    re-pack reads the host pool mirror)."""
    import jax
    from repro import configs
    from repro.models import model as M
    from repro.serve import Request, ServeEngine

    base = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
    params = M.init_params(base, jax.random.PRNGKey(0))

    def run(refresh: bool):
        rng = np.random.default_rng(7)
        eng = ServeEngine(cfg, params, max_batch=requests,
                          max_len=prompt_len + max_new + 8, kv_page_size=4,
                          kv_calib_pages=1, kv_refresh=refresh,
                          kv_refresh_every_pages=24, kv_refresh_min_pages=8,
                          kv_repack_budget=32)
        phases = ([rng.integers(0, cfg.vocab_size, prompt_len)
                   .astype(np.int32) for _ in range(requests)],
                  [np.full(prompt_len, 7, np.int32)
                   for _ in range(requests)])
        ratios, tokens, d2h_steps = [], [], []
        for p, prompts in enumerate(phases):
            t0 = dict(eng.kv.traffic)
            reqs = [Request(rid=100 * p + i, prompt=pr,
                            max_new_tokens=max_new)
                    for i, pr in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            for _ in range(500):
                before = eng.kv.transfers["d2h_calls"]
                n = eng.step()
                if n == 0 and not eng.queue:
                    break
                if p == 1:
                    d2h_steps.append(eng.kv.transfers["d2h_calls"] - before)
            else:
                raise RuntimeError("drift engine failed to drain")
            d = lambda k: eng.kv.traffic[k] - t0[k]
            ratios.append((d("kv_read_bytes") + d("kv_table_bytes"))
                          / max(d("kv_raw_bytes"), 1))
            tokens.extend(r.tokens for r in reqs)
        return eng, ratios, tokens, min(d2h_steps) if d2h_steps else 0

    eng_f, (fa, fb), toks_f, _ = run(False)
    eng_r, (ra, rb), toks_r, d2h = run(True)
    if toks_f != toks_r:
        # refresh must be invisible to sampling (losslessness) — a token
        # divergence is a correctness bug, not a perf regression
        raise RuntimeError("greedy tokens diverged between refresh and "
                           "frozen-table runs")
    steps = max(eng_r.stats["steps"], 1)
    t = eng_r.kv.traffic
    return {
        "pre_refresh_ratio": ra, "post_refresh_ratio": rb,
        "frozen_pre_ratio": fa, "frozen_post_ratio": fb,
        "refreshes": eng_r.stats["kv_refreshes"],
        "pages_repacked": eng_r.stats["kv_pages_repacked"],
        "repack_bytes_per_step": (t["kv_repack_read_bytes"]
                                  + t["kv_repack_write_bytes"]) / steps,
        "steady_d2h_calls": d2h,
        "generation": eng_r.kv.generation,
    }


def pressure_scenario(arch: str = "qwen3-1.7b", *, requests: int = 4,
                      prompt_len: int = 8, max_new: int = 16,
                      pool_frac: float = 0.6,
                      slot_deadline: int = 6) -> dict:
    """Memory-pressure workload: the same request wave served twice — an
    uncontended control (pool sized for the full working set) and a
    pressure run whose pool holds only ``pool_frac`` of the working-set
    pages, with pressure escalation + a slot deadline forcing
    preempt-with-spill rotation through the compressed host spill tier.

    Graceful-degradation gates (enforced here, re-checked in CI from the
    emitted rows): every request completes, greedy tokens are
    bit-identical to the uncontended run (spill/readahead is lossless),
    the steady-state decode loop still makes zero ``device_get`` calls
    (readahead h2d rides admission events), and the spill traffic is
    APack-compressed (spill ratio < 1.0 vs the dense working set)."""
    import jax
    from repro import configs
    from repro.models import model as M
    from repro.serve import Request, ServeEngine

    base = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
    params = M.init_params(base, jax.random.PRNGKey(0))
    max_len = prompt_len + max_new + 8
    per_req = M.PagedKVCache.pages_for_config(
        cfg, prompt_len + max_new, 4)
    working = per_req * requests

    def run(pages, pressure: bool):
        eng = ServeEngine(
            cfg, params, max_batch=requests, max_len=max_len,
            kv_page_size=4, kv_calib_pages=2, kv_pages=pages,
            kv_pressure=pressure,
            slot_deadline_steps=slot_deadline if pressure else None)
        rng = np.random.default_rng(11)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                   prompt_len)
                        .astype(np.int32), max_new_tokens=max_new)
                for i in range(requests)]
        for r in reqs:
            eng.submit(r)
        per_step_d2h = []
        for _ in range(500):
            before = eng.kv.transfers["d2h_calls"]
            n = eng.step()
            if n == 0 and not eng.queue:
                break
            per_step_d2h.append(eng.kv.transfers["d2h_calls"] - before)
        else:
            raise RuntimeError("pressure engine failed to drain")
        bad = [r.rid for r in reqs if not r.done or r.error]
        if bad:
            raise RuntimeError(f"requests failed under pressure: {bad}")
        return eng, [r.tokens for r in reqs], \
            min(per_step_d2h) if per_step_d2h else 0

    _, toks_c, _ = run(None, False)                 # uncontended control
    pages_p = max(per_req, int(np.ceil(working * pool_frac)))
    eng, toks_p, d2h = run(pages_p, True)
    if toks_c != toks_p:
        # spill -> readahead -> resume must be invisible to sampling
        raise RuntimeError("greedy tokens diverged between pressure and "
                           "uncontended runs")
    tr = eng.kv.traffic
    if tr["kv_spill_pages"] == 0:
        raise RuntimeError("pressure run never spilled — pool sizing or "
                           "escalation is not exercising the tier")
    return {
        "pool_pages": pages_p, "working_set_pages": working,
        "spilled_pages": tr["kv_spill_pages"],
        "readahead_pages": tr["kv_readahead_pages"],
        "spill_ratio": tr["kv_spill_bytes"] / max(tr["kv_spill_raw_bytes"],
                                                  1),
        "steady_d2h_calls": d2h,
        "preemptions": eng.stats["preempted"],
        "deadline_preempted": eng.stats["deadline_preempted"],
        "pressure_preempted": eng.stats["pressure_preempted"],
        "completed": eng.stats["completed"],
        "requests": requests,
    }


def weight_stream_scenario(arch: str = "qwen3-1.7b", *, requests: int = 2,
                           prompt_len: int = 8, max_new: int = 12,
                           max_batch: int = 2, max_len: int = 32,
                           min_size: int = 1024) -> dict:
    """Packed-weight serving: the engine's weight store is APack planes
    (``weights="apack-int8"``) and every decode/prefill projection runs
    through the fused decompress-matmul.

    Weights are drawn heavy-tailed (sparse 16x outliers over a narrow
    normal bulk — the shape trained checkpoints actually have, and what
    sets the per-channel absmax), so the smoke measures a *realistic*
    APack weight ratio instead of the near-incompressible random-normal
    init.  The parity control is a dense engine serving the int8
    DEQUANTIZED weights — same quantization, different matmul path — so
    greedy token identity isolates the fused kernel against the dense
    einsum with the quantization-parity bound already applied.  The
    scenario raises on token divergence; the emitted row re-asserts it
    for the CI gate, alongside the measured per-step weight-read ratio
    and the fused path's steady-state zero-``device_get`` guard."""
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.core import quant
    from repro.models import model as M
    from repro.models import modules as mm
    from repro.serve import ServeEngine

    base = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
    params = M.init_params(base, jax.random.PRNGKey(0))

    # heavy-tailed re-draw: narrow normal bulk (sigma 0.02) plus sparse
    # 32x outliers (~1 per 16 rows of every output channel) — the
    # per-channel absmax is then set by an outlier, the bulk quantizes
    # to a few int8 codes, and APack's weight-mode table gets the
    # low-entropy histogram trained checkpoints exhibit.  Outliers are
    # dense enough that every quantization group still contains one
    # when a stacked tensor is later sliced and quantized per layer (a
    # group with no outlier would spread its bulk over the full int8
    # range and decompress to ~8 bits).
    rs = np.random.RandomState(7)

    def redraw(w):
        arr = np.asarray(jax.device_get(w))
        if arr.ndim < 2 or arr.dtype.kind != "f" or arr.size < min_size:
            return w
        vals = rs.normal(0.0, 0.015, arr.shape)
        flat = vals.reshape(-1, arr.shape[-1])
        # one outlier every 32 rows of each channel (random phase): any
        # contiguous per-layer slice of a stacked tensor is guaranteed
        # coverage, so every quantization group's absmax is outlier-set
        for c in range(flat.shape[1]):
            rows = rs.randint(0, 32) + 32 * np.arange(flat.shape[0] // 32)
            flat[rows, c] = rs.choice([-1.0, 1.0], rows.size) * 0.64
        return jnp.asarray(flat.reshape(arr.shape).astype(arr.dtype))

    params = jax.tree.map(redraw, params)

    # dense control: identical int8 quantization, dense einsum path —
    # built from the packed tree's site map so both engines quantize
    # exactly the same tensors
    packed_map, _ = M.pack_weights(cfg, params, min_size=min_size)

    def dequantized(pw, w):
        if not isinstance(pw, mm.PackedWeight):
            return w
        q, qp = quant.quantize_symmetric(jnp.asarray(w, jnp.float32),
                                         axis=-1)
        return (q.astype(jnp.float32) * qp.scale).astype(w.dtype)

    dense_q = jax.tree.map(
        dequantized, packed_map, params,
        is_leaf=lambda x: isinstance(x, mm.PackedWeight))

    kw = dict(requests=requests, prompt_len=prompt_len, max_new=max_new)
    eng_p = ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                        kv_page_size=4, kv_calib_pages=2,
                        weights="apack-int8", weight_min_size=min_size)
    eng_d = ServeEngine(cfg, dense_q, max_batch=max_batch, max_len=max_len,
                        kv_page_size=4, kv_calib_pages=2)

    def tokens_of(eng, seed):
        from repro.serve import Request
        rng = np.random.default_rng(seed)
        reqs = [Request(rid=seed * 1000 + i,
                        prompt=rng.integers(0, cfg.vocab_size, prompt_len)
                        .astype(np.int32), max_new_tokens=max_new)
                for i in range(requests)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        return [(r.prompt, np.asarray(r.tokens, np.int32)) for r in reqs]

    _serve_wave(eng_p, cfg, 0, **kw)            # warmup: jit compiles
    waves = [_serve_wave(eng_p, cfg, 1 + i, **kw) for i in range(REPEAT)]
    best = min(waves, key=lambda w: w["s_per_step"])
    # parity waves on fresh seeds, mirrored on the dense control.  Free-
    # running greedy decode compounds: one near-tie argmax flip (the two
    # paths order their f32 K-accumulation differently) rewrites every
    # later token of that request, so raw wave equality is too brittle
    # to gate on.  Instead the packed engine's output sequences are
    # re-scored TEACHER-FORCED under both weight stores with one full
    # forward each, and parity is the per-position argmax agreement —
    # flips cannot compound, and the measured max logit gap pins the
    # quantization-parity bound the fused kernel must hold.
    seqs = []
    for i in range(REPEAT):
        toks = tokens_of(eng_p, 100 + i)
        tokens_of(eng_d, 100 + i)      # same traffic through the control
        for prompt, gen in toks:
            seqs.append(np.concatenate([prompt, gen]))
    batch = {"tokens": jnp.asarray(np.stack(seqs), jnp.int32)}
    lp, _, _ = M.forward(cfg, eng_p.params, batch, remat=False)
    ld, _, _ = M.forward(cfg, eng_d.params, batch, remat=False)
    pred = slice(prompt_len - 1, -1)   # positions that predict new tokens
    ap = np.asarray(jnp.argmax(lp[:, pred], -1))
    ad = np.asarray(jnp.argmax(ld[:, pred], -1))
    token_identity = float((ap == ad).mean())
    logit_max_diff = float(jnp.max(jnp.abs(
        lp[:, pred].astype(jnp.float32) - ld[:, pred].astype(jnp.float32))))
    if token_identity < 0.98:
        raise RuntimeError(
            f"packed-weight argmax disagrees with the dense control on "
            f"{(1 - token_identity):.1%} of teacher-forced positions "
            f"(max logit diff {logit_max_diff:.4f})")
    ws = eng_p.weight_stats()
    return {
        "us_per_step": best["s_per_step"] * 1e6,
        "steps_per_s": 1.0 / best["s_per_step"],
        "weight_ratio": ws["weight_ratio"],
        "native_ratio": ws["native_ratio"],
        "packed_tensors": ws["packed_tensors"],
        "compressed_read_bytes_per_step":
            ws["compressed_read_bytes_per_step"],
        "dense_read_bytes_per_step": ws["dense_read_bytes_per_step"],
        "token_identity": token_identity,
        "logit_max_diff": logit_max_diff,
        "steady_d2h_calls": min(w["steady_d2h_calls"] for w in waves),
    }


def emit_weight_stream(emit, d: dict) -> None:
    emit("decode/weight_stream/ratio", 0.0,
         f"per-step weight-read bytes, packed vs int8 dense "
         f"({d['compressed_read_bytes_per_step']} / "
         f"{d['dense_read_bytes_per_step']} B; "
         f"x{d['native_ratio']:.3f} vs native dtype, "
         f"{d['packed_tensors']} tensors)",
         value=float(d["weight_ratio"]))
    emit("decode/weight_stream/steps_per_s", d["us_per_step"],
         f"decode steps/s serving from APack-packed weights "
         f"(steps_per_s={d['steps_per_s']:.2f})",
         value=float(d["steps_per_s"]))
    emit("decode/weight_stream/token_identity", 0.0,
         f"teacher-forced argmax agreement vs the dequantized-dense "
         f"control (max logit diff {d['logit_max_diff']:.4f}; the "
         f"scenario raises below 0.98)",
         value=float(d["token_identity"]))
    emit("decode/weight_stream/steady_d2h_calls", 0.0,
         "min per-step device_get calls with packed weights (0 = the "
         "fused loop stayed device-resident)",
         value=float(d["steady_d2h_calls"]))


def serving_scenario(arch: str = "qwen3-1.7b", *, requests: int = 12,
                     max_new: int = 8, max_batch: int = 3,
                     max_len: int = 48, load: float = 2.0) -> dict:
    """Open-loop serving workload: Poisson arrivals (seeded exponential
    inter-arrival gaps, scaled so the offered load is ``load`` of one
    engine's measured decode throughput) over a varied prompt-length mix
    including one long prompt, served by the synchronous engine and the
    async event-loop engine on the *same wall-clock arrival schedule*.
    The default load oversubscribes the engine (queueing regime): that is
    where the overlap pays — an idle engine admits like sync and only
    adds its one-step pipeline latency.

    Reports per-engine end-to-end latency p50/p99 and tokens/s, checks
    greedy tokens bit-identical between the two engines, and keeps the
    fused path's steady-state zero-``device_get`` guard on the async
    run — the overlap must hide host work, not move it back onto the
    device boundary."""
    import jax
    from repro import configs
    from repro.models import model as M
    from repro.serve import Request, ServeEngine

    base = configs.get_smoke_config(arch)
    cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
    params = M.init_params(base, jax.random.PRNGKey(0))
    rng = np.random.default_rng(23)
    lens = [6, 12, 9, 24, 7, 16, 10, 5]
    prompts = [rng.integers(0, cfg.vocab_size, lens[i % len(lens)])
               .astype(np.int32) for i in range(requests)]

    def build(scheduler):
        return ServeEngine(cfg, params, max_batch=max_batch,
                           max_len=max_len, kv_page_size=4,
                           kv_calib_pages=2, scheduler=scheduler)

    def warmup(eng):
        # two passes: the first eats every jit compile (prefill buckets,
        # decode); the second, compile-free, measures the honest service
        # rate — deriving arrival gaps from a compile-inflated step time
        # would spread the schedule out and quietly underload the wave
        t_step = 0.0
        for pass_base in (10_000, 20_000):
            for i, p in enumerate(prompts):
                eng.submit(Request(rid=pass_base + i, prompt=p,
                                   max_new_tokens=max_new))
            steps0 = eng.stats["steps"]
            t0 = time.perf_counter()
            eng.run_until_drained(max_steps=4000)
            t_step = ((time.perf_counter() - t0)
                      / max(eng.stats["steps"] - steps0, 1))
        eng._lat_wait.clear()
        eng._lat_e2e.clear()
        return t_step

    def wave(eng, arrivals, rid_base):
        reqs = [Request(rid=rid_base + i, prompt=p, max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        eng._lat_wait.clear()
        eng._lat_e2e.clear()
        steps0, gen0 = eng.stats["steps"], eng.stats["generated"]
        per_step_d2h = []
        nxt = 0
        t0 = time.perf_counter()
        for _ in range(5000):
            now = time.perf_counter() - t0
            while nxt < len(reqs) and arrivals[nxt] <= now:
                eng.submit(reqs[nxt])
                nxt += 1
            before = eng.kv.transfers["d2h_calls"]
            n = eng.step()
            if n:
                per_step_d2h.append(eng.kv.transfers["d2h_calls"]
                                    - before)
            if n == 0 and not eng.queue and not eng._pump:
                if nxt >= len(reqs):
                    break
                # idle until the next arrival (open-loop workload)
                time.sleep(max(arrivals[nxt]
                               - (time.perf_counter() - t0), 0.0))
        else:
            raise RuntimeError("serving wave failed to drain")
        wall = time.perf_counter() - t0
        assert all(r.done and not r.error for r in reqs)
        lat = eng.latency_stats()
        return {"tokens": [r.tokens for r in reqs],
                "tokens_per_s": (eng.stats["generated"] - gen0) / wall,
                "steps_per_s": (eng.stats["steps"] - steps0) / wall,
                "e2e_p50_ms": lat["e2e_p50"] * 1e3,
                "e2e_p99_ms": lat["e2e_p99"] * 1e3,
                "queue_wait_p99_ms": lat["queue_wait_p99"] * 1e3,
                "steady_d2h_calls": (min(per_step_d2h)
                                     if per_step_d2h else 0)}

    engines = {s: build(s) for s in ("sync", "async")}
    t_step = warmup(engines["sync"])
    warmup(engines["async"])
    # offered load: ~`load` requests' worth of decode work per unit of
    # measured engine capacity (the same absolute schedule drives both
    # engines — identical offered traffic)
    mean_gap = t_step * max_new / (load * max_batch)
    gaps = rng.exponential(mean_gap, requests)
    arrivals = np.cumsum(gaps)
    # 5 *interleaved* sync/async wave pairs on the same schedule, then
    # the median of the per-pair ratios: interleaving makes machine
    # drift hit both engines alike, pairing cancels it out of the
    # ratio, and the median shrugs off a throttle spike landing on one
    # wave.  (A min-per-engine statistic is wrong here: it compares
    # sync's luckiest wave against async's, which on a noisy host is a
    # coin flip.)  Greedy tokens are asserted identical across every
    # wave of both engines.
    waves: dict = {"sync": [], "async": []}
    for w in range(5):
        for scheduler in ("sync", "async"):
            r = wave(engines[scheduler], arrivals, rid_base=(w + 1) * 1000)
            if waves[scheduler] and r["tokens"] != waves[scheduler][0]["tokens"]:
                raise RuntimeError("greedy tokens diverged across waves")
            waves[scheduler].append(r)
    if waves["sync"][0]["tokens"] != waves["async"][0]["tokens"]:
        # the event loop must reschedule work, never change it
        raise RuntimeError("greedy tokens diverged between sync and "
                           "async engines")

    med = lambda xs: float(np.median(xs))
    out = {}
    for scheduler, eng in engines.items():
        rs = waves[scheduler]
        out[scheduler] = {
            k: med([r[k] for r in rs])
            for k in ("tokens_per_s", "steps_per_s", "e2e_p50_ms",
                      "e2e_p99_ms", "queue_wait_p99_ms")}
        out[scheduler]["steady_d2h_calls"] = min(
            r["steady_d2h_calls"] for r in rs)
        out[scheduler]["prefill_chunks"] = eng.stats["prefill_chunks"]
        out[scheduler]["staged_readahead"] = eng.stats["staged_readahead"]
    out["paired"] = {
        "e2e_p99_ratio": med(
            [a["e2e_p99_ms"] / s["e2e_p99_ms"]
             for a, s in zip(waves["async"], waves["sync"])]),
        "queue_wait_p99_ratio": med(
            [a["queue_wait_p99_ms"] / max(s["queue_wait_p99_ms"], 1e-9)
             for a, s in zip(waves["async"], waves["sync"])]),
        "tokens_per_s_ratio": med(
            [a["tokens_per_s"] / s["tokens_per_s"]
             for a, s in zip(waves["async"], waves["sync"])]),
    }
    return out


def emit_serving(emit, d: dict) -> None:
    for mode in ("sync", "async"):
        r = d[mode]
        emit(f"decode/serving_tokens_per_s/{mode}", 0.0,
             f"Poisson-arrival open-loop throughput, median of 5 waves "
             f"(steps/s={r['steps_per_s']:.2f})",
             value=float(r["tokens_per_s"]))
        emit(f"decode/serving_e2e_p99_ms/{mode}", 0.0,
             f"end-to-end latency p99, median of 5 waves "
             f"(p50={r['e2e_p50_ms']:.1f}ms, "
             f"queue-wait p99={r['queue_wait_p99_ms']:.1f}ms)",
             value=float(r["e2e_p99_ms"]))
    emit("decode/serving_steady_d2h_calls/async", 0.0,
         "min per-step device_get calls, async engine (0 = overlap keeps "
         "host work off the device boundary)",
         value=float(d["async"]["steady_d2h_calls"]))
    p = d["paired"]
    emit("decode/serving_paired_queue_wait_ratio", 0.0,
         "async/sync queue-wait p99, median over interleaved wave pairs "
         "— the scheduling tail the event loop controls directly "
         "(continuous admission + chunked prefill vs step-boundary FIFO)",
         value=float(p["queue_wait_p99_ratio"]))
    emit("decode/serving_paired_p99_ratio", 0.0,
         "async/sync e2e p99, median over interleaved wave pairs (on a "
         "serial CPU host the overlap cannot run concurrently, so this "
         "carries scheduling wins + host noise; accelerator hosts see "
         "the full overlap win)",
         value=float(p["e2e_p99_ratio"]))
    emit("decode/serving_async_speedup", 0.0,
         f"async/sync tokens-per-s, median over interleaved wave pairs; "
         f"{d['async']['prefill_chunks']} prefill chunks pumped "
         "(tokens bit-identical)",
         value=float(p["tokens_per_s_ratio"]))


_SHARDED_SCRIPT = r"""
import dataclasses, json, os, time
import numpy as np
import jax
from repro import configs
from repro.models import model as M
from repro.serve import ServeEngine, Request

REQ, NEW, PLEN = 8, 16, 9
REPEAT = 3
N_DATA = int(os.environ.get("REPRO_MESH_DATA", "8"))
N_MODEL = int(os.environ.get("REPRO_MESH_MODEL", "1"))
NDEV = N_DATA * N_MODEL
cfg = dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                          kv_cache_dtype="apack-int8")
params = M.init_params(configs.get_smoke_config("qwen3-1.7b"),
                       jax.random.PRNGKey(0))

def build(mb, mesh=None):
    return ServeEngine(cfg, params, max_batch=mb, max_len=48,
                       kv_page_size=16, kv_calib_pages=2, mesh=mesh)

def wave(eng, n_req, seed, jit_s=None):
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=seed * 1000 + i,
                    prompt=rng.integers(0, cfg.vocab_size, PLEN)
                    .astype(np.int32), max_new_tokens=NEW)
            for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.step()                        # admission/prefill, untimed
    steps0 = eng.stats["steps"]
    d2h = []
    t0 = time.perf_counter()
    for _ in range(500):
        before = eng.kv.transfers["d2h_calls"]
        n = eng.step()
        if n == 0 and not eng.queue:
            break
        d2h.append(eng.kv.transfers["d2h_calls"] - before)
    else:
        raise RuntimeError("engine failed to drain within 500 steps")
    wall = time.perf_counter() - t0
    assert all(r.done and r.error is None for r in reqs)
    steps = max(eng.stats["steps"] - steps0, 1)
    return {"tokens": [list(r.tokens) for r in reqs],
            "tok_per_s": n_req * NEW / wall,
            "s_per_step": wall / steps,
            "steady_d2h": min(d2h) if d2h else 0}

def serve(eng, n_req):
    wave(eng, n_req, 0)               # warmup eats every jit compile
    return [wave(eng, n_req, 1 + i) for i in range(REPEAT)]

one = serve(build(1), 1)
ctrl = serve(build(REQ), REQ)
mesh = jax.make_mesh((N_DATA, N_MODEL), ("data", "model"))
eng_s = build(REQ, mesh)
# wrap the combined sharded step to time its device portion: the host
# platform executes the per-shard programs back-to-back, so jit/NDEV is
# the per-shard critical path a real mesh runs concurrently.  Seal work
# (note_appended: HOT->COLD requantize, APack encode, fused plane
# scatter) is per-PAGE host work — pages are owned by shards, so on a
# real multi-host mesh each host seals only its own shards' pages and
# this bucket divides by NDEV too; only engine bookkeeping
# (retire/admit, step meta, token pull) stays serialized
jit_acc = {"s": 0.0, "n": 0}
seal_acc = {"s": 0.0}
orig = eng_s._step_mesh
def timed_step(*a):
    t0 = time.perf_counter()
    out = orig(*a)
    jax.block_until_ready(out[0])
    jit_acc["s"] += time.perf_counter() - t0
    jit_acc["n"] += 1
    return out
eng_s._step_mesh = timed_step
orig_note = eng_s.kv.note_appended
def timed_note(*a, **k):
    t0 = time.perf_counter()
    r = orig_note(*a, **k)
    seal_acc["s"] += time.perf_counter() - t0
    return r
eng_s.kv.note_appended = timed_note
wave(eng_s, REQ, 0)                   # warmup eats every jit compile
jit_acc["s"], jit_acc["n"] = 0.0, 0   # count compile-free steps only
seal_acc["s"] = 0.0
sh = [wave(eng_s, REQ, 1 + i) for i in range(REPEAT)]

identical = all(w_s["tokens"] == w_c["tokens"]
                for w_s, w_c in zip(sh, ctrl))
t1 = min(w["s_per_step"] for w in one)
tc = min(w["s_per_step"] for w in ctrl)
ts = min(w["s_per_step"] for w in sh)
ts_jit = jit_acc["s"] / max(jit_acc["n"], 1)
ts_seal = seal_acc["s"] / max(jit_acc["n"], 1)
serial = max(ts - ts_jit - ts_seal, 0.0)
parallel_step = ts_jit / NDEV + ts_seal / NDEV + serial
print(json.dumps({
    "mesh": f"{N_DATA}x{N_MODEL}",
    "tok_per_s_single": max(w["tok_per_s"] for w in one),
    "tok_per_s_sharded": max(w["tok_per_s"] for w in sh),
    "s_per_step_single": t1, "s_per_step_batch": tc,
    "s_per_step_sharded": ts, "s_per_step_jit": ts_jit,
    "s_per_step_seal": ts_seal, "s_per_step_serial": serial,
    "scaling_serialized_x": REQ * t1 / ts,
    "scaling_x": REQ * t1 / parallel_step,
    "step_overhead_x": ts / tc,
    "token_identity": bool(identical),
    "steady_d2h_calls": max(w["steady_d2h"] for w in sh)}))
"""


def sharded_scenario(devices: int | None = None,
                     mesh_shape: tuple[int, int] = (8, 1)) -> dict:
    """Mesh-sharded serving scaling row (DESIGN.md §11): a
    ``mesh_shape`` = (data, model) engine — default 8x1, pure data
    parallel; the ``--mesh`` CLI flag selects e.g. 4x2 for kv-head
    tensor parallelism — on a forced multi-device host platform vs the
    single-device engine, in a subprocess so the XLA device-count flag
    never leaks into this process (whose smoke rows must see 1 device).

    Reports aggregate tokens/s, greedy-token bit-identity against the
    single-device control serving the same waves, the per-shard
    steady-state zero-``device_get`` guard, and two scaling figures:
    ``scaling_serialized_x`` is the raw wall-clock aggregate over the
    single-request single-device rate (the host platform executes the 8
    per-shard programs back-to-back on one core, so this is floored near
    1x regardless of how well the sharding partitions); ``scaling_x``
    normalizes the two per-shard buckets to the critical path — device
    time (jit/n_devices) and per-page seal host work (seal/n_devices:
    pages are shard-owned, so on a real multi-host mesh each host
    requantizes/encodes/pushes only its own shards' pages) — while
    engine bookkeeping (retire/admit, step meta, token pull) stays
    fully serialized.  Every quantity is measured, none simulated."""
    import json
    import os
    import subprocess
    import sys
    from pathlib import Path

    n_data, n_model = mesh_shape
    devices = devices or n_data * n_model
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["REPRO_MESH_DATA"] = str(n_data)
    env["REPRO_MESH_MODEL"] = str(n_model)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    out = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        raise RuntimeError(f"sharded scenario subprocess failed:\n"
                           f"{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def emit_sharded(emit, d: dict) -> None:
    mesh = d.get("mesh", "8x1")
    emit("decode/sharded_tokens_per_s", 0.0,
         f"{mesh} mesh aggregate, 8 requests (serialized host platform; "
         f"step {d['s_per_step_sharded']*1e3:.1f}ms = jit "
         f"{d['s_per_step_jit']*1e3:.1f} + per-page seal "
         f"{d['s_per_step_seal']*1e3:.1f} + serial "
         f"{d['s_per_step_serial']*1e3:.1f})",
         value=float(d["tok_per_s_sharded"]))
    emit("decode/sharded_scaling_x", 0.0,
         f"aggregate tokens/s on the {mesh} mesh vs single-device "
         f"single-request engine; device time and per-page seal work "
         f"(partitions with page ownership across hosts) normalize to "
         f"the per-shard critical path, engine bookkeeping stays "
         f"serialized (raw fully-serialized ratio "
         f"{d['scaling_serialized_x']:.2f}x)",
         value=float(d["scaling_x"]))
    emit("decode/sharded_step_overhead_x", 0.0,
         "sharded step time over the single-device step on the same "
         "8-request batch — the partitioning overhead the mesh pays "
         "even before shards parallelize",
         value=float(d["step_overhead_x"]))
    emit("decode/sharded_token_identity", 0.0,
         "greedy tokens bit-identical to the single-device engine "
         "across every timed wave",
         value=float(d["token_identity"]))
    emit("decode/sharded_steady_d2h_calls", 0.0,
         "max per-step device_get calls across sharded waves (0 = the "
         "combined decode+append step stays device-resident per shard)",
         value=float(d["steady_d2h_calls"]))


def emit_pressure(emit, d: dict) -> None:
    emit("decode/pressure_completed", 0.0,
         f"requests completed with pool at "
         f"{d['pool_pages']}/{d['working_set_pages']} working-set pages "
         f"(tokens bit-identical to uncontended control)",
         value=float(d["completed"] == d["requests"]))
    emit("decode/pressure_spill_ratio", 0.0,
         f"spilled bytes / dense working-set bytes over "
         f"{d['spilled_pages']} spilled pages "
         f"({d['readahead_pages']} restored by readahead)",
         value=float(d["spill_ratio"]))
    emit("decode/pressure_spilled_pages", 0.0,
         f"{d['preemptions']} preemptions "
         f"({d['deadline_preempted']} deadline, "
         f"{d['pressure_preempted']} admission-pressure)",
         value=float(d["spilled_pages"]))
    emit("decode/pressure_steady_d2h_calls", 0.0,
         "min per-step device_get calls under pressure (0 = readahead "
         "stays off the step critical path)",
         value=float(d["steady_d2h_calls"]))


def emit_drift(emit, d: dict) -> None:
    emit("decode/drift_kv_ratio/pre_refresh", 0.0,
         f"phase-A window ratio, refresh engine "
         f"(frozen control: {d['frozen_pre_ratio']:.4f})",
         value=d["pre_refresh_ratio"])
    emit("decode/drift_kv_ratio/post_refresh", 0.0,
         f"phase-B window ratio after {d['refreshes']} refreshes / "
         f"{d['pages_repacked']} re-packed pages (gen {d['generation']})",
         value=d["post_refresh_ratio"])
    emit("decode/drift_kv_ratio/frozen_control", 0.0,
         "phase-B window ratio with tables frozen at first calibration",
         value=d["frozen_post_ratio"])
    emit("decode/drift_repack_bytes_per_step", 0.0,
         "re-pack read+write overhead amortized over decode steps",
         value=float(d["repack_bytes_per_step"]))
    emit("decode/drift_steady_d2h_calls", 0.0,
         "min per-step device_get calls with refresh active (0 = "
         "device-resident loop survives refresh)",
         value=float(d["steady_d2h_calls"]))


def main(emit) -> None:
    rows = {}
    for fused in (False, True):
        r = decode_throughput(fused=fused)
        rows[r["mode"]] = r
        emit(f"decode/steps_per_s/{r['mode']}", r["us_per_step"],
             f"steps_per_s={r['steps_per_s']:.2f} "
             f"kv_ratio={r['kv_ratio']:.3f}",
             value=r["steps_per_s"])
        emit(f"decode/transfer_bytes_per_step/{r['mode']}", 0.0,
             "host<->device bytes per decode step (KV path)",
             value=float(r["bytes_per_step"]))
        emit(f"decode/steady_state_d2h_calls/{r['mode']}", 0.0,
             "min per-step device_get calls (0 = device-resident loop)",
             value=float(r["steady_d2h_calls"]))
    speedup = rows["materialize"]["us_per_step"] / rows["fused"]["us_per_step"]
    shrink = (rows["materialize"]["bytes_per_step"]
              / max(rows["fused"]["bytes_per_step"], 1.0))
    emit("decode/fused_speedup", 0.0,
         f"materialize/fused step-time ratio; transfer shrink "
         f"{shrink:.1f}x", value=speedup)
    emit_weight_stream(emit, weight_stream_scenario())
    emit_drift(emit, drift_scenario())
    emit_pressure(emit, pressure_scenario())
    emit_serving(emit, serving_scenario())
    emit_sharded(emit, sharded_scenario())


if __name__ == "__main__":
    # standalone entry: `python -m benchmarks.bench_decode --drift` /
    # `--pressure` run just that scenario (the full module runs via
    # benchmarks.run)
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--drift", action="store_true",
                    help="run only the two-phase drift workload")
    ap.add_argument("--pressure", action="store_true",
                    help="run only the memory-pressure spill workload "
                         "(pool at 60% of the working set)")
    ap.add_argument("--serving", action="store_true",
                    help="run only the Poisson-arrival serving workload "
                         "(sync vs async event-loop engine)")
    ap.add_argument("--weights", action="store_true",
                    help="run only the packed-weight serving workload "
                         "(APack weight store vs dequantized dense)")
    ap.add_argument("--sharded", action="store_true",
                    help="run only the mesh-sharded scaling workload "
                         "(data-parallel vs single-device, forced "
                         "multi-device host platform in a subprocess)")
    ap.add_argument("--mesh", default="8x1", metavar="DATAxMODEL",
                    help="mesh shape for --sharded as DATAxMODEL, e.g. "
                         "8x1 (pure data parallel) or 4x2 (kv-heads "
                         "tensor-parallel over the model axis); data "
                         "must divide max_batch=8 and model must divide "
                         "the smoke config's 2 kv heads (default: 8x1, "
                         "the CI-gated row)")
    args = ap.parse_args()

    def _emit(name, us, derived, value=None):
        print(f"{name},{us:.1f},{derived}"
              + (f",value={value}" if value is not None else ""), flush=True)

    if args.drift:
        emit_drift(_emit, drift_scenario())
    elif args.pressure:
        emit_pressure(_emit, pressure_scenario())
    elif args.serving:
        emit_serving(_emit, serving_scenario())
    elif args.weights:
        emit_weight_stream(_emit, weight_stream_scenario())
    elif args.sharded:
        d, m = (int(x) for x in args.mesh.lower().split("x"))
        emit_sharded(_emit, sharded_scenario(mesh_shape=(d, m)))
    else:
        main(_emit)

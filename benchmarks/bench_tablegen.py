"""Paper Table I + §VI: probability-count table generation — quality
(footprint vs the 16-range entropy optimum and vs uniform init) and cost.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import distributions, tables
from repro.core.format import estimate_bits


def main(emit) -> None:
    for name, gen in distributions.PAPER_LIKE.items():
        v = gen(1 << 18)
        hist = tables.histogram(v)
        t0 = time.perf_counter()
        found = tables.find_table(hist, is_activation=True)
        dt = time.perf_counter() - t0
        uni = tables.uniform_table()
        bits_found = estimate_bits(hist, found)
        bits_uni = estimate_bits(hist, uni)
        p = hist[hist > 0] / hist.sum()
        entropy_bits = float(-(p * np.log2(p)).sum() * hist.sum())
        emit(f"tablegen/{name}", dt * 1e6,
             f"vs_uniform={bits_uni / max(bits_found, 1):.3f}x "
             f"vs_entropy={bits_found / max(entropy_bits, 1):.3f} "
             f"(1.0=optimal)")
    # print one example table (paper Table I analogue)
    v = distributions.gaussian_weights(1 << 16, sigma=3.0)
    t = tables.table_for(v)
    lines = ["IDX  v_min  v_max  OL   low   high      p"]
    for i in range(16):
        p = (t.cum[i + 1] - t.cum[i]) / 1024
        lines.append(f"{i:3d}  0x{t.v_min[i]:02X}   0x{t.v_min[i+1]-1:02X}"
                     f"   {t.ol[i]:2d}  0x{t.cum[i]:03X} 0x{t.cum[i+1]:03X}"
                     f"  {p:.4f}")
    emit("tablegen/example_table", 0.0, " | ".join(lines[:5]) + " ...")

"""Paper Fig. 7/8: end-to-end speedup + energy efficiency with APack
integrated into an accelerator.

Execution model (the paper's methodology, §VII-C): per layer,
``t = max(t_compute, t_memory)``; APack divides the off-chip byte volume by
the measured compression ratio; speedup = sum(t_base)/sum(t_apack).  Two
accelerator configs:

  * the paper's TensorCore design — 8.2 int8-TOPS, 51.2 GB/s dual-channel
    DDR4-3200 (Table III),
  * TPU v5e — 197 bf16-TFLOP/s, 819 GB/s HBM (the adaptation target).

Workloads: the 10-arch zoo in decode (memory-bound, batch 8) and prefill
(compute-bound) regimes; weights int8 + APack, KV/activations int8 + APack.
"""
from __future__ import annotations

import numpy as np

from repro import configs

PAPER_ACC = {"flops": 8.2e12, "bw": 51.2e9, "name": "tensorcore"}
TPU_V5E = {"flops": 197e12, "bw": 819e9, "name": "tpu_v5e"}
# measured by bench_traffic on the zoo (updated from its geomeans at runtime
# if available); defaults are the synthetic-distribution geomeans
DEFAULT_W_RATIO = 1.4
DEFAULT_A_RATIO = 2.0
COMPUTE_E_PJ_PER_FLOP = 0.5        # 65nm int8 MAC ~0.5 pJ (Horowitz)
DRAM_E_PJ_PER_BIT = 20.0


def layer_costs(cfg, seq: int, batch: int, decode: bool):
    """(flops, weight_bytes, act_bytes) per full model pass."""
    n = cfg.active_param_count()
    w_bytes = n                              # int8 weights
    tokens = batch * (1 if decode else seq)
    flops = 2 * n * tokens
    if decode:
        # KV cache read per token (attention archs)
        kv = (cfg.num_layers * batch * seq * cfg.num_kv_heads
              * cfg.head_dim * 2)
        act_bytes = kv
    else:
        act_bytes = batch * seq * cfg.d_model * cfg.num_layers * 2
    return flops, w_bytes, act_bytes


def model_time(acc, flops, w_bytes, a_bytes, w_ratio=1.0, a_ratio=1.0):
    t_c = flops / acc["flops"]
    t_m = (w_bytes / w_ratio + a_bytes / a_ratio) / acc["bw"]
    return max(t_c, t_m), t_c, t_m


def main(emit, w_ratio: float = DEFAULT_W_RATIO,
         a_ratio: float = DEFAULT_A_RATIO) -> None:
    for acc in (PAPER_ACC, TPU_V5E):
        speedups, effs, mem_speedups = [], [], []
        for arch in configs.all_arch_ids():
            cfg = configs.get_config(arch)
            for regime, decode, batch, seq in (("decode", True, 8, 4096),
                                               ("prefill", False, 1, 4096)):
                if cfg.is_encoder and decode:
                    continue
                flops, wb, ab = layer_costs(cfg, seq, batch, decode)
                t0, tc0, tm0 = model_time(acc, flops, wb, ab)
                t1, _, _ = model_time(acc, flops, wb, ab, w_ratio, a_ratio)
                sp = t0 / t1
                e0 = (flops * COMPUTE_E_PJ_PER_FLOP
                      + (wb + ab) * 8 * DRAM_E_PJ_PER_BIT)
                e1 = (flops * COMPUTE_E_PJ_PER_FLOP
                      + (wb / w_ratio + ab / a_ratio) * 8
                      * DRAM_E_PJ_PER_BIT * 1.047)
                eff = e0 / e1
                bound = "mem" if tm0 > tc0 else "compute"
                emit(f"speedup/{acc['name']}/{arch}/{regime}", t1 * 1e6,
                     f"speedup={sp:.2f}x eff={eff:.2f}x bound={bound}")
                speedups.append(sp)
                effs.append(eff)
                if bound == "mem":
                    mem_speedups.append(sp)
        emit(f"speedup/{acc['name']}/geomean", 0.0,
             f"speedup={np.exp(np.mean(np.log(speedups))):.2f}x "
             f"eff={np.exp(np.mean(np.log(effs))):.2f}x "
             f"(paper: 1.44x / 1.37x over a mostly memory-bound suite)")
        if mem_speedups:
            # the paper's 24-model suite is predominantly memory-bound on
            # its 8.2 TOPS / 51 GB/s accelerator; this is the like-for-like
            emit(f"speedup/{acc['name']}/geomean_membound", 0.0,
                 f"speedup={np.exp(np.mean(np.log(mem_speedups))):.2f}x "
                 f"over {len(mem_speedups)} memory-bound cells")

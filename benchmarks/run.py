"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from . import (bench_distributions, bench_tablegen, bench_traffic,
                   bench_energy, bench_speedup, bench_codec, bench_roofline,
                   bench_trained)
    mods = [
        ("distributions(Fig2)", bench_distributions),
        ("tablegen(TableI)", bench_tablegen),
        ("traffic(Fig5)", bench_traffic),
        ("energy(Fig6)", bench_energy),
        ("speedup(Fig7/8)", bench_speedup),
        ("codec(§VII-B)", bench_codec),
        ("trained(§VII-A)", bench_trained),
        ("roofline(§Roofline)", bench_roofline),
    ]
    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str) -> None:
        print(f"{name},{us:.1f},{derived}", flush=True)

    failed = 0
    for label, mod in mods:
        t0 = time.time()
        try:
            mod.main(emit)
            emit(f"_section/{label}", (time.time() - t0) * 1e6, "ok")
        except Exception as e:
            failed += 1
            traceback.print_exc(file=sys.stderr)
            emit(f"_section/{label}", (time.time() - t0) * 1e6, f"FAILED: {e}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()

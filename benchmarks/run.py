"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--json PATH`` additionally
dumps the rows as machine-readable JSON (the perf trajectory across PRs is
tracked by committing ``BENCH_codec.json`` from ``--only codec --json
BENCH_codec.json``).  ``--only SUBSTR`` restricts to matching sections.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write results as JSON to PATH")
    ap.add_argument("--only", metavar="SUBSTR", default=None,
                    help="run only sections whose label contains SUBSTR")
    args = ap.parse_args(argv)

    from . import (bench_distributions, bench_tablegen, bench_traffic,
                   bench_energy, bench_speedup, bench_codec, bench_decode,
                   bench_roofline, bench_trained, bench_analysis)
    mods = [
        ("distributions(Fig2)", bench_distributions),
        ("tablegen(TableI)", bench_tablegen),
        ("traffic(Fig5)", bench_traffic),
        ("energy(Fig6)", bench_energy),
        ("speedup(Fig7/8)", bench_speedup),
        ("codec(§VII-B)", bench_codec),
        ("decode(§Serving)", bench_decode),
        ("trained(§VII-A)", bench_trained),
        ("roofline(§Roofline)", bench_roofline),
        ("analysis(§Invariants)", bench_analysis),
    ]
    if args.only:
        mods = [(label, mod) for label, mod in mods if args.only in label]
        if not mods:
            ap.error(f"--only {args.only!r} matches no benchmark section")
    print("name,us_per_call,derived")

    rows: list[dict] = []

    def emit(name: str, us: float, derived: str, value: float | None = None) -> None:
        print(f"{name},{us:.1f},{derived}", flush=True)
        row = {"name": name, "us_per_call": round(us, 1), "derived": derived}
        if value is not None:
            # machine-readable scalar (e.g. the measured KV compression
            # ratio) so trajectory tooling doesn't parse `derived` strings
            row["value"] = value
        rows.append(row)

    failed = 0
    for label, mod in mods:
        t0 = time.time()
        try:
            mod.main(emit)
            emit(f"_section/{label}", (time.time() - t0) * 1e6, "ok")
        except Exception as e:
            failed += 1
            traceback.print_exc(file=sys.stderr)
            emit(f"_section/{label}", (time.time() - t0) * 1e6, f"FAILED: {e}")

    if args.json:
        doc = {
            "schema": "apack-bench-v1",
            "platform": platform.platform(),
            "python": platform.python_version(),
            "results": rows,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()

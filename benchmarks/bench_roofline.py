"""§Roofline: read the dry-run JSONs and print the per-cell roofline table
(compute / memory / collective seconds per device, dominant term, useful-
FLOPs ratio).  Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path("runs/dryrun2")


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    if not DRYRUN_DIR.exists():
        return cells
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        with open(p) as f:
            c = json.load(f)
        if mesh and c.get("mesh") != mesh:
            continue
        cells.append(c)
    return cells


# decode-phase KV-stream roofline (Fig. 7 analogue): the decode step is
# memory-bound on the KV read, so the bandwidth-bound step-time speedup of
# serving through the compressed pool is 1/ratio at either technology
MEM_GBPS = {"ddr4-3200": 25.6, "hbm2e": 450.0}


def decode_kv_rows(emit) -> None:
    from .common import measured_kv_stats
    kv = measured_kv_stats()
    if kv.get("kv_ratio") is None:
        emit("roofline/decode_kv/missing", 0.0, "no measured KV reads")
        return
    steps = max(kv["steps"], 1)
    raw_b = kv["kv_raw_bytes"] / steps
    comp_b = (kv["kv_read_bytes"] + kv["kv_table_bytes"]) / steps
    for tech, gbps in MEM_GBPS.items():
        t_raw = raw_b / (gbps * 1e9)
        t_comp = comp_b / (gbps * 1e9)
        emit(f"roofline/decode_kv/{tech}", t_comp * 1e6,
             f"KV-stream bandwidth-bound decode: raw={t_raw * 1e6:.3f}"
             f"us/step apack={t_comp * 1e6:.3f}us/step "
             f"speedup={t_raw / t_comp:.3f}x "
             f"(measured kv_ratio={kv['kv_ratio']:.3f})",
             value=t_raw / t_comp)


def main(emit) -> None:
    decode_kv_rows(emit)
    cells = load_cells()
    if not cells:
        emit("roofline/missing", 0.0, "run launch.dryrun first")
        return
    for c in cells:
        key = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        if c["status"] != "ok":
            emit(key, 0.0, f"SKIP: {c.get('reason')}")
            continue
        r = c["roofline"]
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(key, step * 1e6,
             f"compute={r['compute_s']*1e3:.2f}ms "
             f"memory={r['memory_s']*1e3:.2f}ms "
             f"coll={r['collective_s']*1e3:.2f}ms "
             f"dominant={r['dominant']} "
             f"useful={r['useful_flops_ratio']:.2f} "
             f"peak_mem={c['memory']['peak_bytes']/2**30:.1f}GiB")

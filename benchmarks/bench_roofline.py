"""§Roofline: read the dry-run JSONs and print the per-cell roofline table
(compute / memory / collective seconds per device, dominant term, useful-
FLOPs ratio).  Run the sweep first:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path("runs/dryrun2")


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    if not DRYRUN_DIR.exists():
        return cells
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        with open(p) as f:
            c = json.load(f)
        if mesh and c.get("mesh") != mesh:
            continue
        cells.append(c)
    return cells


def main(emit) -> None:
    cells = load_cells()
    if not cells:
        emit("roofline/missing", 0.0, "run launch.dryrun first")
        return
    for c in cells:
        key = f"roofline/{c['arch']}/{c['shape']}/{c['mesh']}"
        if c["status"] != "ok":
            emit(key, 0.0, f"SKIP: {c.get('reason')}")
            continue
        r = c["roofline"]
        step = max(r["compute_s"], r["memory_s"], r["collective_s"])
        emit(key, step * 1e6,
             f"compute={r['compute_s']*1e3:.2f}ms "
             f"memory={r['memory_s']*1e3:.2f}ms "
             f"coll={r['collective_s']*1e3:.2f}ms "
             f"dominant={r['dominant']} "
             f"useful={r['useful_flops_ratio']:.2f} "
             f"peak_mem={c['memory']['peak_bytes']/2**30:.1f}GiB")

"""Paper Fig. 2: cumulative value distributions of int8 weights and
activations — verifies the bimodal (near-0 / near-255) shape APack exploits.
"""
from __future__ import annotations

import numpy as np

from repro.core import distributions


def main(emit) -> None:
    for name, gen in distributions.PAPER_LIKE.items():
        v = np.sort(gen(1 << 18).astype(np.int64))
        q = {p: int(v[int(p / 100 * (v.size - 1))]) for p in (10, 25, 50, 75, 90)}
        lo = float(np.mean(v <= 16) * 100)
        hi = float(np.mean(v >= 240) * 100)
        emit(f"distributions/{name}", 0.0,
             f"p10..p90={list(q.values())} low16={lo:.0f}% high240={hi:.0f}%")

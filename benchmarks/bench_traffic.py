"""Paper Fig. 5 (a/b): normalized off-chip traffic for weights and
activations — APack vs RLE / RLEZ / ShapeShifter vs no compression.

Two tensor sources: (1) synthetic distributions matching the paper's
workload statistics (core/distributions.py), (2) this repo's 10-arch model
zoo (random-init weights + real forward-pass activations, int8-quantized).
Ratios use exact payload bits from the vectorized codec.
"""
from __future__ import annotations

import numpy as np

from repro.core import baselines, distributions, format as fmt, tables
from repro.kernels import fastpath

from . import common


def compress_ratio(v: np.ndarray, is_activation: bool) -> dict[str, float]:
    v = np.asarray(v).reshape(-1)
    orig = v.size * 8
    table = tables.table_for(v[:1 << 20], is_activation=is_activation)
    ct = fastpath.compress_np(v, table)
    return {
        "baseline": 1.0,
        "rle": orig / max(baselines.rle_bits(v), 1),
        "rlez": orig / max(baselines.rlez_bits(v), 1),
        "shapeshifter": orig / max(baselines.shapeshifter_bits(v), 1),
        "apack": orig / max(ct.total_bits, 1),
        "apack_payload": orig / max(ct.payload_bits, 1),
    }


def rows() -> list[dict]:
    out = []
    n = 1 << 20
    for name, gen in distributions.PAPER_LIKE.items():
        kind = "act" if "activation" in name else "weight"
        r = compress_ratio(gen(n), is_activation=(kind == "act"))
        out.append({"tensor": f"synthetic/{name}", "kind": kind, **r})
    for arch, v in common.zoo_weight_samples().items():
        out.append({"tensor": f"zoo/{arch}", "kind": "weight",
                    **compress_ratio(v, False)})
    for arch, v in common.zoo_activation_samples().items():
        out.append({"tensor": f"zoo/{arch}", "kind": "act",
                    **compress_ratio(v, True)})
    return out


def summarize(rs: list[dict]) -> dict:
    acts = [r["apack"] for r in rs if r["kind"] == "act"]
    wts = [r["apack"] for r in rs if r["kind"] == "weight"]
    wins = sum(r["apack"] >= max(r["rle"], r["rlez"], r["shapeshifter"])
               for r in rs)
    return {
        "apack_act_geomean": float(np.exp(np.mean(np.log(acts)))),
        "apack_weight_geomean": float(np.exp(np.mean(np.log(wts)))),
        "apack_wins": f"{wins}/{len(rs)}",
    }


def main(emit) -> None:
    rs = rows()
    for r in rs:
        emit(f"traffic/{r['tensor']}/{r['kind']}", 0.0,
             f"apack={r['apack']:.3f}x ss={r['shapeshifter']:.3f}x "
             f"rle={r['rle']:.3f}x rlez={r['rlez']:.3f}x")
    s = summarize(rs)
    emit("traffic/summary", 0.0,
         f"act_geomean={s['apack_act_geomean']:.3f}x "
         f"weight_geomean={s['apack_weight_geomean']:.3f}x "
         f"wins={s['apack_wins']}")

"""Paper Fig. 5 (a/b): normalized off-chip traffic for weights and
activations — APack vs RLE / RLEZ / ShapeShifter vs no compression.

Two tensor sources: (1) synthetic distributions matching the paper's
workload statistics (core/distributions.py), (2) this repo's 10-arch model
zoo (random-init weights + real forward-pass activations, int8-quantized).
Ratios use exact payload bits from the vectorized codec.

Plus the serving-side measurement: decode KV-cache traffic through the
paged ``kv_cache_dtype="apack-int8"`` engine (activation-mode tables,
Pallas gather-decode reads) — the measured compressed/raw read ratio is
reported as the row *value* (< 1.0 is a win) so the JSON trajectory tracks
it across PRs.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import baselines, distributions, format as fmt, tables
from repro.kernels import fastpath

from . import common


def compress_ratio(v: np.ndarray, is_activation: bool) -> dict[str, float]:
    v = np.asarray(v).reshape(-1)
    orig = v.size * 8
    table = tables.table_for(v[:1 << 20], is_activation=is_activation)
    ct = fastpath.compress_np(v, table)
    return {
        "baseline": 1.0,
        "rle": orig / max(baselines.rle_bits(v), 1),
        "rlez": orig / max(baselines.rlez_bits(v), 1),
        "shapeshifter": orig / max(baselines.shapeshifter_bits(v), 1),
        "apack": orig / max(ct.total_bits, 1),
        "apack_payload": orig / max(ct.payload_bits, 1),
    }


def rows() -> list[dict]:
    out = []
    n = 1 << 20
    for name, gen in distributions.PAPER_LIKE.items():
        kind = "act" if "activation" in name else "weight"
        r = compress_ratio(gen(n), is_activation=(kind == "act"))
        out.append({"tensor": f"synthetic/{name}", "kind": kind, **r})
    for arch, v in common.zoo_weight_samples().items():
        out.append({"tensor": f"zoo/{arch}", "kind": "weight",
                    **compress_ratio(v, False)})
    for arch, v in common.zoo_activation_samples().items():
        out.append({"tensor": f"zoo/{arch}", "kind": "act",
                    **compress_ratio(v, True)})
    return out


def summarize(rs: list[dict]) -> dict:
    acts = [r["apack"] for r in rs if r["kind"] == "act"]
    wts = [r["apack"] for r in rs if r["kind"] == "weight"]
    wins = sum(r["apack"] >= max(r["rle"], r["rlez"], r["shapeshifter"])
               for r in rs)
    return {
        "apack_act_geomean": float(np.exp(np.mean(np.log(acts)))),
        "apack_weight_geomean": float(np.exp(np.mean(np.log(wts)))),
        "apack_wins": f"{wins}/{len(rs)}",
    }


def kv_cache_traffic(arch: str = "qwen3-1.7b", *, requests: int = 4,
                     prompt_len: int = 12, max_new: int = 6,
                     max_batch: int = 2, max_len: int = 32) -> dict:
    """Serve a smoke model with the paged APack KV cache and report the
    measured decode-read traffic (compressed vs raw int8-KV bytes),
    accounted per stream kind (global KV / rolling KV / recurrent state).

    ``arch="hetero-serve-smoke"`` runs the synthetic heterogeneous config
    (global + rolling + recurrent cycle, recurrent prefix) whose window is
    small enough that rolling-page eviction triggers within the run."""
    import jax
    from repro import configs
    from repro.models import model as M
    from repro.serve import Request, ServeEngine

    cfg = dataclasses.replace(configs.get_smoke_config(arch),
                              kv_cache_dtype="apack-int8")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                         kv_page_size=4, kv_calib_pages=2)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, prompt_len)
                    .astype(np.int32), max_new_tokens=max_new)
            for i in range(requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.perf_counter()
    engine.run_until_drained(max_steps=500)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    ks = engine.kv_stats()
    ks["arch"] = arch
    ks["wall_s"] = dt
    ks["steps"] = engine.stats["steps"]
    return ks


def main(emit) -> None:
    rs = rows()
    for r in rs:
        emit(f"traffic/{r['tensor']}/{r['kind']}", 0.0,
             f"apack={r['apack']:.3f}x ss={r['shapeshifter']:.3f}x "
             f"rle={r['rle']:.3f}x rlez={r['rlez']:.3f}x")
    s = summarize(rs)
    emit("traffic/summary", 0.0,
         f"act_geomean={s['apack_act_geomean']:.3f}x "
         f"weight_geomean={s['apack_weight_geomean']:.3f}x "
         f"wins={s['apack_wins']}")
    for arch, kw in (("qwen3-1.7b", {}),
                     ("hetero-serve-smoke",
                      dict(max_len=40, max_new=16, requests=3))):
        # the default-args qwen serve is shared with energy/roofline
        # (common.measured_kv_stats caches it within one bench run)
        kv = (common.measured_kv_stats(arch) if not kw
              else kv_cache_traffic(arch, **kw))
        if kv["kv_ratio"] is None:
            # no KV read traffic: emit the row WITHOUT a value so the CI
            # ratio gate skips it instead of vacuously passing on 1.0
            emit(f"traffic/kv_cache/{kv['arch']}", 0.0,
                 "no KV reads (ratio n/a)")
            continue
        emit(f"traffic/kv_cache/{kv['arch']}",
             kv["wall_s"] * 1e6 / max(kv["steps"], 1),
             f"ratio={kv['kv_ratio']:.3f} raw={kv['kv_raw_bytes']}B "
             f"read={kv['kv_read_bytes']}B tables={kv['kv_table_bytes']}B "
             f"packed_pages={kv['kv_pages_packed']} "
             f"evicted_pages={kv['kv_pages_evicted']} "
             f"high_water={kv['kv_pages_high_water']}",
             value=kv["kv_ratio"])
        for kind, st in kv["kv_streams"].items():
            if st.get("ratio") is None:
                continue
            emit(f"traffic/kv_stream/{kv['arch']}/{kind}", 0.0,
                 " ".join(f"{k}={v}" for k, v in st.items()
                          if k != "ratio")
                 + f" ratio={st['ratio']:.3f}",
                 value=st["ratio"])
        # structured eviction count (CI gates on this row's value, not on
        # parsing the human-readable `derived` string above)
        emit(f"traffic/kv_evicted/{kv['arch']}", 0.0,
             "rolling pages freed during decode",
             value=float(kv["kv_pages_evicted"]))

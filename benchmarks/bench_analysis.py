"""Trend row for the static invariant analyzer (``repro.analysis``).

Not a perf benchmark of product code — a health row for the analysis
suite itself, so the trajectory JSON records per-PR:

* how long each pass takes on the live tree (the analyzer runs in CI
  before the test suite, so its wall-clock is part of every red/green
  cycle and should stay in the sub-second range);
* how many findings/suppressions the tree carries (the suppression
  count creeping up is the earliest sign the hot path is accreting
  boundary traffic behind one-line reasons).
"""
from __future__ import annotations

import time


def main(emit) -> None:
    from repro.analysis import run_passes
    from repro.analysis.runner import DEFAULT_ROOT

    t0 = time.perf_counter()
    report = run_passes(DEFAULT_ROOT)
    total_s = time.perf_counter() - t0

    emit("analysis/total", total_s * 1e6,
         f"5 passes over {DEFAULT_ROOT.name}/", value=float(len(report.findings)))
    for pass_id, secs in sorted(report.pass_seconds.items()):
        emit(f"analysis/pass/{pass_id}", secs * 1e6,
             "wall-clock for one pass", value=float(
                 sum(1 for f in report.findings if f.pass_id == pass_id)))
    emit("analysis/new_vs_baseline", 0.0,
         "findings not in committed baseline (CI gate)",
         value=float(len(report.new)))
    emit("analysis/suppressions", 0.0,
         f"{report.suppressions_used}/{report.suppressions_total} used",
         value=float(report.suppressions_total))

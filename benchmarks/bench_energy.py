"""Paper Fig. 6: normalized off-chip energy.

Methodology mirror: the paper runs compressed traffic volumes through
Micron's DDR4 power model and adds the codec engines' power (4.7% of the
DRAM system at 90% utilization).  We use energy-per-bit constants
(DDR4-3200 ~20 pJ/bit end-to-end; HBM2e/TPU ~3.5 pJ/bit) times measured
compression ratios, plus the same fractional codec overhead.
"""
from __future__ import annotations

import numpy as np

from repro.core import distributions, tables
from repro.kernels import fastpath

DDR4_PJ_PER_BIT = 20.0
HBM_PJ_PER_BIT = 3.5
CODEC_OVERHEAD = 0.047        # paper: 64 engines = 4.7% of DRAM power


def energy_row(name: str, v: np.ndarray, is_act: bool) -> dict:
    table = tables.table_for(np.asarray(v).reshape(-1)[:1 << 20],
                             is_activation=is_act)
    ct = fastpath.compress_np(v, table)
    ratio = v.size * 8 / max(ct.total_bits, 1)
    base_e = v.size * 8 * DDR4_PJ_PER_BIT
    apack_e = (v.size * 8 / ratio) * DDR4_PJ_PER_BIT * (1 + CODEC_OVERHEAD)
    return {"tensor": name, "ratio": ratio,
            "normalized_energy": apack_e / base_e,
            "savings_pct": 100 * (1 - apack_e / base_e)}


def main(emit) -> None:
    n = 1 << 20
    cases = {
        "pruned_weights (AlexNet-Eyeriss-like)": (
            distributions.pruned_weights(n, sparsity=0.89), False),
        "pruned_weights (GoogLeNet-Eyeriss-like)": (
            distributions.pruned_weights(n, sparsity=0.7), False),
        "gaussian_weights": (distributions.gaussian_weights(n), False),
        "noisy_weights (NCF-like)": (distributions.noisy_weights(n), False),
        "relu_activations": (distributions.relu_activations(n), True),
    }
    for name, (v, is_act) in cases.items():
        r = energy_row(name, v, is_act)
        emit(f"energy/{name}", 0.0,
             f"normalized={r['normalized_energy']:.3f} "
             f"savings={r['savings_pct']:.1f}%")
    # paper anchors: AlexNet-Eyeriss 91% / GoogLeNet-Eyeriss 72% weight
    # energy savings; NCF ~13%; activations ~53% (NCF)

    # decode KV stream (Fig. 6 analogue from *measured* serving traffic):
    # the paged engine's compressed/raw read ratio through the same
    # energy-per-bit model, per memory technology — per-step pJ uses the
    # engine's actual bytes-per-step, not a synthetic tensor
    from .common import measured_kv_stats
    kv = measured_kv_stats()
    if kv.get("kv_ratio") is not None:
        steps = max(kv["steps"], 1)
        raw_bits = kv["kv_raw_bytes"] * 8 / steps
        comp_bits = (kv["kv_read_bytes"] + kv["kv_table_bytes"]) * 8 / steps
        normalized = (comp_bits / raw_bits) * (1 + CODEC_OVERHEAD)
        for tech, pj in (("ddr4", DDR4_PJ_PER_BIT), ("hbm", HBM_PJ_PER_BIT)):
            emit(f"energy/kv_decode_stream/{tech}", 0.0,
                 f"measured kv_ratio={kv['kv_ratio']:.3f} "
                 f"raw={raw_bits * pj / 1e6:.2f}uJ/step "
                 f"apack={comp_bits * pj * (1 + CODEC_OVERHEAD) / 1e6:.2f}"
                 f"uJ/step normalized={normalized:.3f} "
                 f"savings={100 * (1 - normalized):.1f}%",
                 value=normalized)

"""Trained-model compression: briefly train the qwen3 smoke model, then
compare APack ratios on its weights/activations against random init.

Measured finding (kept deliberately): a few hundred steps do NOT develop
the paper's trained-checkpoint skew — per-channel quantization normalizes
absolute scale, and distribution kurtosis only grows over full training
runs with weight decay.  The paper's 1.13-11.4x ratios come from fully
trained/pruned checkpoints; core/distributions.py models those shapes
directly (bench_traffic), while this benchmark documents that short
fine-tuning alone leaves distributions near-gaussian.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import quant, tables
from repro.data import DataConfig, SyntheticLM
from repro.kernels import fastpath
from repro.models import model as M
from repro.train import AdamWConfig, init_state
from repro.train.train_step import make_train_step


def apack_ratio(x: np.ndarray, is_act: bool) -> float:
    if x.dtype.kind == "f":
        q, _ = quant.quantize_symmetric(jnp.asarray(x, jnp.float32))
        u = quant.to_unsigned(np.asarray(q))
    else:
        u = np.asarray(x)
    t = tables.table_for(u.reshape(-1)[:1 << 20], is_activation=is_act)
    ct = fastpath.compress_np(u, t)
    return u.size * 8 / ct.payload_bits


def weight_sample(params) -> np.ndarray:
    leaves = [np.asarray(x, np.float32) for x in jax.tree.leaves(params)
              if hasattr(x, "ndim") and x.ndim >= 2 and x.size > 4096]
    return np.concatenate([l.reshape(-1, l.shape[-1])[:2048].reshape(-1)
                           for l in leaves])


def act_sample(cfg, params, seed=0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 128)))}
    h = M.embed_inputs(cfg, params, batch)
    acts = []
    for i, kind in enumerate(cfg.cycle):
        p0 = jax.tree.map(lambda x: x[0], params["blocks"][i])
        h, _, _ = M.block_full(cfg, kind, p0, h)
        acts.append(np.asarray(h, np.float32).reshape(-1))
    flat = np.concatenate(acts)
    q, _ = quant.quantize_affine(jnp.asarray(flat), bits=8)
    return np.asarray(q)


def main(emit, steps: int = 300) -> None:
    cfg = configs.get_smoke_config("qwen3-1.7b")
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps,
                      weight_decay=0.1)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    r_w0 = apack_ratio(weight_sample(params), False)
    r_a0 = apack_ratio(act_sample(cfg, params), True)

    data = SyntheticLM(DataConfig(batch_size=8, seq_len=128,
                                  vocab_size=cfg.vocab_size))
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    opt = init_state(ocfg, params)
    first = last = None
    for i in range(steps):
        b = data.next_batch()
        params, opt, m = step(params, opt, {"tokens": jnp.asarray(b["tokens"])})
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    r_w1 = apack_ratio(weight_sample(params), False)
    r_a1 = apack_ratio(act_sample(cfg, params), True)
    emit("trained/loss", 0.0, f"{first:.3f} -> {last:.3f} ({steps} steps)")
    emit("trained/weights", 0.0,
         f"apack {r_w0:.3f}x (init) -> {r_w1:.3f}x (trained)")
    emit("trained/activations", 0.0,
         f"apack {r_a0:.3f}x (init) -> {r_a1:.3f}x (trained)")

"""Serve a small model with batched requests from APack-compressed weights
(paper Fig. 1 integration at the serving layer).

    PYTHONPATH=src python examples/serve_compressed.py
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

if __name__ == "__main__":
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    raise SystemExit(subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-1.7b",
         "--smoke", "--requests", "12", "--prompt-len", "16",
         "--max-new", "12", "--max-batch", "4"] + sys.argv[1:],
        env=env).returncode)

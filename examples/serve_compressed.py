"""Serve a small model with batched requests from APack-compressed weights
AND a paged, APack-compressed int8 KV cache (paper Fig. 1 integration at
the serving layer: weights decompress at load, decode KV reads go through
the activation-mode gather-decode path and the run prints the measured
raw-vs-compressed KV traffic ratio).

    PYTHONPATH=src python examples/serve_compressed.py
    # raw-KV baseline for comparison:
    PYTHONPATH=src python examples/serve_compressed.py --kv int8
    # heterogeneous stack (global + rolling + recurrent cycle): rolling
    # layers evict whole pages as tokens leave the window, recurrent
    # states stay dense on the hot path; per-stream ratios are printed
    PYTHONPATH=src python examples/serve_compressed.py --hetero
"""
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

if __name__ == "__main__":
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    argv = sys.argv[1:]
    if "--hetero" in argv:
        argv.remove("--hetero")
        args = ["--arch", "hetero-serve-smoke", "--smoke", "--requests", "8",
                "--prompt-len", "12", "--max-new", "16", "--max-batch", "4",
                "--kv-page-size", "4"]
    else:
        args = ["--arch", "qwen3-1.7b", "--smoke", "--requests", "12",
                "--prompt-len", "16", "--max-new", "12", "--max-batch", "4"]
    if not any(a == "--kv" or a.startswith("--kv=") for a in argv):
        args += ["--kv", "apack-int8"]
        if "--kv-page-size" not in args:
            args += ["--kv-page-size", "8"]
    raise SystemExit(subprocess.run(
        [sys.executable, "-m", "repro.launch.serve"] + args + argv,
        env=env).returncode)

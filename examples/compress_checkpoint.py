"""Lossless APack byteplane compression of a training checkpoint
(beyond-paper: cuts checkpoint storage + restore traffic ~1.2-2x, bit-exact).

    PYTHONPATH=src python examples/compress_checkpoint.py
"""
import tempfile
import time
from pathlib import Path

import jax
import numpy as np

from repro import configs
from repro.ckpt import checkpoint as ckpt
from repro.models import model as M


def main() -> None:
    cfg = configs.get_smoke_config("qwen3-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # make the weights trained-like (small magnitudes, skewed exponents)
    params = jax.tree.map(
        lambda x: (x * 0.02).astype(x.dtype) if x.ndim >= 2 else x, params)
    with tempfile.TemporaryDirectory() as d:
        t0 = time.time()
        ckpt.save(Path(d) / "raw", 1, params, compress=False)
        t_raw = time.time() - t0
        t0 = time.time()
        ckpt.save(Path(d) / "apack", 1, params, compress=True)
        t_comp = time.time() - t0

        def dir_bytes(p):
            return sum(f.stat().st_size for f in Path(p).rglob("*")
                       if f.is_file())

        raw = dir_bytes(Path(d) / "raw")
        comp = dir_bytes(Path(d) / "apack")
        print(f"raw checkpoint:    {raw / 1e6:8.2f} MB ({t_raw:.1f}s)")
        print(f"apack checkpoint:  {comp / 1e6:8.2f} MB ({t_comp:.1f}s) "
              f"-> {raw / comp:.2f}x smaller")
        restored, _, _ = ckpt.restore(Path(d) / "apack")
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a).view(np.uint8),
                                  np.asarray(b).view(np.uint8))
        print("restore: bit-exact OK")


if __name__ == "__main__":
    main()

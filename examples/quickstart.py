"""APack quickstart: tables, compression, kernels, baselines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (baselines, compress, decompress, distributions,
                        table_for)
from repro.kernels import ops


def main() -> None:
    # 1. a paper-like int8 weight tensor (bimodal two's-complement view)
    w = distributions.gaussian_weights(1 << 16, sigma=8.0)
    print(f"tensor: {w.size} uint8 values; "
          f"{np.mean(w <= 16) * 100:.0f}% near 0, "
          f"{np.mean(w >= 240) * 100:.0f}% near 255")

    # 2. profile -> probability-count table (paper Listing 1)
    table = table_for(w, is_activation=False)
    print("table v_min:", table.v_min)
    print("table counts:", tuple(b - a for a, b in zip(table.cum,
                                                       table.cum[1:])))

    # 3. golden-path container compression
    ct = compress(w[:8192], table)
    out = decompress(ct)
    assert np.array_equal(out, w[:8192])
    print(f"golden codec: {ct.ratio():.2f}x (lossless, "
          f"{ct.payload_bits} payload bits)")

    # 4. Pallas kernel path (interpret mode on CPU; bit-identical)
    ca = ops.apack_encode(w, table, backend="pallas_interpret")
    back = ops.apack_decode(ca, backend="pallas_interpret")
    assert np.array_equal(np.asarray(back), w)
    print(f"pallas kernels: roundtrip OK, "
          f"{w.size * 8 / ca.payload_bits:.2f}x payload ratio")

    # 5. versus the paper's baselines
    orig = w.size * 8
    print(f"RLE {orig / baselines.rle_bits(w):.2f}x | "
          f"RLEZ {orig / baselines.rlez_bits(w):.2f}x | "
          f"ShapeShifter {orig / baselines.shapeshifter_bits(w):.2f}x | "
          f"APack {orig / ca.payload_bits:.2f}x")


if __name__ == "__main__":
    main()

"""End-to-end training driver example: train an xLSTM LM with the full
substrate (AdamW, synthetic data, async checkpoints, supervisor restart).

Defaults are CPU-sized (a ~6M-param xlstm); pass ``--full`` to train the
real 125M-parameter xlstm-125m config (slower on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="full 125M config instead of the reduced one")
    ap.add_argument("--ckpt-dir", default="runs/example_train")
    args = ap.parse_args()
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "xlstm-125m",
           "--steps", str(args.steps), "--batch", "8", "--seq", "256",
           "--ckpt-dir", args.ckpt_dir, "--save-every", "50",
           "--compress-ckpt"]
    if not args.full:
        cmd.append("--smoke")
    env = {"PYTHONPATH": str(REPO / "src")}
    import os
    env.update(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    raise SystemExit(subprocess.run(cmd, env=env).returncode)


if __name__ == "__main__":
    main()

"""Pass orchestration: build one SourceTree, run the requested passes,
compare against the committed baseline."""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path

from . import boundary, jit_cache, lifecycle, pallas_lint, phases
from .framework import (DEFAULT_BASELINE, Finding, Reporter, SourceTree,
                        load_baseline)

PASSES = {
    "boundary": boundary.run,
    "lifecycle": lifecycle.run,
    "phase": phases.run,
    "pallas": pallas_lint.run,
    "jit-cache": jit_cache.run,
}

DEFAULT_ROOT = Path(__file__).resolve().parents[1]    # src/repro


@dataclasses.dataclass
class Report:
    findings: list[Finding]
    new: list[Finding]             # not in baseline
    stale: set[str]                # baseline entries no longer firing
    suppressions_used: int
    suppressions_total: int
    pass_seconds: dict[str, float]

    @property
    def ok(self) -> bool:
        return not self.new

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.findings:
            out[f.pass_id] = out.get(f.pass_id, 0) + 1
        return out


def run_passes(root: Path | None = None, *,
               passes: list[str] | None = None,
               baseline: Path | None = None) -> Report:
    tree = SourceTree(root or DEFAULT_ROOT)
    reporter = Reporter(tree)
    timings: dict[str, float] = {}
    for name in (passes or list(PASSES)):
        t0 = time.perf_counter()
        PASSES[name](tree, reporter)
        timings[name] = time.perf_counter() - t0
    reporter.check_suppression_keys()

    base = load_baseline(baseline if baseline is not None
                         else DEFAULT_BASELINE)
    fired = {f.fingerprint for f in reporter.findings}
    new = [f for f in reporter.findings if f.fingerprint not in base]
    supps = [s for m in tree.modules for s in m.suppressions]
    return Report(
        findings=sorted(reporter.findings,
                        key=lambda f: (f.path, f.line, f.code)),
        new=sorted(new, key=lambda f: (f.path, f.line, f.code)),
        stale=base - fired,
        suppressions_used=sum(1 for s in supps if s.used),
        suppressions_total=len(supps),
        pass_seconds=timings,
    )

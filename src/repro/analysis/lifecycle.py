"""Pass ``lifecycle``: page state-machine conformance.

The canonical transition table lives in ``models/modules.py`` as the
pure literal ``PAGE_TRANSITIONS`` (the same dict the runtime guard
``KVPagePool._require_transition`` enforces).  This pass parses that
literal out of the AST and verifies every ``<x>.state[pid] = PAGE_*``
assignment site in the tree:

* ``undeclared-edge``     — the enclosing method is not a declared edge
  and no dominating guard names one;
* ``unguarded-state-write`` — no dominating ``_require_transition`` call
  (or equivalent ``if state[pid] == PAGE_*: raise`` narrowing) precedes
  the write in the same branch;
* ``guard-dst-mismatch``  — the dominating guard validates a different
  destination state than the one assigned;
* ``undeclared-transition`` — the guard-narrowed (src, dst) pairs are
  not a subset of the declared pairs for that edge;
* ``non-symbolic-state``  — the assigned value is not a ``PAGE_*`` name
  (raw ints defeat both the table and the reader);
* ``table-malformed``     — the literal itself references unknown state
  names or is not a pure literal.

"Dominating" is syntactic: the nearest preceding ``_require_transition``
expression-statement in the same statement list, walking outward through
enclosing blocks.  Raise-guard narrowing (``if self.state[pid] ==
PAGE_X: raise``) is honored for hand-rolled guards in fixtures and
third-party pools."""

from __future__ import annotations

import ast

from .framework import Reporter, SourceTree, attr_chain, call_name

PASS_ID = "lifecycle"
TABLE_NAME = "PAGE_TRANSITIONS"
STATE_PREFIX = "PAGE_"


def _load_table(tree: SourceTree, reporter: Reporter):
    """Find the PAGE_TRANSITIONS literal; returns (module, {edge:
    {(src_name, dst_name), ...}}) with symbolic state names."""
    for mod in tree.modules:
        for node in mod.tree.body:
            if not isinstance(node, ast.Assign):
                continue
            if not any(isinstance(t, ast.Name) and t.id == TABLE_NAME
                       for t in node.targets):
                continue
            table = {}
            if not isinstance(node.value, ast.Dict):
                reporter.emit(PASS_ID, "table-malformed", mod, node.lineno,
                              f"{TABLE_NAME} must be a dict literal")
                return mod, {}
            for k, v in zip(node.value.keys, node.value.values):
                if not (isinstance(k, ast.Constant)
                        and isinstance(k.value, str)):
                    reporter.emit(PASS_ID, "table-malformed", mod, k.lineno,
                                  f"{TABLE_NAME} keys must be string edge "
                                  "names")
                    continue
                pairs = set()
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else []
                for pair in elts:
                    names = [n.id for n in getattr(pair, "elts", [])
                             if isinstance(n, ast.Name)]
                    if len(names) != 2 or not all(
                            n.startswith(STATE_PREFIX) for n in names):
                        reporter.emit(
                            PASS_ID, "table-malformed", mod, pair.lineno,
                            f"{TABLE_NAME}[{k.value!r}] entries must be "
                            f"({STATE_PREFIX}*, {STATE_PREFIX}*) pairs")
                        continue
                    pairs.add((names[0], names[1]))
                table[k.value] = pairs
            return mod, table
    return None, {}


def _state_write(node: ast.AST):
    """Match ``<expr>.state[<pid>] = <value>``; returns (target, value)."""
    if not isinstance(node, ast.Assign) or len(node.targets) != 1:
        return None
    t = node.targets[0]
    if isinstance(t, ast.Subscript) and isinstance(t.value, ast.Attribute) \
            and t.value.attr == "state":
        return t, node.value
    return None


def _guard_in(stmts: list, before_line: int):
    """Nearest ``_require_transition(...)`` expression-statement (or
    assignment from one) strictly before ``before_line`` in this list."""
    best = None
    for s in stmts:
        if s.lineno >= before_line:
            break
        call = None
        if isinstance(s, ast.Expr) and isinstance(s.value, ast.Call):
            call = s.value
        elif isinstance(s, ast.Assign) and isinstance(s.value, ast.Call):
            call = s.value
        if call is not None and call_name(call) == "_require_transition":
            best = call
    return best


def _narrowed_sources(fn_node: ast.AST, site: ast.Assign,
                      universe: set[str]) -> set[str] | None:
    """Hand-rolled-guard fallback: walk the function linearly and apply
    ``if <state-expr> == PAGE_X: raise`` / ``!= PAGE_X: raise`` narrowing
    (including through ``st = <x>.state[pid]`` aliases).  Returns the
    possible source-state set at the write, or None if no narrowing
    happened (i.e. genuinely unguarded)."""
    aliases = {"state"}        # names aliasing a state read
    possible = set(universe)
    narrowed = False

    def is_state_read(e: ast.AST) -> bool:
        if isinstance(e, ast.Subscript):
            v = e.value
            return isinstance(v, ast.Attribute) and v.attr == "state"
        if isinstance(e, ast.Call):  # int(self.state[pid])
            return bool(e.args) and is_state_read(e.args[0])
        if isinstance(e, ast.Name):
            return e.id in aliases
        return False

    def state_const(e: ast.AST) -> str | None:
        if isinstance(e, ast.Name) and e.id.startswith(STATE_PREFIX):
            return e.id
        return None

    def scan(stmts: list) -> bool:
        nonlocal possible, narrowed
        for s in stmts:
            if s is site:
                return True
            if isinstance(s, ast.Assign) and is_state_read(s.value):
                for t in s.targets:
                    if isinstance(t, ast.Name):
                        aliases.add(t.id)
            if isinstance(s, ast.If):
                cmp = s.test
                raises = any(isinstance(b, ast.Raise) for b in s.body)
                if isinstance(cmp, ast.Compare) and len(cmp.ops) == 1 \
                        and raises:
                    lhs, rhs = cmp.left, cmp.comparators[0]
                    if is_state_read(rhs):
                        lhs, rhs = rhs, lhs
                    st = state_const(rhs)
                    if is_state_read(lhs) and st is not None:
                        if isinstance(cmp.ops[0], ast.Eq):
                            possible.discard(st)
                            narrowed = True
                        elif isinstance(cmp.ops[0], ast.NotEq):
                            possible &= {st}
                            narrowed = True
                # branch bodies may contain the site: src narrowing from
                # the branch condition itself (st == PAGE_X: ... write)
                if isinstance(cmp, ast.Compare) and len(cmp.ops) == 1 \
                        and isinstance(cmp.ops[0], ast.Eq):
                    lhs, rhs = cmp.left, cmp.comparators[0]
                    if is_state_read(rhs):
                        lhs, rhs = rhs, lhs
                    st = state_const(rhs)
                    if is_state_read(lhs) and st is not None:
                        saved = set(possible)
                        possible &= {st}
                        narrowed = True
                        if scan(s.body):
                            return True
                        possible = saved - {st}
                        if scan(s.orelse):
                            return True
                        continue
                if scan(s.body) or scan(s.orelse):
                    return True
            for attr in ("body", "orelse", "finalbody"):
                if not isinstance(s, ast.If) and hasattr(s, attr):
                    if scan(getattr(s, attr)):
                        return True
        return False

    found = scan(fn_node.body)
    if not found or not narrowed:
        return None
    return possible


def run(tree: SourceTree, reporter: Reporter) -> None:
    table_mod, table = _load_table(tree, reporter)
    if table_mod is None:
        return     # no pool in this tree (e.g. a fixture without one)
    universe = {f"{STATE_PREFIX}FREE", f"{STATE_PREFIX}HOT",
                f"{STATE_PREFIX}COLD", f"{STATE_PREFIX}PACKED"}
    declared = {e for pairs in table.values() for p in pairs for e in p}
    unknown = declared - universe - {f"{STATE_PREFIX}SPILLED"}
    for name in sorted(unknown):
        reporter.emit(PASS_ID, "table-malformed", table_mod, 0,
                      f"{TABLE_NAME} references unknown state {name}")

    for fi in tree.functions:
        for stmt in ast.walk(fi.node):
            m = _state_write(stmt)
            if m is None:
                continue
            _target, value = m
            mod = fi.module

            dst = value.id if isinstance(value, ast.Name) \
                and value.id.startswith(STATE_PREFIX) else None
            if dst is None:
                reporter.emit(PASS_ID, "non-symbolic-state", mod,
                              stmt.lineno,
                              "state write must assign a PAGE_* constant",
                              fn=fi)
                continue

            guard = _find_dominating_guard(fi.node, stmt)
            if guard is not None:
                edge = None
                if len(guard.args) >= 2 and isinstance(
                        guard.args[1], ast.Constant):
                    edge = guard.args[1].value
                gdst = guard.args[2].id if len(guard.args) >= 3 and \
                    isinstance(guard.args[2], ast.Name) else None
                if edge not in table:
                    reporter.emit(PASS_ID, "undeclared-edge", mod,
                                  stmt.lineno,
                                  f"guard names edge {edge!r} which is not "
                                  f"declared in {TABLE_NAME}", fn=fi)
                    continue
                if gdst != dst:
                    reporter.emit(PASS_ID, "guard-dst-mismatch", mod,
                                  stmt.lineno,
                                  f"guard validates {edge!r}->{gdst} but "
                                  f"the site assigns {dst}", fn=fi)
                    continue
                # the runtime guard admits exactly the declared (src, dst)
                # pairs ending at gdst; statically we only need the
                # assigned dst to be a declared destination of this edge
                if not any(d == dst for _, d in table[edge]):
                    reporter.emit(PASS_ID, "undeclared-transition", mod,
                                  stmt.lineno,
                                  f"edge {edge!r} declares destinations "
                                  f"{sorted({d for _, d in table[edge]})} "
                                  f"but the site assigns {dst}", fn=fi)
                continue

            # no _require_transition guard: accept a hand-rolled
            # raise-narrowed guard iff the narrowed transition set is
            # declared under the enclosing method's edge name
            edge = fi.name
            srcs = _narrowed_sources(fi.node, stmt, universe)
            if srcs is None:
                reporter.emit(PASS_ID, "unguarded-state-write", mod,
                              stmt.lineno,
                              f"state write to {dst} has no dominating "
                              "_require_transition or raise-guard", fn=fi)
                continue
            if edge not in table:
                reporter.emit(PASS_ID, "undeclared-edge", mod, stmt.lineno,
                              f"state write in {fi.qualname!r}: "
                              f"{edge!r} is not a declared edge in "
                              f"{TABLE_NAME}", fn=fi)
                continue
            extra = {(s, dst) for s in srcs} - table[edge]
            if extra:
                pretty = sorted(f"{s}->{d}" for s, d in extra)
                reporter.emit(PASS_ID, "undeclared-transition", mod,
                              stmt.lineno,
                              f"guard admits undeclared transition(s) "
                              f"{pretty} for edge {edge!r}", fn=fi)


def _find_dominating_guard(fn_node: ast.AST, site: ast.Assign):
    """Nearest ``_require_transition`` call preceding ``site``, searching
    the innermost statement list containing the site first, then outward."""
    chains: list[list] = []

    def locate(stmts: list, stack: list) -> bool:
        for s in stmts:
            if s is site:
                chains.extend(stack + [stmts])
                return True
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(s, attr, None)
                if sub and locate(sub, stack + [stmts]):
                    return True
        return False

    locate(fn_node.body, [])
    for stmts in reversed(chains):
        g = _guard_in(stmts, site.lineno)
        if g is not None:
            return g
    return None

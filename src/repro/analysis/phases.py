"""Pass ``phase``: async event-loop race checker.

DESIGN.md §9's correctness argument for the async engine is phase
discipline: while a decode step is in flight (the *overlap window*,
everything ``_step_async`` runs before ``self._collect()``), the host
may do table refresh, chunked prefill ingest, and readahead staging —
but must not rebind decode slots or mutate page tables the in-flight
step could be reading.  All slot mutation is *post-collect*.

This pass derives the two phases structurally from ``_step_async``'s
body (no annotation needed — the code is the spec): calls textually
before the ``self._collect()`` statement are overlap-window roots,
calls after it are post-collect.  It then walks the class-local call
graph from the overlap roots and flags:

* ``overlap-slot-write``   — assignment to per-slot binding state
  (``self.active[...]``, ``self.positions``, ``self.last_tokens``,
  ``self._slot_steps``) reachable from the overlap window;
* ``overlap-pool-mutation`` — calls into the page-pool / page-table
  mutating API (the ``PAGE_TRANSITIONS`` edges plus the cache-level
  mutators) reachable from the overlap window;
* ``collect-order``        — ``_step_async`` retires/admits/dispatches
  before collecting (the phases only exist if collect splits them).

Every legitimate overlap-window mutation (staging a *parked* request's
pages, failing a request that holds no slot) carries an
``# apack: allow-phase(<reason>)`` — the reason is the safety argument."""

from __future__ import annotations

import ast

from .framework import (FunctionInfo, Reporter, SourceTree, attr_chain,
                        call_name)

PASS_ID = "phase"

LOOP_METHOD = "_step_async"
COLLECT = "_collect"
# per-slot binding state: writes rebind what the in-flight step decodes
SLOT_ATTRS = {"active", "positions", "last_tokens", "_slot_steps"}
# page-pool / page-table mutators (pool lifecycle edges + cache-level
# wrappers that rewrite page tables the dispatched step may read)
POOL_MUTATORS = {"alloc", "free", "evict", "seal", "pack", "repack",
                 "spill", "adopt", "write_token", "note_device_write",
                 "spill_request", "unspill_request", "release",
                 "add_request", "ingest_prefill_chunk", "finish_prefill",
                 "ingest_prefill", "append_token", "repack_pending",
                 "refresh_step", "restore_state", "write_state_slot"}
# methods that must only run post-collect
POST_COLLECT = {"_retire", "_admit", "_admit_async", "_dispatch",
                "_check_deadlines"}


def _find_loop(tree: SourceTree) -> FunctionInfo | None:
    for fi in tree.functions:
        if fi.name == LOOP_METHOD and fi.cls:
            return fi
    return None


def _stmt_calls(stmt: ast.stmt) -> list[str]:
    out = []
    for node in ast.walk(stmt):
        if isinstance(node, ast.Call):
            n = call_name(node)
            if n:
                out.append(n)
    return out


def run(tree: SourceTree, reporter: Reporter) -> None:
    loop = _find_loop(tree)
    if loop is None:
        return                      # no async engine in this tree
    mod = loop.module

    # ---- split the loop body at the _collect() statement
    overlap_roots: list[str] = []
    post_names: list[tuple[str, int]] = []
    seen_collect = False
    for stmt in loop.node.body:
        calls = _stmt_calls(stmt)
        if COLLECT in calls:
            seen_collect = True
            continue
        for n in calls:
            if not seen_collect:
                overlap_roots.append(n)
            else:
                post_names.append((n, stmt.lineno))
    if not seen_collect:
        reporter.emit(PASS_ID, "collect-order", mod, loop.node.lineno,
                      f"{LOOP_METHOD} never calls {COLLECT}(): the "
                      "overlap/post-collect phase split does not exist",
                      fn=loop)
        return
    del post_names                  # post-collect calls are unrestricted
    # retire/admit/dispatch sneaking into the overlap window is the
    # inverse ordering bug
    for stmt in loop.node.body:
        calls = _stmt_calls(stmt)
        if COLLECT in calls:
            break
        for n in calls:
            if n in POST_COLLECT:
                reporter.emit(PASS_ID, "collect-order", mod, stmt.lineno,
                              f"{n}() runs in the overlap window (before "
                              f"{COLLECT}); slot rebinding must be "
                              "post-collect", fn=loop)

    # ---- class-local reachability from the overlap roots
    cls = loop.cls
    methods = {f.name: f for f in tree.functions
               if f.cls == cls and f.module is mod}
    frontier = [n for n in overlap_roots if n in methods]
    reach: dict[str, FunctionInfo] = {}
    while frontier:
        name = frontier.pop()
        if name in reach:
            continue
        reach[name] = methods[name]
        for node in ast.walk(methods[name].node):
            if isinstance(node, ast.Call):
                n = call_name(node)
                if n in methods and n not in reach:
                    frontier.append(n)

    for name, fi in sorted(reach.items()):
        for node in ast.walk(fi.node):
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    base = t.value if isinstance(t, ast.Subscript) else t
                    chain = attr_chain(base)
                    if chain and chain[0] == "self" and \
                            chain[-1] in SLOT_ATTRS:
                        reporter.emit(
                            PASS_ID, "overlap-slot-write", mod, node.lineno,
                            f"write to self.{'.'.join(chain[1:])} is "
                            "reachable from the overlap window (via "
                            f"{fi.qualname}); slot bindings may only "
                            "change post-collect", fn=fi)
            elif isinstance(node, ast.Call):
                n = call_name(node)
                chain = attr_chain(node.func)
                # only flag mutator calls leaving the engine (self.kv.*,
                # self.kv.pool.*, ...) — engine-local helpers are walked
                if n in POOL_MUTATORS and chain and chain[0] == "self" \
                        and len(chain) > 2:
                    reporter.emit(
                        PASS_ID, "overlap-pool-mutation", mod, node.lineno,
                        f"{'.'.join(chain)}() mutates page tables from "
                        f"the overlap window (via {fi.qualname}); the "
                        "in-flight step may be reading them", fn=fi)

"""Name-resolution call graph over a :class:`~.framework.SourceTree`.

Deliberately conservative (an over-approximation): a call ``self.f(...)``
resolves to the same class's ``f`` when one exists, otherwise — like any
``obj.f(...)`` or bare ``f(...)`` — to *every* function named ``f`` in
the tree (same-module definitions first, but all candidates are linked).
Reachability passes therefore never miss an edge through dynamic
dispatch at the cost of occasionally walking into a same-named stranger;
the passes built on top only flag specific constructs, so extra breadth
costs a suppression, not a false invariant."""

from __future__ import annotations

import ast

from .framework import FunctionInfo, SourceTree, attr_chain


class CallGraph:
    def __init__(self, tree: SourceTree):
        self.tree = tree
        self.edges: dict[str, set[str]] = {}      # qualname@rel -> callees
        self.nodes: dict[str, FunctionInfo] = {}
        for fi in tree.functions:
            self.nodes[self.key(fi)] = fi
        for fi in tree.functions:
            self.edges[self.key(fi)] = {
                self.key(c) for c in self._callees(fi)}

    @staticmethod
    def key(fi: FunctionInfo) -> str:
        return f"{fi.module.rel}::{fi.qualname}"

    def _callees(self, fi: FunctionInfo) -> set[FunctionInfo]:
        out: set[FunctionInfo] = set()
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name):
                out.update(self._resolve(f.id, fi, via_self=False))
            elif isinstance(f, ast.Attribute):
                chain = attr_chain(f)
                via_self = bool(chain) and chain[0] == "self" \
                    and len(chain) == 2
                out.update(self._resolve(f.attr, fi, via_self=via_self))
        # a nested def / lambda body executes (at most) when the enclosing
        # function runs; treat "defines" as an edge so closures passed to
        # jit or map() stay reachable
        for child in ast.iter_child_nodes(fi.node):
            for sub in ast.walk(child):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for cand in self.tree.by_def_name.get(sub.name, []):
                        if cand.module is fi.module and cand.node is sub:
                            out.add(cand)
        return out

    def _resolve(self, name: str, caller: FunctionInfo, *,
                 via_self: bool) -> list[FunctionInfo]:
        cands = self.tree.by_def_name.get(name, [])
        if not cands:
            return []
        if via_self and caller.cls:
            same_cls = [c for c in cands
                        if c.cls == caller.cls and c.module is caller.module]
            if same_cls:
                return same_cls
        return cands

    def reachable(self, roots: list[FunctionInfo]) -> list[FunctionInfo]:
        """BFS closure over the call graph, roots included, stable order."""
        seen: dict[str, FunctionInfo] = {}
        frontier = [self.key(r) for r in roots]
        for k in frontier:
            seen[k] = self.nodes[k]
        while frontier:
            nxt = []
            for k in frontier:
                for callee in sorted(self.edges.get(k, ())):
                    if callee not in seen and callee in self.nodes:
                        seen[callee] = self.nodes[callee]
                        nxt.append(callee)
            frontier = nxt
        return list(seen.values())

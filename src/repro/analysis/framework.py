"""Shared visitor framework: parsed source tree, annotations/suppressions,
structured findings, and the committed-baseline protocol.

Everything here is plain-``ast`` — the analyzer never imports the code it
checks (so it runs in CI before any jax initialization, and a syntax
error in the tree is a finding, not a crash)."""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path

# pass ids, in report order
PASS_IDS = ("boundary", "lifecycle", "phase", "pallas", "jit-cache")

# suppression key (in `# apack: allow-<key>(reason)`) -> pass id
SUPPRESS_KEYS = {
    "transfer": "boundary",
    "transition": "lifecycle",
    "phase": "phase",
    "pallas": "pallas",
    "jit-cache": "jit-cache",
}

# the reason may wrap onto continuation comment lines: capture to the
# closing paren or end of line, whichever comes first
_ALLOW_RE = re.compile(r"#\s*apack:\s*allow-([a-z\-]+)\(([^)]*)(?:\)|$)")
_ROOT_RE = re.compile(r"#\s*apack:\s*hot-path-root(?:\((traced|host)\))?")

DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``fingerprint`` deliberately omits the line number so the committed
    baseline survives unrelated edits above a grandfathered site; the
    enclosing symbol + message pin it tightly enough in practice."""
    pass_id: str
    code: str
    path: str            # tree-relative posix path
    line: int
    symbol: str          # enclosing qualname ("Cls.meth", "fn", "<module>")
    message: str
    severity: str = "error"

    @property
    def fingerprint(self) -> str:
        return "|".join((self.pass_id, self.code, self.path, self.symbol,
                         self.message))

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.pass_id}/{self.code}] "
                f"{self.message}  ({self.symbol})")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Suppression:
    path: str
    line: int
    key: str             # e.g. "transfer"
    reason: str
    used: bool = False


@dataclasses.dataclass
class ModuleInfo:
    path: Path
    rel: str             # tree-relative posix path
    source: str
    lines: list[str]
    tree: ast.Module
    suppressions: list[Suppression]
    root_lines: dict[int, str]     # line -> "traced" | "host"


@dataclasses.dataclass(eq=False)      # identity hash: used in graph sets
class FunctionInfo:
    module: ModuleInfo
    node: ast.FunctionDef | ast.AsyncFunctionDef
    qualname: str
    cls: str | None                # enclosing class name, if a method
    root_kind: str | None = None   # "traced" | "host" | None

    @property
    def name(self) -> str:
        return self.node.name

    @property
    def head_lines(self) -> set[int]:
        """Lines where a function-level suppression/annotation may sit:
        the def line, each decorator line, and the line above the first
        of those."""
        first = min([d.lineno for d in self.node.decorator_list]
                    + [self.node.lineno])
        lines = {self.node.lineno, first, first - 1}
        lines.update(d.lineno for d in self.node.decorator_list)
        return lines


class SourceTree:
    """All ``*.py`` files under a root, parsed once, with per-module
    suppressions/annotations extracted and a flat function index."""

    # the analyzer never analyzes itself: its helper names (`run`, `scan`,
    # `emit`) would cross-link into product code through the conservative
    # name-resolution call graph
    EXCLUDE_DIRS = ("analysis",)

    def __init__(self, root: Path):
        self.root = Path(root).resolve()
        self.modules: list[ModuleInfo] = []
        self.parse_failures: list[Finding] = []
        self.functions: list[FunctionInfo] = []
        self.by_def_name: dict[str, list[FunctionInfo]] = {}
        self.by_qualname: dict[str, list[FunctionInfo]] = {}
        for path in sorted(self.root.rglob("*.py")):
            rel = path.relative_to(self.root).as_posix()
            if any(part in self.EXCLUDE_DIRS
                   for part in Path(rel).parts[:-1]):
                continue
            source = path.read_text()
            try:
                tree = ast.parse(source, filename=str(path))
            except SyntaxError as e:
                self.parse_failures.append(Finding(
                    "framework", "syntax-error", rel, e.lineno or 0,
                    "<module>", f"cannot parse: {e.msg}"))
                continue
            mod = ModuleInfo(path, rel, source, source.splitlines(), tree,
                             _scan_suppressions(rel, source),
                             _scan_roots(source))
            self.modules.append(mod)
            self._index(mod)

    def _index(self, mod: ModuleInfo) -> None:
        def visit(node, prefix: str, cls: str | None):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    fi = FunctionInfo(mod, child, qual, cls)
                    for ln in fi.head_lines:
                        if ln in mod.root_lines:
                            fi.root_kind = mod.root_lines[ln]
                    self.functions.append(fi)
                    self.by_def_name.setdefault(child.name, []).append(fi)
                    self.by_qualname.setdefault(qual, []).append(fi)
                    visit(child, qual + ".", cls)
                elif isinstance(child, ast.ClassDef):
                    visit(child, child.name + ".", child.name)
                else:
                    visit(child, prefix, cls)
        visit(mod.tree, "", None)

    # ------------------------------------------------------------ lookups
    def module(self, rel: str) -> ModuleInfo | None:
        for m in self.modules:
            if m.rel == rel or m.rel.endswith("/" + rel):
                return m
        return None

    def function_at(self, mod: ModuleInfo, line: int) -> FunctionInfo | None:
        """Innermost function containing ``line`` (for symbol attribution)."""
        best = None
        for fi in self.functions:
            if fi.module is not mod:
                continue
            end = getattr(fi.node, "end_lineno", fi.node.lineno)
            if fi.node.lineno <= line <= end:
                if best is None or fi.node.lineno >= best.node.lineno:
                    best = fi
        return best

    def roots(self, kind: str | None = None) -> list[FunctionInfo]:
        return [f for f in self.functions
                if f.root_kind and (kind is None or f.root_kind == kind)]


def _scan_suppressions(rel: str, source: str) -> list[Suppression]:
    out = []
    for i, line in enumerate(source.splitlines(), 1):
        for m in _ALLOW_RE.finditer(line):
            out.append(Suppression(rel, i, m.group(1), m.group(2).strip()))
    return out


def _scan_roots(source: str) -> dict[int, str]:
    out = {}
    for i, line in enumerate(source.splitlines(), 1):
        m = _ROOT_RE.search(line)
        if m:
            out[i] = m.group(1) or "host"
    return out


def _adjacent(mod: ModuleInfo, supp_line: int, target: int) -> bool:
    """A suppression covers ``target`` if it sits on that line, or above
    it separated only by comment lines (so a wrapped reason block stays
    attached to the construct directly below it)."""
    if supp_line == target:
        return True
    if supp_line > target or target - supp_line > 8:
        return False
    for ln in range(supp_line + 1, target):
        if ln - 1 >= len(mod.lines):
            return False
        if not mod.lines[ln - 1].lstrip().startswith("#"):
            return False
    return True


class Reporter:
    """Collects findings, resolving suppressions at emit time.

    A finding at line L of function F is suppressed by an
    ``# apack: allow-<key>(reason)`` whose key maps to the finding's pass
    and whose line is L, L-1, or one of F's head lines (def/decorator
    lines or the line above them — i.e. a def-level suppression covers
    the whole body).  A matching suppression with an empty reason is
    converted into a ``missing-reason`` finding: the reason string is the
    reviewable artifact, not a formality."""

    def __init__(self, tree: SourceTree):
        self.tree = tree
        self.findings: list[Finding] = list(tree.parse_failures)

    def emit(self, pass_id: str, code: str, mod: ModuleInfo, line: int,
             message: str, *, fn: FunctionInfo | None = None,
             severity: str = "error") -> None:
        if fn is None:
            fn = self.tree.function_at(mod, line)
        symbol = fn.qualname if fn else "<module>"
        cand = {line}
        if fn is not None:
            cand |= fn.head_lines
        for s in mod.suppressions:
            if SUPPRESS_KEYS.get(s.key) == pass_id and any(
                    _adjacent(mod, s.line, c) for c in cand):
                s.used = True
                if not s.reason:
                    self.findings.append(Finding(
                        pass_id, "missing-reason", mod.rel, s.line, symbol,
                        f"suppression allow-{s.key} has no reason (was "
                        f"suppressing: {message})"))
                return
        self.findings.append(Finding(pass_id, code, mod.rel, line, symbol,
                                     message, severity))

    def check_suppression_keys(self) -> None:
        """Unknown `allow-*` keys are typos that silently suppress
        nothing — surface them as findings."""
        for mod in self.tree.modules:
            for s in mod.suppressions:
                if s.key not in SUPPRESS_KEYS:
                    self.findings.append(Finding(
                        "framework", "unknown-suppression-key", mod.rel,
                        s.line, "<module>",
                        f"unknown suppression key allow-{s.key} "
                        f"(known: {', '.join(sorted(SUPPRESS_KEYS))})"))


# ---------------------------------------------------------------- baseline
def load_baseline(path: Path) -> set[str]:
    if not Path(path).exists():
        return set()
    data = json.loads(Path(path).read_text())
    return {e["fingerprint"] for e in data.get("findings", [])}


def write_baseline(path: Path, findings: list[Finding]) -> None:
    entries = sorted(
        ({"fingerprint": f.fingerprint, "pass": f.pass_id, "path": f.path,
          "symbol": f.symbol, "message": f.message} for f in findings),
        key=lambda e: e["fingerprint"])
    Path(path).write_text(json.dumps({"findings": entries}, indent=2) + "\n")


# ------------------------------------------------------------ ast helpers
def attr_chain(node: ast.AST) -> list[str] | None:
    """``a.b.c`` -> ["a", "b", "c"]; None if not a pure name/attr chain."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return None


def call_name(call: ast.Call) -> str | None:
    """Terminal name of the callee: ``f(...)`` and ``a.b.f(...)`` -> "f"."""
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def const_int(node: ast.AST) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return node.value
    return None

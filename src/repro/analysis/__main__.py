"""CLI: ``python -m repro.analysis`` — run the invariant passes and exit
non-zero on any finding not in the committed baseline.

    python -m repro.analysis                    # full tree, all passes
    python -m repro.analysis --pass boundary    # one pass
    python -m repro.analysis --changed          # report only files in the
                                                # working diff (analysis is
                                                # still whole-program)
    python -m repro.analysis --json out.json    # machine-readable findings
    python -m repro.analysis --write-baseline   # grandfather current state
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
from pathlib import Path

from .framework import DEFAULT_BASELINE, PASS_IDS, write_baseline
from .runner import DEFAULT_ROOT, run_passes


def _changed_files(repo_root: Path) -> set[str] | None:
    try:
        out = subprocess.run(
            ["git", "diff", "--name-only", "HEAD"], cwd=repo_root,
            capture_output=True, text=True, timeout=30, check=True).stdout
    except (OSError, subprocess.SubprocessError):
        return None
    return {line.strip() for line in out.splitlines() if line.strip()}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="hot-path invariant analyzer (see DESIGN.md §10)")
    ap.add_argument("--root", type=Path, default=DEFAULT_ROOT,
                    help="source tree to analyze (default: src/repro)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASS_IDS, help="run only this pass (repeat ok)")
    ap.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE)
    ap.add_argument("--json", type=Path, nargs="?", const=Path("-"),
                    help="emit findings as JSON (to PATH, or stdout if "
                         "no path given)")
    ap.add_argument("--changed", action="store_true",
                    help="only *report* findings in files changed vs HEAD")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline to the current findings")
    args = ap.parse_args(argv)

    report = run_passes(args.root, passes=args.passes,
                        baseline=args.baseline)

    if args.write_baseline:
        write_baseline(args.baseline, report.findings)
        print(f"baseline: wrote {len(report.findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    shown = report.new
    if args.changed:
        changed = _changed_files(args.root.resolve().parents[1]
                                 if args.root == DEFAULT_ROOT
                                 else Path.cwd())
        if changed is not None:
            rels = {c.split("src/", 1)[-1].removeprefix("repro/")
                    for c in changed}
            shown = [f for f in shown
                     if f.path in rels or any(c.endswith(f.path)
                                              for c in changed)]

    counts = report.counts()
    json_to_stdout = args.json is not None and str(args.json) == "-"
    if not json_to_stdout:
        for f in shown:
            print(f.render())
        per_pass = ", ".join(f"{p}={counts.get(p, 0)}" for p in PASS_IDS)
        print(f"analysis: {len(report.findings)} finding(s) [{per_pass}], "
              f"{len(report.new)} new vs baseline, "
              f"{report.suppressions_used}/{report.suppressions_total} "
              "suppressions used")
        if report.stale:
            print(f"analysis: {len(report.stale)} baseline entr"
                  f"{'y is' if len(report.stale) == 1 else 'ies are'} stale "
                  "(fixed findings) — run --write-baseline to shrink it")

    if args.json is not None:
        payload = json.dumps({
            "findings": [f.to_json() for f in report.findings],
            "new": [f.to_json() for f in report.new],
            "counts": counts,
            "stale_baseline": sorted(report.stale),
            "suppressions": {"used": report.suppressions_used,
                             "total": report.suppressions_total},
            "pass_seconds": report.pass_seconds,
        }, indent=2) + "\n"
        if json_to_stdout:
            sys.stdout.write(payload)
        else:
            args.json.write_text(payload)

    if shown or (not args.changed and report.new):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

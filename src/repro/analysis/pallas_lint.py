"""Pass ``pallas``: kernel hygiene for the Pallas TPU paths.

Interpret-mode CI (see ROADMAP "compiled-mode validation") hides a class
of bugs Mosaic would reject or — worse — miscompile: BlockSpec
``index_map`` arity drifting from the grid rank, kernel signatures out
of sync with spec/scratch lists, scalar-prefetch operands dropped, and
unguarded output writes on revisited blocks (the output-block revisit
caveat: a multi-pass grid must ``pl.when`` its writes or the revisit
clobbers the accumulator).  These are shape-of-the-code facts, so they
lint statically:

* ``index-map-arity``     — a ``pl.BlockSpec`` index_map lambda whose
  arity != grid rank + num_scalar_prefetch;
* ``kernel-arity``        — kernel positional params != prefetch +
  len(in_specs) + n_outputs + len(scratch_shapes);
* ``operand-count``       — the ``pallas_call(...)`` invocation passes a
  different number of operands than prefetch + len(in_specs) (scalar
  prefetch operands come *first* — a count mismatch is the usual
  symptom of misordering them);
* ``scratch-shape``       — a ``scratch_shapes`` entry that is not a
  ``pltpu.VMEM(...)`` / ``pltpu.SMEM(...)`` constructor;
* ``unguarded-output-write`` — a store to an output ref in a kernel
  whose grid has rank >= 2, not nested under a ``pl.when`` block;
* ``mesh-op-in-kernel``   — a ``jax.lax`` mesh collective
  (``axis_index``/``psum``/``all_gather``/...) inside a kernel body:
  under the mesh-sharded serving step the kernels launch inside a
  ``shard_map`` body with *per-shard* grids and block shapes, and mesh
  collectives belong in that body around the ``pallas_call`` — Mosaic
  has no lowering for them inside kernel code.

Mesh-partitioned grids need no special casing beyond that: every count
this pass checks (spec list lengths, index_map arity, kernel signature,
operand order) is shard-invariant — only the grid *sizes* shrink per
shard, and those are skipped when non-literal anyway.  Anything else the
linter cannot resolve statically (non-literal grids, specs built in
loops) is skipped silently — this pass is a tripwire for the real
kernels, not a Mosaic reimplementation."""

from __future__ import annotations

import ast

from .framework import (FunctionInfo, ModuleInfo, Reporter, SourceTree,
                        attr_chain, call_name, const_int)

PASS_ID = "pallas"


def _is_pallas_module(mod: ModuleInfo) -> bool:
    return "pallas" in mod.source and (
        "pl.pallas_call" in mod.source or "pallas_call" in mod.source)


# jax.lax mesh collectives that must not appear inside kernel bodies
_MESH_OPS = {"axis_index", "axis_size", "psum", "pmean", "pmax", "pmin",
             "all_gather", "all_to_all", "ppermute", "pshuffle"}


def run(tree: SourceTree, reporter: Reporter) -> None:
    for mod in tree.modules:
        if not _is_pallas_module(mod):
            continue
        kernels: dict[int, FunctionInfo] = {}
        for fi in tree.functions:
            if fi.module is not mod:
                continue
            _check_host_fn(fi, tree, reporter)
            env = _local_assignments(fi.node)
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Call) \
                        and call_name(node) == "pallas_call":
                    k = _kernel_def(node, env, tree, fi)
                    if k is not None:
                        kernels[id(k)] = k
        for k in kernels.values():
            _check_mesh_ops(k, reporter)


def _check_mesh_ops(kernel: FunctionInfo, reporter: Reporter) -> None:
    """Mesh collectives inside a kernel body: Mosaic has no lowering for
    ``jax.lax`` collectives, and under the mesh-sharded serving step the
    kernel's grid/blocks are already shard-local — the collective belongs
    in the surrounding ``shard_map`` body."""
    for node in ast.walk(kernel.node):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        chain = attr_chain(node.func)
        if name in _MESH_OPS and chain and chain[0] in ("lax", "jax"):
            reporter.emit(
                PASS_ID, "mesh-op-in-kernel", kernel.module, node.lineno,
                f"mesh collective {name} inside Pallas kernel "
                f"{kernel.qualname}: collectives belong in the shard_map "
                "body around the pallas_call (the kernel's grid and blocks "
                "are shard-local; Mosaic cannot lower jax.lax collectives)",
                fn=kernel)


def _local_assignments(fn: ast.AST) -> dict[str, ast.AST]:
    out = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            out[node.targets[0].id] = node.value
    return out


def _resolve(expr: ast.AST, env: dict[str, ast.AST], depth=0) -> ast.AST:
    while isinstance(expr, ast.Name) and expr.id in env and depth < 8:
        expr = env[expr.id]
        depth += 1
    return expr


def _seq_len(expr: ast.AST, env: dict[str, ast.AST]) -> int | None:
    expr = _resolve(expr, env)
    if isinstance(expr, (ast.Tuple, ast.List)):
        return len(expr.elts)
    return None


def _check_host_fn(fi: FunctionInfo, tree: SourceTree,
                   reporter: Reporter) -> None:
    env = _local_assignments(fi.node)
    mod = fi.module
    for node in ast.walk(fi.node):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name == "pallas_call":
            _check_pallas_call(node, fi, env, tree, reporter)
        elif name == "PrefetchScalarGridSpec":
            pass    # handled from the enclosing pallas_call
        elif name == "BlockSpec":
            pass    # handled with grid context below
    # arity of every BlockSpec lambda in this function against the
    # function's (single) grid configuration, if determinable
    ctx = _grid_context(fi.node, env)
    if ctx is None:
        return
    rank, prefetch = ctx
    for spec_call, lam in _block_spec_lambdas(fi, tree):
        arity = len(lam.args.posonlyargs) + len(lam.args.args)
        if lam.args.vararg is not None:
            continue
        if arity != rank + prefetch:
            reporter.emit(
                PASS_ID, "index-map-arity", spec_call_mod(spec_call, fi),
                lam.lineno,
                f"index_map takes {arity} args but grid rank {rank} + "
                f"{prefetch} scalar-prefetch operands requires "
                f"{rank + prefetch}", fn=fi)


def spec_call_mod(spec_call, fi):
    return fi.module


def _grid_context(fn: ast.AST, env: dict[str, ast.AST]):
    """(grid_rank, num_scalar_prefetch) for the pallas_call(s) in this
    function, or None if absent/ambiguous."""
    found = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node)
        if name == "pallas_call":
            rank, prefetch = _call_grid(node, env)
            if rank is not None:
                found.append((rank, prefetch))
    if len(set(found)) == 1:
        return found[0]
    return None


def _call_grid(call: ast.Call, env: dict[str, ast.AST]):
    """Resolve (grid_rank, prefetch) of one pallas_call: either a direct
    ``grid=`` kwarg (prefetch 0) or a ``grid_spec=PrefetchScalarGridSpec``."""
    for kw in call.keywords:
        if kw.arg == "grid":
            rank = _seq_len(kw.value, env)
            return rank, 0
        if kw.arg == "grid_spec":
            spec = _resolve(kw.value, env)
            if isinstance(spec, ast.Call) and \
                    call_name(spec) == "PrefetchScalarGridSpec":
                rank = prefetch = None
                for skw in spec.keywords:
                    if skw.arg == "grid":
                        rank = _seq_len(skw.value, env)
                    if skw.arg == "num_scalar_prefetch":
                        prefetch = const_int(skw.value)
                return rank, (prefetch or 0)
    return None, 0


def _block_spec_lambdas(fi: FunctionInfo, tree: SourceTree):
    """Every ``pl.BlockSpec(..., lambda...)`` built in this function or in
    a helper defined in the same module and called from here."""
    fns = [fi.node]
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Call):
            n = call_name(node)
            for cand in tree.by_def_name.get(n or "", []):
                if cand.module is fi.module and cand.node not in fns \
                        and cand.cls is None:
                    fns.append(cand.node)
    seen: set[int] = set()
    for fn in fns:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and call_name(node) == "BlockSpec":
                args = list(node.args) + [kw.value for kw in node.keywords]
                for a in args:
                    if isinstance(a, ast.Lambda) and id(a) not in seen:
                        seen.add(id(a))
                        yield node, a


def _check_pallas_call(call: ast.Call, fi: FunctionInfo,
                       env: dict[str, ast.AST], tree: SourceTree,
                       reporter: Reporter) -> None:
    mod = fi.module
    rank, prefetch = _call_grid(call, env)

    # ---- spec/out/scratch counts
    n_in = n_out = n_scratch = None
    spec_src = call          # keywords live on pallas_call or the grid_spec
    for kw in call.keywords:
        if kw.arg == "grid_spec":
            g = _resolve(kw.value, env)
            if isinstance(g, ast.Call):
                spec_src = g
    for src in (call, spec_src):
        for kw in src.keywords:
            if kw.arg == "in_specs":
                n_in = _seq_len(kw.value, env)
            elif kw.arg == "out_specs":
                v = _resolve(kw.value, env)
                n_out = len(v.elts) if isinstance(
                    v, (ast.Tuple, ast.List)) else 1
            elif kw.arg == "out_shape":
                v = _resolve(kw.value, env)
                if n_out is None:
                    n_out = len(v.elts) if isinstance(
                        v, (ast.Tuple, ast.List)) else 1
            elif kw.arg == "scratch_shapes":
                v = _resolve(kw.value, env)
                if isinstance(v, (ast.Tuple, ast.List)):
                    n_scratch = len(v.elts)
                    for s in v.elts:
                        sname = call_name(s) if isinstance(s, ast.Call) \
                            else None
                        if sname not in ("VMEM", "SMEM", "SemaphoreType"):
                            reporter.emit(
                                PASS_ID, "scratch-shape", mod, s.lineno,
                                "scratch_shapes entries must be "
                                "pltpu.VMEM/pltpu.SMEM constructors",
                                fn=fi)
    if n_scratch is None:
        n_scratch = 0

    # ---- kernel signature arity
    kernel = _kernel_def(call, env, tree, fi)
    if kernel is not None and None not in (n_in, n_out):
        bound = kernel_bound_args(call, env)
        a = kernel.node.args
        n_params = len(a.posonlyargs) + len(a.args) - bound
        expected = prefetch + n_in + n_out + n_scratch
        if a.vararg is None and n_params != expected:
            reporter.emit(
                PASS_ID, "kernel-arity", mod, call.lineno,
                f"kernel {kernel.qualname} takes {n_params} refs but "
                f"{prefetch} prefetch + {n_in} inputs + {n_out} outputs "
                f"+ {n_scratch} scratch = {expected}", fn=fi)

        # ---- unguarded output writes on revisiting grids
        if rank is not None and rank >= 2:
            out_params = (a.posonlyargs + a.args)[
                bound + prefetch + n_in: bound + prefetch + n_in + n_out]
            out_names = {p.arg for p in out_params}
            _check_guarded_writes(kernel, out_names, reporter)

    # ---- operand count at the invocation site
    if n_in is not None:
        parent = _invocation(call, fi.node)
        if parent is not None and not any(
                isinstance(x, ast.Starred) for x in parent.args):
            got = len(parent.args)
            expected = prefetch + n_in
            if got != expected:
                reporter.emit(
                    PASS_ID, "operand-count", mod, parent.lineno,
                    f"pallas_call invoked with {got} operands but "
                    f"{prefetch} scalar-prefetch + {n_in} inputs = "
                    f"{expected} (prefetch operands come first)", fn=fi)


def _invocation(call: ast.Call, fn: ast.AST) -> ast.Call | None:
    """The ``pl.pallas_call(...)(*operands)`` outer call, if immediate."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and node.func is call:
            return node
    # `f = pl.pallas_call(...); ...; f(*operands)`
    bound = None
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and node.value is call and \
                len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            bound = node.targets[0].id
    if bound is not None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id == bound:
                return node
    return None


def kernel_bound_args(call: ast.Call, env: dict[str, ast.AST]) -> int:
    """Positional args pre-bound by functools.partial on the kernel."""
    k = _kernel_expr(call, env)
    if isinstance(k, ast.Call) and call_name(k) == "partial":
        return max(0, len(k.args) - 1)
    return 0


def _kernel_expr(call: ast.Call, env: dict[str, ast.AST]) -> ast.AST | None:
    if call.args:
        return _resolve(call.args[0], env)
    for kw in call.keywords:
        if kw.arg in ("kernel", "f"):
            return _resolve(kw.value, env)
    return None


def _kernel_def(call: ast.Call, env: dict[str, ast.AST], tree: SourceTree,
                fi: FunctionInfo) -> FunctionInfo | None:
    k = _kernel_expr(call, env)
    if isinstance(k, ast.Call) and call_name(k) == "partial" and k.args:
        k = _resolve(k.args[0], env)
    name = None
    if isinstance(k, ast.Name):
        name = k.id
    elif isinstance(k, ast.Attribute):
        name = k.attr
    if name is None:
        return None
    # same-module resolution only: kernels named `_fused_kernel` exist in
    # several modules and cross-linking them would mix signatures
    for cand in tree.by_def_name.get(name, []):
        if cand.module is fi.module:
            return cand
    return None


def _check_guarded_writes(kernel: FunctionInfo, out_names: set[str],
                          reporter: Reporter) -> None:
    """Stores to output refs must sit under a ``pl.when``-decorated nested
    def when the grid revisits blocks (rank >= 2)."""
    guarded: set[int] = set()
    for node in ast.walk(kernel.node):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                base = dec.func if isinstance(dec, ast.Call) else dec
                chain = attr_chain(base)
                if chain and chain[-1] == "when":
                    for sub in ast.walk(node):
                        guarded.add(id(sub))
    for node in ast.walk(kernel.node):
        if isinstance(node, (ast.Assign, ast.AugAssign)) \
                and id(node) not in guarded:
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.value, ast.Name) and \
                        t.value.id in out_names:
                    reporter.emit(
                        PASS_ID, "unguarded-output-write", kernel.module,
                        node.lineno,
                        f"write to output ref {t.value.id!r} outside "
                        "pl.when on a rank>=2 grid: block revisits will "
                        "clobber it", fn=kernel)

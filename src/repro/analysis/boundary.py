"""Pass ``boundary``: host-sync constructs reachable from hot-path roots.

The invariant (DESIGN.md §5, gated dynamically by the decode/serving
benches): the steady-state decode loop makes **zero** ``device_get``
calls and never forces a device value to host mid-step.  This pass makes
the same invariant fail at the diff.  From every function annotated
``# apack: hot-path-root`` (host roots like ``ServeEngine.step``) or
``# apack: hot-path-root(traced)`` (jit-traced roots like
``decode_step_paged``), it walks the call graph and flags:

* ``device-get``          — any ``jax.device_get(...)`` call;
* ``block-until-ready``   — any ``.block_until_ready()`` call;
* ``host-materialize``    — ``np.asarray`` / ``np.array`` of a *device-
  tainted* expression (host numpy on host values is fine);
* ``scalar-coerce``       — ``int()`` / ``float()`` / ``bool()`` of a
  device-tainted expression (each is an implicit blocking d2h);
* ``item-call``           — ``.item()`` on a device-tainted expression.

Taint is per-function and syntactic: expressions rooted at ``jnp`` /
``jax`` / ``lax``, calls to jit-wrapped attributes (``self._x`` where
some method assigns ``self._x = jax.jit(...)``), and — inside the traced
subtree — every parameter.  ``np.asarray``, ``jax.device_get`` and the
accounted ``_fetch`` wrapper launder taint (their *argument* is where
the flag lands, their result is host).  ``.shape``/``.dtype`` metadata
of a tainted value is static, not tainted — trace-time planning code
stays clean."""

from __future__ import annotations

import ast

from .callgraph import CallGraph
from .framework import (FunctionInfo, Reporter, SourceTree, attr_chain,
                        call_name)

PASS_ID = "boundary"

# attribute-chain roots whose expressions live on device
_DEVICE_ROOTS = {"jnp", "lax"}
# terminal callee names that return *host* data (taint laundering); the
# construct itself is flagged separately where that matters
_UNTAINT_CALLS = {"device_get", "asarray", "array", "_fetch", "int",
                  "float", "bool", "len", "item", "tolist"}
_METADATA_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}


def run(tree: SourceTree, reporter: Reporter) -> None:
    graph = CallGraph(tree)
    host_roots = tree.roots("host")
    traced_roots = tree.roots("traced")
    # shard_map bodies are traced per shard even when no annotation marks
    # them: seed them from the call sites so reachability (and per-param
    # taint) crosses the shard_map boundary like any jit trace
    seen = {id(f) for f in traced_roots}
    for f in _shard_map_bodies(tree):
        if id(f) not in seen:
            seen.add(id(f))
            traced_roots.append(f)
    jit_attrs = _collect_jit_attrs(tree)
    jit_defs = _collect_jit_defs(tree)

    traced = {CallGraph.key(f) for f in graph.reachable(traced_roots)}
    for fi in graph.reachable(host_roots + traced_roots):
        _check_function(fi, reporter, jit_attrs, jit_defs,
                        traced=CallGraph.key(fi) in traced)


def _shard_map_bodies(tree: SourceTree) -> list[FunctionInfo]:
    """Functions passed by name as a ``shard_map(fn, ...)`` body.  Their
    parameters are per-shard device operands — exactly the traced-root
    contract — so the boundary walk must treat them as roots even though
    nothing annotates the (library-supplied) tracing entry point."""
    out: list[FunctionInfo] = []
    for mod in tree.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) \
                    or call_name(node) != "shard_map" or not node.args:
                continue
            fn = node.args[0]
            if not isinstance(fn, ast.Name):
                continue
            for cand in tree.by_def_name.get(fn.id, []):
                if cand.module is mod:
                    out.append(cand)
    return out


def _collect_jit_attrs(tree: SourceTree) -> set[str]:
    """Attribute names assigned from ``jax.jit(...)`` anywhere — calls
    through them return device arrays (``self._decode_paged`` etc.)."""
    out = set()
    for mod in tree.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and _is_jit_call(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Attribute):
                        out.add(t.attr)
    return out


def _collect_jit_defs(tree: SourceTree) -> set[str]:
    """Names of functions decorated with ``jax.jit`` (direct or via
    ``functools.partial(jax.jit, ...)``)."""
    out = set()
    for fi in tree.functions:
        for dec in fi.node.decorator_list:
            chain = attr_chain(dec if not isinstance(dec, ast.Call)
                               else dec.func)
            if chain and "jit" in chain:
                out.add(fi.name)
            if isinstance(dec, ast.Call):
                for arg in dec.args:
                    c = attr_chain(arg)
                    if c and c[-1] == "jit":
                        out.add(fi.name)
    return out


def _is_jit_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    chain = attr_chain(node.func)
    if chain and chain[-1] in ("jit", "pallas_call"):
        return True
    # functools.partial(jax.jit, ...) / jax.jit(fn, static_argnames=...)
    for arg in node.args:
        c = attr_chain(arg)
        if c and c[-1] == "jit":
            return True
    return False


def _static_params(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> set[str]:
    """Params named in a jit decorator's ``static_argnames`` are host
    values at trace time — coercing them is free, not a d2h sync."""
    out: set[str] = set()
    for dec in fn.decorator_list:
        for node in ast.walk(dec):
            if isinstance(node, ast.keyword) and \
                    node.arg == "static_argnames":
                v = node.value
                elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) \
                    else [v]
                out.update(e.value for e in elts
                           if isinstance(e, ast.Constant)
                           and isinstance(e.value, str))
    return out


def _static_annotation(p: ast.arg) -> bool:
    """Config/scalar-annotated params (``cfg: ModelConfig``, ``bits:
    int``) are trace-time constants, not device operands."""
    ann = p.annotation
    name = None
    if isinstance(ann, ast.Name):
        name = ann.id
    elif isinstance(ann, ast.Attribute):
        name = ann.attr
    if name is None:
        return False
    return name in ("int", "float", "bool", "str") or name.endswith("Config")


class _FnChecker:
    def __init__(self, fi: FunctionInfo, reporter: Reporter,
                 jit_attrs: set[str], jit_defs: set[str], traced: bool):
        self.fi = fi
        self.reporter = reporter
        self.jit_attrs = jit_attrs
        self.jit_defs = jit_defs
        self.traced = traced
        self.tainted: set[str] = set()
        if traced:
            static = _static_params(fi.node)
            a = fi.node.args
            for p in (a.posonlyargs + a.args + a.kwonlyargs):
                if p.arg not in static and not _static_annotation(p):
                    self.tainted.add(p.arg)
            if a.vararg:
                self.tainted.add(a.vararg.arg)
            if a.kwarg:
                self.tainted.add(a.kwarg.arg)

    # -------------------------------------------------------------- taint
    def taints(self, e: ast.AST) -> bool:
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in _METADATA_ATTRS:
                return False
            return self.taints(e.value)
        if isinstance(e, ast.Subscript):
            return self.taints(e.value)
        if isinstance(e, ast.Call):
            name = call_name(e)
            chain = attr_chain(e.func)
            root = chain[0] if chain else None
            if root in _DEVICE_ROOTS:
                return True
            if root == "jax" and name != "device_get":
                return True
            if name in self.jit_attrs or name in self.jit_defs:
                return True
            if name in _UNTAINT_CALLS:
                return False
            # unknown call: conservatively forwards its arguments' taint
            return any(self.taints(a) for a in e.args) or \
                any(self.taints(kw.value) for kw in e.keywords)
        if isinstance(e, (ast.BinOp,)):
            return self.taints(e.left) or self.taints(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.taints(e.operand)
        if isinstance(e, ast.BoolOp):
            return any(self.taints(v) for v in e.values)
        if isinstance(e, ast.Compare):
            return self.taints(e.left) or \
                any(self.taints(c) for c in e.comparators)
        if isinstance(e, ast.IfExp):
            return self.taints(e.body) or self.taints(e.orelse)
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            return any(self.taints(x) for x in e.elts)
        if isinstance(e, ast.Starred):
            return self.taints(e.value)
        return False

    def _mark(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            (self.tainted.add if tainted
             else self.tainted.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self._mark(t, tainted)
        elif isinstance(target, ast.Starred):
            self._mark(target.value, tainted)

    def _propagate(self, node: ast.AST) -> None:
        if isinstance(node, ast.Assign):
            t = self.taints(node.value)
            for tgt in node.targets:
                self._mark(tgt, t)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            self._mark(node.target, self.taints(node.value))
        elif isinstance(node, ast.AugAssign):
            if self.taints(node.value):
                self._mark(node.target, True)
        elif isinstance(node, ast.For):
            self._mark(node.target, self.taints(node.iter))
        elif isinstance(node, (ast.withitem,)) and node.optional_vars:
            self._mark(node.optional_vars, self.taints(node.context_expr))
        elif isinstance(node, ast.comprehension):
            self._mark(node.target, self.taints(node.iter))

    # --------------------------------------------------------------- scan
    def run(self) -> None:
        body = list(ast.walk(self.fi.node))
        # two passes: taint only grows, so a second sweep fixes ordering
        # artifacts from loops and forward references
        for node in body:
            self._propagate(node)
        for node in body:
            self._propagate(node)
            if isinstance(node, ast.Call):
                self._flag_call(node)

    def _emit(self, code: str, node: ast.AST, msg: str) -> None:
        self.reporter.emit(PASS_ID, code, self.fi.module,
                           node.lineno, msg, fn=self.fi)

    def _flag_call(self, call: ast.Call) -> None:
        name = call_name(call)
        chain = attr_chain(call.func)
        where = "traced hot path" if self.traced else "hot path"
        if chain and chain[0] == "jax" and name == "device_get":
            self._emit("device-get", call,
                       f"jax.device_get on the {where}: blocking d2h "
                       "transfer in steady state")
            return
        if isinstance(call.func, ast.Attribute) and \
                call.func.attr == "block_until_ready":
            self._emit("block-until-ready", call,
                       f".block_until_ready() on the {where}: host "
                       "blocks on device completion")
            return
        if chain and chain[0] == "np" and name in ("asarray", "array"):
            args = list(call.args) + [kw.value for kw in call.keywords]
            if any(self.taints(a) for a in args):
                self._emit("host-materialize", call,
                           f"np.{name} of a device value on the {where}: "
                           "implicit blocking d2h transfer")
            return
        if isinstance(call.func, ast.Name) and name in ("int", "float",
                                                        "bool"):
            if call.args and self.taints(call.args[0]):
                self._emit("scalar-coerce", call,
                           f"{name}() of a device value on the {where}: "
                           "implicit blocking d2h sync")
            return
        if isinstance(call.func, ast.Attribute) and name == "item" \
                and not call.args:
            if self.taints(call.func.value):
                self._emit("item-call", call,
                           f".item() on a device value on the {where}: "
                           "implicit blocking d2h sync")


def _check_function(fi: FunctionInfo, reporter: Reporter,
                    jit_attrs: set[str], jit_defs: set[str],
                    traced: bool) -> None:
    _FnChecker(fi, reporter, jit_attrs, jit_defs, traced).run()

"""Hot-path invariant analyzer: static AST + call-graph passes.

The serving engine's load-bearing invariants — zero steady-state
``device_get`` on the fused decode loop, the declared page-lifecycle
state machine, post-collect-only slot mutation in the async scheduler,
Pallas grid/BlockSpec/scratch consistency, and bucketed jit-cache keys —
are enforced dynamically by tests and bench gates, which catch a
violating edit hours after it lands.  These passes catch it at the diff:
``python -m repro.analysis`` runs all five against ``src/repro`` and
fails on any finding not in the committed baseline.

Passes (ids used in findings, suppressions, and ``--pass``):

* ``boundary``  — host-sync constructs reachable from annotated
  hot-path roots (``# apack: hot-path-root``), see :mod:`.boundary`;
* ``lifecycle`` — ``self.state[pid] = PAGE_*`` sites vs the canonical
  ``PAGE_TRANSITIONS`` table in ``models/modules.py``, see
  :mod:`.lifecycle`;
* ``phase``     — slot-binding / page-table mutations reachable from the
  async engine's overlap window, see :mod:`.phases`;
* ``pallas``    — BlockSpec index_map arity, operand counts, scratch
  shapes, ``pl.when``-guarded output writes, see :mod:`.pallas_lint`;
* ``jit-cache`` — unbucketed shape-derived cache keys and float /
  unhashable static args, see :mod:`.jit_cache`.

Suppression grammar (one per line, trailing or the line above; a
suppression on the ``def`` line covers the whole function):

    # apack: allow-transfer(<reason>)      boundary
    # apack: allow-transition(<reason>)    lifecycle
    # apack: allow-phase(<reason>)         phase
    # apack: allow-pallas(<reason>)        pallas
    # apack: allow-jit-cache(<reason>)     jit-cache

A suppression with an empty reason is itself a finding.  See
DESIGN.md §10 for the full grammar and the baseline workflow.
"""

from .framework import (Finding, SourceTree, Reporter, load_baseline,
                        write_baseline, DEFAULT_BASELINE, PASS_IDS)
from .runner import run_passes

__all__ = ["Finding", "SourceTree", "Reporter", "load_baseline",
           "write_baseline", "DEFAULT_BASELINE", "PASS_IDS", "run_passes"]

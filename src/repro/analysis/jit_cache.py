"""Pass ``jit-cache``: compile-cache hygiene.

PR 7's recompile-storm guard bucketed prefill lengths to powers of two
and capped the gather grid to ``GATHER_BUCKETS``; this pass keeps the
discipline from eroding:

* ``unbucketed-cache-key`` — a ``*_cache[key] = ...`` store (or
  ``setdefault``) whose key derives from a raw length/shape
  (``len(...)``, ``.shape``) without flowing through a ``*bucket*``
  function: every distinct request length would mint a fresh jit
  compilation;
* ``float-static-arg``     — a ``static_argnames`` entry whose
  parameter is float-typed (annotation or default): floats hash by
  value, so every new value recompiles — thread it as a traced operand
  or quantize it into the config;
* ``unhashable-static-arg`` — a ``static_argnames`` entry whose
  parameter defaults to / is annotated as a list, dict or set (jit
  raises at call time, but only on the path that passes it)."""

from __future__ import annotations

import ast

from .framework import Reporter, SourceTree, attr_chain, call_name

PASS_ID = "jit-cache"

_UNHASHABLE = {"list", "dict", "set", "List", "Dict", "Set"}


def run(tree: SourceTree, reporter: Reporter) -> None:
    for fi in tree.functions:
        _check_static_args(fi, reporter)
        _check_cache_keys(fi, reporter)
    for mod in tree.modules:
        _check_module_jits(mod, tree, reporter)


# ------------------------------------------------------- static_argnames
def _static_names(call: ast.Call) -> list[str]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            v = kw.value
            if isinstance(v, (ast.Tuple, ast.List)):
                return [e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)]
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return [v.value]
    return []


def _jit_call(node: ast.AST) -> ast.Call | None:
    """Match ``jax.jit(...)``, ``functools.partial(jax.jit, ...)``."""
    if not isinstance(node, ast.Call):
        return None
    chain = attr_chain(node.func)
    if chain and chain[-1] == "jit":
        return node
    if call_name(node) == "partial" and node.args:
        c = attr_chain(node.args[0])
        if c and c[-1] == "jit":
            return node
    return None


def _check_static_args(fi, reporter: Reporter) -> None:
    names: list[str] = []
    line = fi.node.lineno
    for dec in fi.node.decorator_list:
        jc = _jit_call(dec)
        if jc is not None:
            names += _static_names(jc)
            line = dec.lineno
    if not names:
        return
    params = {}
    a = fi.node.args
    all_args = a.posonlyargs + a.args + a.kwonlyargs
    defaults = dict(zip([p.arg for p in reversed(a.args)],
                        list(reversed(a.defaults))))
    defaults.update(zip([p.arg for p in a.kwonlyargs], a.kw_defaults))
    for p in all_args:
        params[p.arg] = p
    for name in names:
        p = params.get(name)
        if p is None:
            continue
        ann = p.annotation
        ann_name = None
        if isinstance(ann, ast.Name):
            ann_name = ann.id
        elif isinstance(ann, ast.Subscript) and isinstance(ann.value,
                                                           ast.Name):
            ann_name = ann.value.id
        d = defaults.get(name)
        if ann_name == "float" or (isinstance(d, ast.Constant)
                                   and isinstance(d.value, float)):
            reporter.emit(
                PASS_ID, "float-static-arg", fi.module, line,
                f"static arg {name!r} of {fi.qualname} is float-typed: "
                "every distinct value mints a fresh compilation", fn=fi)
        if ann_name in _UNHASHABLE or isinstance(d, (ast.List, ast.Dict,
                                                     ast.Set)):
            reporter.emit(
                PASS_ID, "unhashable-static-arg", fi.module, line,
                f"static arg {name!r} of {fi.qualname} is unhashable: "
                "jit will raise at call time", fn=fi)


def _check_module_jits(mod, tree: SourceTree, reporter: Reporter) -> None:
    """``self._f = jax.jit(g, static_argnames=...)`` wrapping a resolvable
    function: apply the same static-arg checks to g's signature."""
    for node in ast.walk(mod.tree):
        jc = _jit_call(node)
        if jc is None or not jc.args:
            continue
        target = jc.args[-1] if call_name(jc) == "partial" else jc.args[0]
        names = _static_names(jc)
        if not names or not isinstance(target, ast.Name):
            continue
        for cand in tree.by_def_name.get(target.id, []):
            if cand.module is mod:
                _check_static_args_of(cand, names, jc.lineno, reporter)


def _check_static_args_of(fi, names, line, reporter):
    a = fi.node.args
    params = {p.arg: p for p in a.posonlyargs + a.args + a.kwonlyargs}
    defaults = dict(zip([p.arg for p in reversed(a.args)],
                        list(reversed(a.defaults))))
    for name in names:
        p = params.get(name)
        if p is None:
            continue
        ann = p.annotation
        ann_name = ann.id if isinstance(ann, ast.Name) else None
        d = defaults.get(name)
        if ann_name == "float" or (isinstance(d, ast.Constant)
                                   and isinstance(d.value, float)):
            reporter.emit(
                PASS_ID, "float-static-arg", fi.module, line,
                f"static arg {name!r} of {fi.qualname} is float-typed: "
                "every distinct value mints a fresh compilation", fn=fi)
        if ann_name in _UNHASHABLE or isinstance(d, (ast.List, ast.Dict,
                                                     ast.Set)):
            reporter.emit(
                PASS_ID, "unhashable-static-arg", fi.module, line,
                f"static arg {name!r} of {fi.qualname} is unhashable: "
                "jit will raise at call time", fn=fi)


# ------------------------------------------------------------ cache keys
def _check_cache_keys(fi, reporter: Reporter) -> None:
    """Flag ``*cache*[key]`` subscripts whose key components derive from a
    raw ``len(...)`` / ``.shape`` without passing through a bucketing
    call (name containing "bucket")."""
    raw: set[str] = set()          # locals holding raw lengths/shapes
    bucketed: set[str] = set()     # locals laundered through a bucket fn

    def classify(expr: ast.AST) -> str | None:
        """'raw' | 'bucketed' | None for an expression."""
        if isinstance(expr, ast.Call):
            n = call_name(expr) or ""
            if "bucket" in n:
                return "bucketed"
            if n == "len":
                return "raw"
            return None
        if isinstance(expr, ast.Attribute) and expr.attr == "shape":
            return "raw"
        if isinstance(expr, ast.Subscript):
            return classify(expr.value)
        if isinstance(expr, ast.Name):
            if expr.id in bucketed:
                return "bucketed"
            if expr.id in raw:
                return "raw"
        if isinstance(expr, ast.BinOp):
            kinds = {classify(expr.left), classify(expr.right)}
            if "bucketed" in kinds:
                return "bucketed"
            if "raw" in kinds:
                return "raw"
        if isinstance(expr, ast.Tuple):
            # a key tuple leaks if ANY component is raw; comparisons like
            # ``s == bucket`` collapse the length to a bool and stay None
            kinds = {classify(e) for e in expr.elts}
            if "raw" in kinds:
                return "raw"
            if "bucketed" in kinds:
                return "bucketed"
        return None

    for node in ast.walk(fi.node):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            kind = classify(node.value)
            if kind == "raw":
                raw.add(node.targets[0].id)
                bucketed.discard(node.targets[0].id)
            elif kind == "bucketed":
                bucketed.add(node.targets[0].id)
                raw.discard(node.targets[0].id)

    def key_exprs(node: ast.AST):
        # cache[key] on either side of an assignment, or .setdefault/.get
        if isinstance(node, ast.Subscript):
            base = node.value
            chain = attr_chain(base)
            if chain and "cache" in chain[-1].lower():
                yield node.slice
        if isinstance(node, ast.Call) and isinstance(node.func,
                                                     ast.Attribute):
            chain = attr_chain(node.func.value)
            if chain and "cache" in chain[-1].lower() and \
                    node.func.attr in ("setdefault", "get") and node.args:
                yield node.args[0]

    for node in ast.walk(fi.node):
        for key in key_exprs(node):
            parts = key.elts if isinstance(key, ast.Tuple) else [key]
            for part in parts:
                if classify(part) == "raw":
                    reporter.emit(
                        PASS_ID, "unbucketed-cache-key", fi.module,
                        node.lineno,
                        f"jit-cache key component in {fi.qualname} "
                        "derives from a raw length/shape; route it "
                        "through a bucketing function or every distinct "
                        "size recompiles", fn=fi)
                    break
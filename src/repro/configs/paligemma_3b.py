"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP vision frontend is a stub (precomputed patch
embeddings); gemma text backbone.  [arXiv:2407.07726; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm", num_layers=18, d_model=2048,
    num_heads=8, num_kv_heads=1, head_dim=256, d_ff=16384,
    vocab_size=257216, mlp_variant="geglu", frontend="vision",
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    head_dim=16, d_ff=128, vocab_size=512)

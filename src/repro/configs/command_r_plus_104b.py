"""command-r-plus-104b [dense] — 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel attn||mlp blocks.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense", num_layers=64,
    d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000, mlp_variant="swiglu",
    parallel_block=True, tie_embeddings=True, param_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    head_dim=16, d_ff=256, vocab_size=512, param_dtype="float32")

"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) vocab=163840,
MoE 384 experts top-8 + 1 shared, expert d_ff=2048, first layer dense
(d_ff=18432) — trillion-param MoE.  [arXiv:2501.kimi2; unverified]

Note: the assignment table specifies GQA kv=8 (the released model uses
MLA); we follow the assignment."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", num_layers=61, d_model=7168,
    num_heads=64, num_kv_heads=8, head_dim=112, d_ff=18432,
    vocab_size=163840, mlp_variant="swiglu", num_experts=384,
    num_experts_per_tok=8, moe_d_ff=2048, n_shared_experts=1,
    prefix_pattern=("global",), tie_embeddings=False, param_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=128, num_experts=8, num_experts_per_tok=2,
    moe_d_ff=32, n_shared_experts=1, prefix_pattern=("global",), vocab_size=512,
    param_dtype="float32")

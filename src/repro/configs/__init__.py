"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``."""
from __future__ import annotations

import importlib

ARCHS = [
    "qwen3_1_7b", "minitron_4b", "minitron_8b", "command_r_plus_104b",
    "hubert_xlarge", "paligemma_3b", "dbrx_132b", "kimi_k2_1t_a32b",
    "xlstm_125m", "recurrentgemma_9b",
]

# assignment ids -> module names
ALIASES = {
    "qwen3-1.7b": "qwen3_1_7b",
    "minitron-4b": "minitron_4b",
    "minitron-8b": "minitron_8b",
    "command-r-plus-104b": "command_r_plus_104b",
    "hubert-xlarge": "hubert_xlarge",
    "paligemma-3b": "paligemma_3b",
    "dbrx-132b": "dbrx_132b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "xlstm-125m": "xlstm_125m",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str):
    if name == "hetero-serve-smoke":        # synthetic, smoke-sized only
        return get_hetero_smoke_config()
    return _module(name).CONFIG


def get_smoke_config(name: str):
    if name == "hetero-serve-smoke":
        return get_hetero_smoke_config()
    return _module(name).SMOKE


def all_arch_ids() -> list[str]:
    return list(ALIASES)


def get_hetero_smoke_config():
    """Synthetic heterogeneous *serving* smoke: one cycle mixing global +
    rolling-window + recurrent blocks plus a recurrent prefix layer, with
    a window small enough that rolling-page eviction triggers within a few
    dozen decode steps.  Exercises all three paged-KV stream kinds (global
    pages, rolling pages, fixed-size recurrent state) in one stack —
    shared by tests/test_paged_kv_hetero.py and the bench-smoke CI step."""
    import dataclasses
    base = get_smoke_config("qwen3-1.7b")
    return dataclasses.replace(
        base, name="hetero-serve-smoke", family="hybrid", num_layers=4,
        block_pattern=("global", "local", "recurrent"),
        prefix_pattern=("recurrent",), window_size=8, lru_width=64)

"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) vocab=100352,
MoE 16 experts top-4 fine-grained, expert d_ff=10752.
[hf:databricks/dbrx-base; unverified]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe", num_layers=40, d_model=6144,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=0,
    vocab_size=100352, mlp_variant="swiglu", num_experts=16,
    num_experts_per_tok=4, moe_d_ff=10752, tie_embeddings=False,
    param_dtype="bfloat16",
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, num_experts=4, num_experts_per_tok=2, moe_d_ff=64,
    vocab_size=512, param_dtype="float32")

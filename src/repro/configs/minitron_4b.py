"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron (squared-ReLU MLP).  [arXiv:2407.14679; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense", num_layers=32, d_model=3072,
    num_heads=24, num_kv_heads=8, head_dim=128, d_ff=9216,
    vocab_size=256000, mlp_variant="relu2", tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=96, num_heads=6, num_kv_heads=2,
    head_dim=16, d_ff=192, vocab_size=512)

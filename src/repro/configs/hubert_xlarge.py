"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16, MHA) d_ff=5120
vocab=504 (cluster units) — encoder-only; conv frame frontend is a stub
(input_specs provides precomputed frame embeddings).  [arXiv:2106.07447]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder", num_layers=48, d_model=1280,
    num_heads=16, num_kv_heads=16, head_dim=80, d_ff=5120, vocab_size=504,
    mlp_variant="gelu", causal=False, frontend="audio",
    tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=32)

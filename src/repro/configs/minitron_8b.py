"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron.  [arXiv:2407.14679; hf]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=16384,
    vocab_size=256000, mlp_variant="relu2", tie_embeddings=False,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    head_dim=16, d_ff=256, vocab_size=512)

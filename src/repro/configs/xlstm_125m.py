"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
mLSTM/sLSTM blocks (xLSTM[1:1]).  [arXiv:2405.04517; unverified]"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="xlstm", num_layers=12, d_model=768,
    num_heads=4, num_kv_heads=4, head_dim=192, d_ff=0, vocab_size=50304,
    block_pattern=("mlstm", "slstm"), tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, vocab_size=512)

"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — Griffin: RG-LRU recurrent blocks + local attention 1:2
(pattern recurrent,recurrent,local), window 2048.  [arXiv:2402.19427]

38 layers = 2 leading recurrent layers (unscanned prefix) + 12 cycles of
(recurrent, recurrent, local) — preserves both the assignment's exact layer
count and the paper's 2:1 recurrent:attention ratio."""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", num_layers=38,
    d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000, mlp_variant="geglu",
    block_pattern=("recurrent", "recurrent", "local"),
    prefix_pattern=("recurrent", "recurrent"),
    window_size=2048, lru_width=4096, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, num_layers=5, d_model=64, num_heads=4, num_kv_heads=1,
    head_dim=16, d_ff=128, vocab_size=512, lru_width=64, window_size=32)

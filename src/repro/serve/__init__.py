from .engine import (AdmissionImpossible, Request, ServeEngine,
                     compress_params, decompress_params)
from .faults import FaultInjector, PageIntegrityError, TransferDropped

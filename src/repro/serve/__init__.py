from .engine import (DEFAULT_WEIGHT_MIN_SIZE, AdmissionImpossible, Request,
                     ServeEngine, compress_params, decompress_params)
from .faults import FaultInjector, PageIntegrityError, TransferDropped

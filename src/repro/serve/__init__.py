from .engine import ServeEngine, Request, compress_params, decompress_params

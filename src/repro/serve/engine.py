"""Batched serving engine with APack-compressed weights.

Continuous-batching-lite: a fixed pool of decode slots; finished sequences
retire and waiting requests are admitted with a (jit-cached) single-request
prefill.  Weights arrive APack-compressed (``compress_params``): the engine
decompresses through the bit-exact codec at load and keeps per-tensor
traffic stats — on TPU the fused ``decompress_matmul`` kernel consumes the
compressed planes directly (kernels/decompress_matmul.py), which is the
paper's Figure-1 integration; this engine is the scheduling layer above it.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant, tables
from repro.kernels import fastpath, ops
from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass
class CompressedParams:
    """APack-compressed int8 view of a param tree (large matrices only)."""
    containers: dict                     # path -> (CompressedTensor, QuantParams)
    passthrough: dict                    # path -> raw small leaves
    treedef: Any
    n_leaves: int
    original_bytes: int
    compressed_bytes: int

    @property
    def ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)


def compress_params(params: Any, min_size: int = 16384) -> CompressedParams:
    """int8-quantize + APack-compress every large matrix in a param tree."""
    leaves, treedef = jax.tree.flatten(params)
    containers: dict = {}
    passthrough: dict = {}
    orig = comp = 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        orig += arr.nbytes
        if arr.size >= min_size and arr.dtype.kind == "f" and arr.ndim >= 2:
            q, qp = quant.quantize_symmetric(jnp.asarray(arr, jnp.float32),
                                             axis=-1)
            u = quant.to_unsigned(np.asarray(q))
            # Weights are static, so the paper's weight-mode heuristic
            # applies: profile the full tensor (histogram is cheap) and do
            # NOT steal probability counts for empty ranges — that slack is
            # only needed for activations whose values aren't all profiled.
            # (tests/test_serve.py pins table.mode == "weight".)
            table = tables.table_for(u.reshape(-1), is_activation=False)
            ct = fastpath.compress_np(u, table)
            containers[i] = (ct, np.asarray(qp.scale), str(arr.dtype))
            comp += ct.total_bits // 8
        else:
            passthrough[i] = arr
            comp += arr.nbytes
    return CompressedParams(containers=containers, passthrough=passthrough,
                            treedef=treedef, n_leaves=len(leaves),
                            original_bytes=orig, compressed_bytes=comp)


def decompress_params(cp: CompressedParams) -> Any:
    leaves: list = [None] * cp.n_leaves
    for i, arr in cp.passthrough.items():
        leaves[i] = jnp.asarray(arr)
    for i, (ct, scale, dtype) in cp.containers.items():
        u = fastpath.decompress_np(ct)
        q = quant.from_unsigned(u, bits=ct.bits)
        leaves[i] = (jnp.asarray(q, jnp.float32)
                     * jnp.asarray(scale)).astype(jnp.dtype(dtype))
    return jax.tree.unflatten(cp.treedef, leaves)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 kv_pages: int | None = None, kv_page_size: int = 16,
                 kv_calib_pages: int = 4, kv_backend: str | None = None,
                 kv_fused: bool | None = None, kv_refresh: bool = False,
                 kv_refresh_every_pages: int | None = None,
                 kv_refresh_threshold: float = 0.15,
                 kv_refresh_min_pages: int = 4,
                 kv_repack_budget: int = 4):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * max_batch
        self.positions = np.zeros(max_batch, np.int32)
        self.last_tokens = np.zeros((max_batch, 1), np.int32)
        self.last_logits = None              # device array, step output
        self.stats = {"steps": 0, "generated": 0, "completed": 0,
                      "kv_admission_blocked": 0, "preempted": 0,
                      "resumed": 0, "kv_refreshes": 0,
                      "kv_pages_repacked": 0}
        # adaptive table refresh: when enabled, every decode step checks
        # the drift triggers and re-packs at most ``kv_repack_budget``
        # stale pages, so a refresh amortizes over steps instead of
        # stalling the batch (steady-state latency preserved; the re-pack
        # is host-side + h2d sync only — zero device_get)
        self.kv_refresh = kv_refresh
        self.kv_repack_budget = kv_repack_budget
        # paged, APack-compressed KV mode.  Default (fused=True): the pool
        # planes stay device-resident, attention reads pages through the
        # fused gather-decode kernel and the new token appends on-device —
        # no per-step host<->device payload traffic.  kv_fused=False keeps
        # the legacy materialize path (dense cache rebuilt from the pool
        # every step) as the parity oracle.
        self.paged = cfg.kv_cache_dtype == "apack-int8"
        self.fused = bool(kv_fused) if kv_fused is not None else self.paged
        if self.paged:
            if kv_pages is None:
                # enough for every slot at full context (slot-equivalent),
                # per layer kind: rolling layers cap at their window pages,
                # recurrent-kind layers take none
                kv_pages = max_batch * M.PagedKVCache.pages_for_config(
                    cfg, max_len, kv_page_size)
            self.kv = M.PagedKVCache(
                cfg, kv_pages, page_size=kv_page_size,
                calib_pages=kv_calib_pages, backend=kv_backend,
                refresh_every_pages=kv_refresh_every_pages,
                refresh_threshold=kv_refresh_threshold,
                refresh_min_pages=kv_refresh_min_pages)
            self._reserved: dict[int, int] = {}
            self._reserved_total = 0
            # rid -> (compressed state snapshot, position, last token):
            # preempted requests resume without re-prefill
            self._preempted: dict[int, tuple] = {}
            self.cache = None
            if self.fused:
                self.kv.enable_device_pool(max_batch)
                self._decode_paged = jax.jit(
                    lambda p, pl, st, mt, t, pos: M.decode_step_paged(
                        cfg, p, pl, st, mt, t, pos, backend=kv_backend))
                self._append = jax.jit(
                    lambda pl, nc, tg: M.device_append(cfg, pl, nc, tg))
        else:
            self.fused = False
            self.kv = None
            self.cache = M.init_cache(cfg, max_batch, max_len)
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
        self._prefill_cache = {}

    # -------------------------------------------------------- scheduling
    def submit(self, req: Request) -> None:
        if self.paged:
            need = self._pages_for(req)
            if need > self.kv.pool.num_pages:
                # would head-of-line-block the queue forever otherwise
                raise ValueError(
                    f"request {req.rid} needs {need} pages worst-case but "
                    f"the pool only has {self.kv.pool.num_pages}; shorten "
                    "the request or grow kv_pages")
        req.t_submit = time.time()
        self.queue.append(req)

    def _pages_for(self, req: Request) -> int:
        """Worst-case page reservation: prompt + generated tokens, capped at
        the context window (so ``append_token`` can never starve)."""
        toks = min(self.max_len, len(req.prompt) + req.max_new_tokens)
        return self.kv.pages_needed(toks)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.active[slot] is None and self.queue:
                if self.paged:
                    head = self.queue[0]
                    if head.rid in self._preempted:
                        # resuming: pages + reservation were kept across
                        # the preemption, only the slot was given up
                        self._resume_into_slot(slot, self.queue.popleft())
                        continue
                    need = self._pages_for(head)
                    if self._reserved_total + need > self.kv.pool.num_pages:
                        # free slot but no pages: request waits (FIFO)
                        self.stats["kv_admission_blocked"] += 1
                        break
                req = self.queue.popleft()
                self._prefill_into_slot(slot, req)

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        # single-request prefill at the exact prompt length (jit-cached per
        # length — submit same-length prompts for best compile reuse)
        s = len(req.prompt)
        if s not in self._prefill_cache:
            self._prefill_cache[s] = jax.jit(
                lambda p, t: M.forward(self.cfg, p, {"tokens": t},
                                       remat=False, collect_cache=True,
                                       last_only=True)[:2])
        logits, caches = self._prefill_cache[s](
            self.params, jnp.asarray(np.asarray(req.prompt)[None]))
        if self.paged:
            # chop the prefill cache into pages instead of a batch write
            self.kv.add_request(req.rid)
            self._reserved[req.rid] = self._pages_for(req)
            self._reserved_total += self._reserved[req.rid]
            self.kv.ingest_prefill(req.rid, caches, s)
            if self.fused:
                # admission-time device sync: pages (HOT partials
                # included) + recurrent-kind states move once, here — the
                # decode loop itself never uploads payloads
                self.kv.sync_request_to_device(req.rid)
                if self.kv.state_layers:
                    self.kv.write_state_slot(slot, req.rid)
        else:
            self._write_prefill_cache(slot, caches)
        next_tok = int(jnp.argmax(logits[0, -1]))
        req.tokens.append(next_tok)
        self.active[slot] = req
        self.positions[slot] = s
        self.last_tokens[slot, 0] = next_tok

    def _write_prefill_cache(self, slot: int, caches) -> None:
        # write this sequence's prefill cache into the batch cache at `slot`
        caches = M.extend_caches(self.cfg, caches, self.max_len)

        def put(batch_leaf, one_leaf):
            # both trees have identical ndim (init_cache vs forward caches
            # stacked the same way); find the batch axis by shape matching
            rank = one_leaf.ndim
            # find batch axis: the axis where one_leaf has size 1 and
            # batch_leaf has size max_batch
            for ax in range(rank):
                if one_leaf.shape[ax] == 1 and batch_leaf.shape[ax] == self.max_batch:
                    idx = [slice(None)] * rank
                    idx[ax] = slice(slot, slot + 1)
                    return batch_leaf.at[tuple(idx)].set(
                        one_leaf.astype(batch_leaf.dtype))
            return batch_leaf                          # scalar stats etc.

        self.cache = jax.tree.map(put, self.cache, caches)

    def preempt(self, slot: int) -> dict:
        """Checkpoint/preemption path (paged mode): kick an in-flight
        request out of its decode slot and back to the queue head.

        Its attention KV stays where it is — already APack-compressed in
        the page pool, reservation held — while the dense
        recurrent/mLSTM/sLSTM hot-path states are snapshot-compressed
        (``PagedKVCache.snapshot_state``, weight-mode tables, bit-exact).
        Re-admission restores the snapshot and resumes decoding at the
        same position: no re-prefill, byte-identical continuation.
        Returns the compressed snapshot (also kept internally)."""
        if not self.paged:
            raise RuntimeError("preempt requires the paged apack-int8 KV")
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is idle, nothing to preempt")
        if self.fused and self.kv.state_layers:
            # states live on device in fused mode; pull this slot's copy
            # into the host store the snapshot reads (boundary transfer)
            self.kv.states[req.rid] = self.kv.read_state_slot(slot)
        snap = self.kv.snapshot_state(req.rid)
        # drop the dense copy: the compressed snapshot is now the only
        # home of the state, so preemption actually reclaims the memory
        # (and the restore path is load-bearing, not a formality)
        self.kv.states[req.rid] = {}
        self._preempted[req.rid] = (snap, int(self.positions[slot]),
                                    int(self.last_tokens[slot, 0]))
        self.active[slot] = None
        self.queue.appendleft(req)
        self.stats["preempted"] += 1
        return snap

    def _resume_into_slot(self, slot: int, req: Request) -> None:
        snap, pos, last = self._preempted.pop(req.rid)
        self.kv.restore_state(req.rid, snap)
        if self.fused and self.kv.state_layers:
            self.kv.write_state_slot(slot, req.rid)
        self.active[slot] = req
        self.positions[slot] = pos
        self.last_tokens[slot, 0] = last
        self.stats["resumed"] += 1

    def _retire(self) -> None:
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            eos = self.eos_id if req.eos_id is None else req.eos_id
            if (len(req.tokens) >= req.max_new_tokens
                    or (eos is not None and req.tokens
                        and req.tokens[-1] == eos)
                    or self.positions[slot] >= self.max_len - 1):
                req.done = True
                req.t_done = time.time()
                self.stats["completed"] += 1
                self.active[slot] = None
                if self.paged:
                    self.kv.release(req.rid)
                    self._reserved_total -= self._reserved.pop(req.rid)

    # ------------------------------------------------------------- step
    def step(self) -> int:
        """One engine iteration.  Returns number of active sequences."""
        self._retire()
        self._admit()
        n_active = sum(r is not None for r in self.active)
        if n_active == 0:
            return 0
        # per-slot positions: every sequence advances at its own offset
        # (attention_step takes a [B] position vector)
        slot_rids = [r.rid if r is not None else None for r in self.active]
        if self.fused:
            # device-resident hot path: pages stay on device, attention
            # gather-decodes them in the fused kernel, and the new token's
            # K/V scatters into the pool planes on-device — the only
            # per-step host<->device traffic is the i32 page-table meta
            # up and the sampled logits down
            meta = self.kv.step_meta(slot_rids, self.max_len)
            logits, new_cache = self._decode_paged(
                self.params, self.kv.dev.planes, self.kv.dev_states, meta,
                jnp.asarray(self.last_tokens), jnp.asarray(self.positions))
            targets = self.kv.claim_append_targets(slot_rids)
            self.kv.dev.planes = self._append(self.kv.dev.planes,
                                              new_cache, targets)
            self.kv.dev_states = M.states_from_step(self.cfg, new_cache)
            self.kv.note_appended(slot_rids)
            toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        else:
            if self.paged:
                # attention read: rebuild the dense int8 cache from the
                # page pool (compressed pages decode through the Pallas
                # kernel)
                self.cache = self.kv.materialize(slot_rids, self.max_len)
            logits, new_cache = self._decode(self.params, self.cache,
                                             jnp.asarray(self.last_tokens),
                                             jnp.asarray(self.positions))
            toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
            if self.paged:
                # the decode wrote each slot's quantized K/V at its
                # position; extract into the paged store and drop the
                # dense view (re-materialized from pages next step)
                self.kv.append_step_tokens(new_cache, slot_rids,
                                           self.positions)
                self.cache = None
            else:
                self.cache = new_cache
        if self.paged and self.kv_refresh:
            # drift check + budgeted re-pack ride the decode loop: all
            # host-side (sketches were fed at seal time), so the fused
            # path's zero-device_get steady state survives refresh
            rs = self.kv.refresh_step(self.kv_repack_budget)
            self.stats["kv_refreshes"] += len(rs["refreshed_layers"])
            self.stats["kv_pages_repacked"] += rs["repacked"]
        self.last_logits = logits
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.tokens.append(int(toks[slot]))
            self.last_tokens[slot, 0] = toks[slot]
            self.positions[slot] += 1
            self.stats["generated"] += 1
        self.stats["steps"] += 1
        return n_active

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break

    def kv_stats(self) -> dict:
        """Raw-vs-compressed KV traffic + pool occupancy (paged mode).

        ``kv_ratio`` is ``None`` until a read has actually moved bytes —
        an engine that has served nothing must not report break-even.
        ``kv_streams`` splits the accounting into the three stream kinds
        (global KV, rolling/local KV, recurrent-state snapshots)."""
        if not self.paged:
            return {}
        out = dict(self.kv.traffic)
        out["kv_ratio"] = self.kv.kv_ratio()
        out["kv_streams"] = self.kv.stream_stats()
        out["kv_repack"] = out["kv_streams"]["repack"]
        out["kv_pool_pages"] = self.kv.pool.num_pages
        out["kv_pages_allocated"] = self.kv.pool.alloc_count
        out["kv_pages_high_water"] = self.kv.pool.high_water
        out["kv_pages_evicted"] = self.kv.pool.evict_count
        out["kv_fused"] = self.fused
        out["transfers"] = dict(self.kv.transfers)
        return out

    def sync_host_mirror(self) -> None:
        """Fused mode: pull device-resident HOT pages and recurrent states
        into the host mirror so ``kv.materialize`` / snapshots see the
        live data (tests + oracle path; never called by ``step``)."""
        if not self.fused:
            return
        slot_rids = [r.rid if r is not None else None for r in self.active]
        self.kv.sync_hot_to_host(slot_rids)
        self.kv._pull_states(slot_rids)

"""Batched serving engine with APack-compressed weights.

Continuous batching over a fixed pool of decode slots; finished sequences
retire and waiting requests are admitted with a (jit-cached, power-of-two
bucketed) single-request prefill.  Weights arrive APack-compressed
(``compress_params``): the engine decompresses through the bit-exact codec
at load and keeps per-tensor traffic stats — on TPU the fused
``decompress_matmul`` kernel consumes the compressed planes directly
(kernels/decompress_matmul.py), which is the paper's Figure-1 integration;
this engine is the scheduling layer above it.

Two schedulers share every slot/pool/pressure mechanism:

* ``scheduler="sync"`` — the original loop: retire / admit / decode /
  host work, strictly serialized per step.
* ``scheduler="async"`` — the event-loop core (DESIGN.md §9): the fused
  decode is *dispatched* and left in flight while the next iteration's
  host work runs (seal pulls, sketch refresh + budgeted re-pack, chunked
  prefill ingest, spill-tier readahead staging), then collected one
  iteration later.  Greedy tokens are bit-identical to the sync engine —
  the same kernels see the same inputs, only the host work moved off the
  device critical path.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant, tables
from repro.kernels import fastpath, ops
from repro.kernels.decompress_matmul import DEFAULT_WEIGHT_MIN_SIZE
from repro.models import model as M
from repro.models import modules as m
from repro.models.config import ModelConfig
from repro.runtime.supervisor import StragglerWatchdog, WatchdogEvent

_log = logging.getLogger("repro.serve")

# Distinct jit prefill bucket sizes before the recompile-storm warning
# fires (same guard as kernels.paged_decode.gather_bucket).
PREFILL_BUCKET_WARN_THRESHOLD = 12
_seen_prefill_buckets: set[int] = set()


def prefill_bucket(s: int, max_len: int) -> int:
    """Power-of-two jit bucket for a prompt of length ``s``, capped at the
    context window (every admissible prompt fits it, so the cap keeps the
    bucket a valid cache length).  Varied-length traffic compiles one
    prefill per *bucket* instead of one per exact length; past
    ``PREFILL_BUCKET_WARN_THRESHOLD`` distinct buckets a warning fires
    once per new size — the same recompile-storm guard PR 4 added for
    ``gather_bucket``."""
    b = 1
    while b < s:
        b *= 2
    b = min(b, max_len)
    if b not in _seen_prefill_buckets:
        _seen_prefill_buckets.add(b)
        if len(_seen_prefill_buckets) > PREFILL_BUCKET_WARN_THRESHOLD:
            _log.warning(
                "prefill has compiled %d distinct jit bucket sizes "
                "(latest: %d): recompile storm — consider normalizing "
                "prompt lengths or growing the bucket threshold",
                len(_seen_prefill_buckets), b)
    return b


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [S] int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # timestamps are time.perf_counter() — the monotonic clock.  A
    # wall-clock here (the old time.time()) races NTP slew against the
    # step loop's perf_counter and can report negative latencies.
    t_submit: float = 0.0
    t_admit: float = 0.0                # prefill dispatch (queue-wait end)
    t_done: float = 0.0
    # SLO: steps this request may hold a decode slot while others queue
    # (None: engine-level slot_deadline_steps, or no deadline at all)
    deadline_steps: int | None = None
    # SLO: target end-to-end latency.  Admission orders by earliest
    # deadline (t_submit + slo_ms); None sorts last, so traffic that sets
    # no SLOs keeps pure-FIFO admission exactly.
    slo_ms: float | None = None
    # structured failure (integrity quarantine): done=True + error set,
    # tokens truncated at the failure point — never silently wrong
    error: str | None = None


@dataclasses.dataclass
class _InFlight:
    """Dispatch-time record of one in-flight fused decode step (async
    scheduler).  Collect applies tokens against this snapshot of the
    slot binding — immune to any later rebinding, which by construction
    only happens post-collect."""
    slot_reqs: list                      # dispatch-time slot -> Request
    slot_rids: list                      # dispatch-time slot -> rid
    logits: Any                          # device future, [B, 1, V]


@dataclasses.dataclass
class _PendingPrefill:
    """A queued request whose prefill is being pumped in the background
    (async scheduler): the bucketed forward was dispatched (device
    future), its cache view is pulled once, and pages ingest chunk by
    chunk during the overlapped host phase — one long prompt no longer
    stalls the whole batch behind a monolithic prefill."""
    req: Request
    s: int                               # true prompt length
    logits: Any                          # [1, 1, V] device future
    caches: Any                          # forward caches until view pull
    view: dict | None = None             # host-side prefill view
    cursor: int = 0                      # tokens ingested so far
    tok: int | None = None               # first generated token when done

    @property
    def ready(self) -> bool:
        return self.tok is not None


class AdmissionImpossible(RuntimeError):
    """Admission can never succeed for the queue head — the structured
    replacement for ``run_until_drained`` silently spinning to
    ``max_steps``.  Names the request and its page reservation."""

    def __init__(self, req: Request, need: int, pool_pages: int, why: str):
        super().__init__(
            f"request {req.rid} can never be admitted: reserves {need} "
            f"pages worst-case against a pool of {pool_pages} ({why})")
        self.rid = req.rid
        self.pages_needed = need
        self.pool_pages = pool_pages


@dataclasses.dataclass
class CompressedParams:
    """APack-compressed int8 view of a param tree (large matrices only)."""
    containers: dict                     # path -> (CompressedTensor, QuantParams)
    passthrough: dict                    # path -> raw small leaves
    treedef: Any
    n_leaves: int
    original_bytes: int
    compressed_bytes: int

    @property
    def ratio(self) -> float:
        return self.original_bytes / max(self.compressed_bytes, 1)


def compress_params(params: Any,
                    min_size: int = DEFAULT_WEIGHT_MIN_SIZE
                    ) -> CompressedParams:
    """int8-quantize + APack-compress every large matrix in a param tree."""
    leaves, treedef = jax.tree.flatten(params)
    containers: dict = {}
    passthrough: dict = {}
    orig = comp = 0
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        orig += arr.nbytes
        if arr.size >= min_size and arr.dtype.kind == "f" and arr.ndim >= 2:
            q, qp = quant.quantize_symmetric(jnp.asarray(arr, jnp.float32),
                                             axis=-1)
            u = quant.to_unsigned(np.asarray(q))
            # Weights are static, so the paper's weight-mode heuristic
            # applies: profile the full tensor (histogram is cheap) and do
            # NOT steal probability counts for empty ranges — that slack is
            # only needed for activations whose values aren't all profiled.
            # (tests/test_serve.py pins table.mode == "weight".)
            table = tables.table_for(u.reshape(-1), is_activation=False)
            ct = fastpath.compress_np(u, table)
            scale = np.asarray(qp.scale)
            containers[i] = (ct, scale, str(arr.dtype))
            # ceil-bytes, and the per-channel dequant scale ships with the
            # payload — flooring the bits and dropping the scale stream
            # (the old accounting) overstated the ratio
            comp += -(-ct.total_bits // 8) + scale.nbytes
        else:
            passthrough[i] = arr
            comp += arr.nbytes
    return CompressedParams(containers=containers, passthrough=passthrough,
                            treedef=treedef, n_leaves=len(leaves),
                            original_bytes=orig, compressed_bytes=comp)


def decompress_params(cp: CompressedParams) -> Any:
    leaves: list = [None] * cp.n_leaves
    for i, arr in cp.passthrough.items():
        leaves[i] = jnp.asarray(arr)
    for i, (ct, scale, dtype) in cp.containers.items():
        u = fastpath.decompress_np(ct)
        q = quant.from_unsigned(u, bits=ct.bits)
        leaves[i] = (jnp.asarray(q, jnp.float32)
                     * jnp.asarray(scale)).astype(jnp.dtype(dtype))
    return jax.tree.unflatten(cp.treedef, leaves)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 kv_pages: int | None = None, kv_page_size: int = 16,
                 kv_calib_pages: int = 4, kv_backend: str | None = None,
                 kv_fused: bool | None = None, kv_refresh: bool = False,
                 kv_refresh_every_pages: int | None = None,
                 kv_refresh_threshold: float = 0.15,
                 kv_refresh_min_pages: int = 4,
                 kv_repack_budget: int = 4,
                 kv_pressure: bool = False,
                 slot_deadline_steps: int | None = None,
                 pressure_backoff_max: int = 64,
                 watchdog_ratio: float | None = None,
                 watchdog_patience: int = 3,
                 kv_verify_on_repack: bool = False,
                 scheduler: str = "sync",
                 prefill_chunk_tokens: int | None = None,
                 mesh=None,
                 faults=None,
                 weights: str | None = None,
                 weight_min_size: int | None = None,
                 weight_tile_k: int | None = None):
        self.cfg = cfg
        self.params = params
        # packed weight store: ``weights="apack-int8"`` converts every
        # large projection/FFN matrix to CompressedLinear planes resident
        # in HBM (model.pack_weights) and the forward routes those sites
        # through the fused decompress-matmul — the weight-read stream at
        # decode becomes the compressed footprint, not the dense one.
        self.weights_mode = weights
        self._weight_stats: dict | None = None
        if weights is not None:
            if weights != "apack-int8":
                raise ValueError(f"unknown weights mode {weights!r}; "
                                 "expected 'apack-int8' or None")
            self.params, self._weight_stats = M.pack_weights(
                cfg, params, min_size=weight_min_size, tile_k=weight_tile_k)
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * max_batch
        self.positions = np.zeros(max_batch, np.int32)
        self.last_tokens = np.zeros((max_batch, 1), np.int32)
        self.last_logits = None              # device array, step output
        self.stats = {"steps": 0, "generated": 0, "completed": 0,
                      "kv_admission_blocked": 0, "preempted": 0,
                      "resumed": 0, "kv_refreshes": 0,
                      "kv_pages_repacked": 0, "failed": 0,
                      "spilled_requests": 0, "admission_retries": 0,
                      "pressure_preempted": 0, "deadline_preempted": 0,
                      "watchdog_preempted": 0, "prefill_chunks": 0,
                      "staged_readahead": 0,
                      "queue_wait_p50_ms": 0.0, "queue_wait_p99_ms": 0.0,
                      "e2e_p50_ms": 0.0, "e2e_p99_ms": 0.0}
        # pressure policy: level 1 (always on) spills *preempted*
        # requests' idle pages to the host tier when admission blocks;
        # level 2 (kv_pressure opt-in) additionally preempts-with-spill
        # active slots under exponential backoff.  Without the opt-in,
        # blocked admission keeps today's FIFO-wait semantics.
        self.kv_pressure = kv_pressure
        self.slot_deadline_steps = slot_deadline_steps
        self.pressure_backoff_max = pressure_backoff_max
        self._pressure_backoff = 1
        self._next_pressure_admit = 0
        self._admit_clock = 0
        self._slot_steps = np.zeros(max_batch, np.int64)
        self._spilled: set[int] = set()
        # step-time watchdog (shared StragglerWatchdog code path with the
        # training Supervisor): a hung step preempts-with-spill the
        # longest-running slot so the rest of the batch keeps moving
        self.watchdog = (StragglerWatchdog(ratio=watchdog_ratio,
                                           patience=watchdog_patience)
                         if watchdog_ratio is not None else None)
        self.faults = faults
        # adaptive table refresh: when enabled, every decode step checks
        # the drift triggers and re-packs at most ``kv_repack_budget``
        # stale pages, so a refresh amortizes over steps instead of
        # stalling the batch (steady-state latency preserved; the re-pack
        # is host-side + h2d sync only — zero device_get)
        self.kv_refresh = kv_refresh
        self.kv_repack_budget = kv_repack_budget
        # paged, APack-compressed KV mode.  Default (fused=True): the pool
        # planes stay device-resident, attention reads pages through the
        # fused gather-decode kernel and the new token appends on-device —
        # no per-step host<->device payload traffic.  kv_fused=False keeps
        # the legacy materialize path (dense cache rebuilt from the pool
        # every step) as the parity oracle.
        self.paged = cfg.kv_cache_dtype == "apack-int8"
        self.fused = bool(kv_fused) if kv_fused is not None else self.paged
        # mesh-sharded serving (DESIGN.md §11): decode jobs data-parallel
        # over the mesh's "data" axis (slots, state store, page planes and
        # per-shard free lists all partition with their jobs), kv-heads
        # tensor-parallel over "model" inside the fused kernel.  Greedy
        # tokens stay bit-identical to the single-device engine.
        self.mesh = mesh
        self._n_data = 1
        self._n_model = 1
        self._step_mesh = None
        if mesh is not None:
            if not (self.paged and self.fused):
                raise ValueError(
                    "mesh= requires the fused paged apack-int8 KV (the "
                    "sharded step is the combined decode+append program)")
            if scheduler != "sync":
                raise ValueError(
                    "mesh= requires scheduler='sync' (the async overlap "
                    "window is not shard-aware yet)")
            if "data" not in dict(mesh.shape):
                raise ValueError("serving mesh must name a 'data' axis")
            self._n_data, self._n_model = M.mesh_axis_sizes(mesh)
            if max_batch % self._n_data:
                raise ValueError(
                    f"max_batch={max_batch} must divide over the "
                    f"{self._n_data}-way data axis (whole slots per shard)")
            if self._n_model > 1 and cfg.num_kv_heads % self._n_model:
                raise ValueError(
                    f"num_kv_heads={cfg.num_kv_heads} must divide over "
                    f"the {self._n_model}-way model axis")
        if self.paged:
            if kv_pages is None:
                # enough for every slot at full context (slot-equivalent),
                # per layer kind: rolling layers cap at their window pages,
                # recurrent-kind layers take none
                kv_pages = max_batch * M.PagedKVCache.pages_for_config(
                    cfg, max_len, kv_page_size)
            if kv_pages % self._n_data:
                # whole pages per shard: round the pool up so every data
                # shard owns an equal contiguous range
                kv_pages += self._n_data - kv_pages % self._n_data
            self.kv = M.PagedKVCache(
                cfg, kv_pages, page_size=kv_page_size,
                calib_pages=kv_calib_pages, backend=kv_backend,
                refresh_every_pages=kv_refresh_every_pages,
                refresh_threshold=kv_refresh_threshold,
                refresh_min_pages=kv_refresh_min_pages,
                verify_on_repack=kv_verify_on_repack,
                n_shards=self._n_data)
            self.kv.faults = faults
            self._reserved: dict[int, int] = {}
            # per-shard reservation accounting — THE admission mechanism
            # (a single shard reduces it to the old global check, so the
            # single-device engine is the n_data=1 special case, not a
            # separate code path).  No global lock: each shard's admission
            # reserves against its own free-list-backed counter.
            self._rshard: dict[int, int] = {}
            self._shard_reserved: list[int] = [0] * self._n_data
            # rid -> (compressed state snapshot, position, last token):
            # preempted requests resume without re-prefill
            self._preempted: dict[int, tuple] = {}
            self.cache = None
            if self.fused:
                self.kv.enable_device_pool(max_batch, mesh=mesh)
                if mesh is not None:
                    self._step_mesh = M.build_sharded_step(
                        cfg, mesh, backend=kv_backend, params=self.params)
                self._decode_paged = jax.jit(
                    lambda p, pl, st, mt, t, pos: M.decode_step_paged(
                        cfg, p, pl, st, mt, t, pos, backend=kv_backend))
                self._append = jax.jit(
                    lambda pl, nc, tg: M.device_append(cfg, pl, nc, tg))
        else:
            self.fused = False
            self.kv = None
            self.cache = M.init_cache(cfg, max_batch, max_len)
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(cfg, p, c, t, pos))
        self._prefill_cache = {}
        # ---- event-loop scheduler state (DESIGN.md §9) ----
        if scheduler not in ("sync", "async"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        if scheduler == "async" and not (self.paged and self.fused):
            raise ValueError(
                "scheduler='async' requires the fused paged apack-int8 KV "
                "(the overlap window is the in-flight fused device step)")
        self.scheduler = scheduler
        # chunked-prefill ingest budget per overlapped host phase; the
        # default covers a few pages so short prompts still bind in one
        # step while long ones amortize over many
        self.prefill_chunk_tokens = (int(prefill_chunk_tokens)
                                     if prefill_chunk_tokens
                                     else kv_page_size * 4)
        self._inflight: _InFlight | None = None
        self._pump: dict[int, _PendingPrefill] = {}
        self._lat_wait: list[float] = []
        self._lat_e2e: list[float] = []

    # -------------------------------------------------------- scheduling
    def submit(self, req: Request) -> None:
        if self.paged:
            need = self._pages_for(req)
            if need > self._shard_pages():
                # would head-of-line-block the queue forever otherwise
                # (a request lives entirely within one data shard's
                # page range, so the per-shard capacity is the limit)
                raise ValueError(
                    f"request {req.rid} needs {need} pages worst-case but "
                    f"each pool shard only has {self._shard_pages()}; "
                    "shorten the request or grow kv_pages")
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _pages_for(self, req: Request) -> int:
        """Worst-case page reservation: prompt + generated tokens, capped at
        the context window (so ``append_token`` can never starve)."""
        toks = min(self.max_len, len(req.prompt) + req.max_new_tokens)
        return self.kv.pages_needed(toks)

    def _admission_order(self) -> list[Request]:
        """Queue snapshot in admission priority order: earliest SLO
        deadline first (EDF over ``t_submit + slo_ms``), submission order
        among requests without an SLO and as the tie-break — traffic that
        sets no SLOs keeps today's pure-FIFO admission exactly."""
        if not any(r.slo_ms is not None for r in self.queue):
            return list(self.queue)

        def key(ir):
            i, r = ir
            ddl = (r.t_submit + r.slo_ms / 1e3
                   if r.slo_ms is not None else float("inf"))
            return (ddl, i)

        return [r for _, r in sorted(enumerate(self.queue), key=key)]

    # ------------------------------------------ per-shard reservations
    # Admission accounting is per data shard: shard ``s`` owns pool pages
    # ``[s*pps, (s+1)*pps)`` (matching ``KVPagePool``'s free lists) and
    # the contiguous slot block ``[s*spb, (s+1)*spb)``.  There is no
    # global reservation lock — each shard's admission checks only its
    # own counter, so shards admit independently; the single-device
    # engine is the n_data=1 special case of the same mechanism.
    @property
    def _reserved_total(self) -> int:
        return sum(self._shard_reserved)

    @_reserved_total.setter
    def _reserved_total(self, v: int) -> None:
        # compatibility hook (tests poke this to simulate a full pool):
        # route the whole total to shard 0 — exact on a single shard
        self._shard_reserved = [int(v)] + [0] * (self._n_data - 1)

    def _slot_shard(self, slot: int) -> int:
        return slot // (self.max_batch // self._n_data)

    def _shard_pages(self) -> int:
        """Page capacity of ONE data shard (the whole pool at n_data=1)."""
        return self.kv.pool.num_pages // self._n_data

    def _reserve(self, rid: int, need: int, shard: int) -> None:
        self._reserved[rid] = need
        self._rshard[rid] = shard
        self._shard_reserved[shard] += need

    def _unreserve(self, rid: int) -> int:
        need = self._reserved.pop(rid)
        self._shard_reserved[self._rshard.pop(rid, 0)] -= need
        return need

    def _try_reserve(self, req: Request, shard: int = 0, *,
                     allow_relief: bool) -> int | None:
        """Reservation headroom check for one admission candidate against
        ONE data shard's page budget.  Returns the page count to reserve
        (0 when the request still holds its reservation), or None while
        it stays blocked.  Only the priority head may trigger pressure
        relief (``allow_relief``) — other candidates admit into existing
        headroom only, so continuous batching never spills victims on
        behalf of a request that jumped the queue."""
        need = 0 if req.rid in self._reserved else self._pages_for(req)
        if self._shard_reserved[shard] + need <= self._shard_pages():
            if allow_relief:
                self._pressure_backoff = 1    # clean head admission
            return need
        if not allow_relief:
            return None
        self.stats["kv_admission_blocked"] += 1
        if not self._relieve_pressure(req, need, shard):
            return None                       # request waits
        # Recompute after relief: the victim scan can change this very
        # request's standing (an L2 preemption requeues an active
        # request's pages).  Trusting the stale pre-relief ``need`` was
        # the pool over-commit bug — a head whose own reservation was
        # released by relief would resume with need=0 and under-count
        # the shard counter forever after.
        need = 0 if req.rid in self._reserved else self._pages_for(req)
        if self._shard_reserved[shard] + need > self._shard_pages():
            return None                       # partial relief; retry later
        self.stats["admission_retries"] += 1
        return need

    def _resume_request(self, slot: int, req: Request, need: int,
                        shard: int = 0) -> None:
        if need:
            self._reserve(req.rid, need, shard)
        # spilled requests re-adopt into fresh pages and are shard-free
        # until here; resident preempted requests only reach this with
        # their own shard (the _admit candidate scan guarantees it), so
        # the rebind is a no-op for them
        self.kv.request_shard[req.rid] = shard
        try:
            self._resume_into_slot(slot, req)
        except m.PageIntegrityError as e:
            # quarantined on unspill: fail ONLY this request
            self._fail_request(req, e)

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self.active[slot] is not None or not self.queue:
                continue
            if not self.paged:
                self._prefill_into_slot(slot, self.queue.popleft())
                continue
            self._admit_clock += 1
            shard = self._slot_shard(slot)
            head = None
            for r in self._admission_order():
                # a preempted-but-resident request's pages are pinned to
                # the shard range they were allocated from: it can only
                # resume into that shard's slots.  Spilled requests
                # re-adopt into fresh pages, so they bind to any shard.
                if (r.rid in self._preempted
                        and r.rid not in self._spilled
                        and self.kv.request_shard.get(r.rid, shard)
                        != shard):
                    continue
                head = r
                break
            if head is None:
                continue                   # nothing eligible for this shard
            need = self._try_reserve(head, shard, allow_relief=True)
            if need is None:
                if self._n_data > 1:
                    continue               # other shards admit independently
                break                      # head waits (FIFO)
            self.queue.remove(head)
            if head.rid in self._preempted:
                self._resume_request(slot, head, need, shard)
                continue
            self._prefill_into_slot(slot, head)

    def _relieve_pressure(self, head: Request, need: int,
                          shard: int = 0) -> bool:
        """Bounded spill -> retry -> preempt escalation under pool
        exhaustion of ONE data shard.  Returns True when reservation
        headroom was freed on that shard (the caller re-checks and
        admits); False means wait.

        Level 1 (always on): spill the *coldest* preempted request still
        holding a reservation on this shard — its pages sit idle in the
        pool, so parking them compressed in the host tier frees a whole
        reservation without touching any active slot.  Level 2
        (``kv_pressure`` opt-in): preempt-with-spill the longest-running
        active slot of this shard, gated by exponential backoff so a pool
        that is simply too small degrades to FIFO instead of livelocking
        on preempt/resume churn."""
        # The head itself can be parked (preempted, reservation held) —
        # it must never be its own victim: spilling it would release the
        # reservation the caller's ``need`` math was computed against
        # (the other half of the over-commit bug `_try_reserve` guards).
        parked = [rid for rid in self._preempted
                  if rid in self._reserved and rid not in self._spilled
                  and rid != head.rid
                  and self._rshard.get(rid, 0) == shard]
        if parked:
            rid = min(parked, key=self.kv.request_last_read)
            self._spill_reserved(rid)
            return True
        if not self.kv_pressure:
            return False
        if self._admit_clock < self._next_pressure_admit:
            return False                  # backing off
        victims = [s for s, r in enumerate(self.active)
                   if r is not None and self._slot_shard(s) == shard]
        if not victims:
            if self._pump:
                # pumped prefills hold reservations and will bind, serve
                # and retire — admission is delayed, not impossible
                return False
            if self._n_data > 1 and any(r is not None for r in self.active):
                # other shards still serve; this shard just waits (a
                # retire elsewhere can't help it, but a spill-free wait
                # is not impossibility — the caller keeps FIFO order)
                return False
            # nothing active and nothing left to spill: no future retire
            # or spill can ever free pages for this reservation
            raise AdmissionImpossible(
                head, need, self._shard_pages(),
                "no active slots to retire and no spillable reservations")
        slot = max(victims, key=lambda s: int(self._slot_steps[s]))
        self.preempt(slot, spill=True, requeue="tail")
        self.stats["pressure_preempted"] += 1
        self._next_pressure_admit = self._admit_clock + self._pressure_backoff
        self._pressure_backoff = min(2 * self._pressure_backoff,
                                     self.pressure_backoff_max)
        return True

    def _spill_reserved(self, rid: int) -> None:
        """Park a preempted request's pages compressed in the host spill
        tier and release its pool reservation (resume re-reserves and
        runs the checksum-verified readahead)."""
        self.kv.spill_request(rid)
        self._unreserve(rid)
        self._spilled.add(rid)
        self.stats["spilled_requests"] += 1

    def _fail_request(self, req: Request, err: Exception) -> None:
        """Structured failure of ONE request (the integrity-quarantine
        recovery path): surface the error on the request, release its
        pages/reservation/snapshot, and leave every other slot untouched
        — corruption never poisons neighbors."""
        req.done = True
        req.error = str(err)
        req.t_done = time.perf_counter()
        self.stats["failed"] += 1
        rid = req.rid
        self._pump.pop(rid, None)
        for s, r in enumerate(self.active):
            if r is req:
                # apack: allow-phase(overlap-reachable only via readahead
                # staging, which fails parked/spilled requests; a request
                # bound to an in-flight slot never takes this path)
                self.active[s] = None
        try:
            self.queue.remove(req)
        except ValueError:
            pass
        if self.paged:
            if rid in self.kv.page_tables:
                # apack: allow-phase(releases a parked request's SPILLED refs
                # and residual pages; the in-flight step's page tables were
                # snapshotted at dispatch and cannot reference this rid)
                self.kv.release(rid)
            if rid in self._reserved:
                self._unreserve(rid)
        self._preempted.pop(rid, None)
        self._spilled.discard(rid)

    def _prefill_forward(self, prompt) -> tuple:
        """Single-request prefill, jit-cached per power-of-two *bucket*
        rather than per exact prompt length — the recompile-storm fix.
        Prompts shorter than their bucket are zero-padded and the model
        masks the pads (``true_len``): pad positions drop out of
        attention, freeze out of the recurrent/mLSTM/sLSTM scans, and the
        returned last-token logits are sliced at the true position.  A
        prompt that lands exactly on its bucket skips the mask entirely
        (bit-identical to the legacy exact-length path)."""
        s = len(prompt)
        bucket = prefill_bucket(s, self.max_len)
        exact = s == bucket
        key = (bucket, exact)
        fn = self._prefill_cache.get(key)
        if fn is None:
            if exact:
                fn = jax.jit(
                    lambda p, t: M.forward(self.cfg, p, {"tokens": t},
                                           remat=False, collect_cache=True,
                                           last_only=True)[:2])
            else:
                fn = jax.jit(
                    lambda p, t, n: M.forward(self.cfg, p, {"tokens": t},
                                              remat=False,
                                              collect_cache=True,
                                              last_only=True,
                                              true_len=n)[:2])
            self._prefill_cache[key] = fn
        if exact:
            return fn(self.params, jnp.asarray(np.asarray(prompt)[None]))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :s] = np.asarray(prompt)
        return fn(self.params, jnp.asarray(toks), jnp.asarray(s, jnp.int32))

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        s = len(req.prompt)
        req.t_admit = time.perf_counter()
        logits, caches = self._prefill_forward(req.prompt)
        if self.paged:
            # chop the prefill cache into pages instead of a batch write;
            # the request binds to its slot's data shard — page claims
            # come from that shard's free list from here on
            shard = self._slot_shard(slot)
            self.kv.add_request(req.rid, shard=shard)
            self._reserve(req.rid, self._pages_for(req), shard)
            self.kv.ingest_prefill(req.rid, caches, s)
            if self.fused:
                # admission-time device sync: pages (HOT partials
                # included) + recurrent-kind states move once, here — the
                # decode loop itself never uploads payloads
                self.kv.sync_request_to_device(req.rid)
                if self.kv.state_layers:
                    self.kv.write_state_slot(slot, req.rid)
        else:
            self._write_prefill_cache(slot, caches)
        # apack: allow-transfer(admission event: first-token pick after a
        # prefill forward; not in the steady-state decode loop)
        next_tok = int(jnp.argmax(logits[0, -1]))
        req.tokens.append(next_tok)
        self.active[slot] = req
        self.positions[slot] = s
        self.last_tokens[slot, 0] = next_tok
        self._slot_steps[slot] = 0

    def _write_prefill_cache(self, slot: int, caches) -> None:
        # write this sequence's prefill cache into the batch cache at `slot`
        caches = M.extend_caches(self.cfg, caches, self.max_len)

        def put(batch_leaf, one_leaf):
            # both trees have identical ndim (init_cache vs forward caches
            # stacked the same way); find the batch axis by shape matching
            rank = one_leaf.ndim
            # find batch axis: the axis where one_leaf has size 1 and
            # batch_leaf has size max_batch
            for ax in range(rank):
                if one_leaf.shape[ax] == 1 and batch_leaf.shape[ax] == self.max_batch:
                    idx = [slice(None)] * rank
                    idx[ax] = slice(slot, slot + 1)
                    return batch_leaf.at[tuple(idx)].set(
                        one_leaf.astype(batch_leaf.dtype))
            return batch_leaf                          # scalar stats etc.

        self.cache = jax.tree.map(put, self.cache, caches)

    def preempt(self, slot: int, *, spill: bool = False,
                requeue: str = "head") -> dict:
        """Checkpoint/preemption path (paged mode): kick an in-flight
        request out of its decode slot and back to the queue.

        Default (``spill=False``, ``requeue="head"``): its attention KV
        stays where it is — already APack-compressed in the page pool,
        reservation held — while the dense recurrent/mLSTM/sLSTM
        hot-path states are snapshot-compressed
        (``PagedKVCache.snapshot_state``, weight-mode tables, bit-exact).
        Re-admission restores the snapshot and resumes decoding at the
        same position: no re-prefill, byte-identical continuation.

        ``spill=True`` (pressure/deadline/watchdog path) additionally
        parks the pages compressed in the host spill tier and releases
        the pool reservation — resume re-reserves and readahead restores
        them, still byte-identical.  ``requeue="tail"`` avoids the
        head-of-line livelock when the preemption was *caused by* the
        head waiting.  Returns the compressed snapshot (also kept
        internally)."""
        if not self.paged:
            raise RuntimeError("preempt requires the paged apack-int8 KV")
        self._drain()      # async: the in-flight step must land first
        req = self.active[slot]
        if req is None:
            raise ValueError(f"slot {slot} is idle, nothing to preempt")
        if self.fused and self.kv.state_layers:
            # states live on device in fused mode; pull this slot's copy
            # into the host store the snapshot reads (boundary transfer)
            self.kv.states[req.rid] = self.kv.read_state_slot(slot)
        snap = self.kv.snapshot_state(req.rid)
        # drop the dense copy: the compressed snapshot is now the only
        # home of the state, so preemption actually reclaims the memory
        # (and the restore path is load-bearing, not a formality)
        self.kv.states[req.rid] = {}
        self._preempted[req.rid] = (snap, int(self.positions[slot]),
                                    int(self.last_tokens[slot, 0]))
        self.active[slot] = None
        self._slot_steps[slot] = 0
        if requeue == "tail":
            self.queue.append(req)
        else:
            self.queue.appendleft(req)
        self.stats["preempted"] += 1
        if spill:
            self._spill_reserved(req.rid)
        return snap

    def _resume_into_slot(self, slot: int, req: Request) -> None:
        snap, pos, last = self._preempted[req.rid]
        if req.rid in self._spilled:
            # readahead: checksum-verified restore of every SPILLED page
            # into fresh pool slots + ONE batched h2d flush, all before
            # the fused kernel's next read (an admission event — the
            # steady-state zero-device_get invariant is untouched).
            # PageIntegrityError propagates to _admit, which fails only
            # this request (reservation was already re-taken; _fail_request
            # unwinds it).
            self.kv.unspill_request(req.rid)
            self._spilled.discard(req.rid)
        del self._preempted[req.rid]
        self.kv.restore_state(req.rid, snap)
        if self.fused and self.kv.state_layers:
            self.kv.write_state_slot(slot, req.rid)
        self.active[slot] = req
        self.positions[slot] = pos
        self.last_tokens[slot, 0] = last
        self._slot_steps[slot] = 0
        self.stats["resumed"] += 1

    def _retire(self) -> None:
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            eos = self.eos_id if req.eos_id is None else req.eos_id
            if (len(req.tokens) >= req.max_new_tokens
                    or (eos is not None and req.tokens
                        and req.tokens[-1] == eos)
                    or self.positions[slot] >= self.max_len - 1):
                req.done = True
                req.t_done = time.perf_counter()
                self._log_latency(req)
                self.stats["completed"] += 1
                self.active[slot] = None
                if self.paged:
                    self.kv.release(req.rid)
                    self._unreserve(req.rid)

    def _log_latency(self, req: Request) -> None:
        if req.t_submit <= 0.0:
            return            # directly-constructed request (tests)
        t_admit = req.t_admit if req.t_admit > 0.0 else req.t_done
        self._lat_wait.append(max(t_admit - req.t_submit, 0.0))
        self._lat_e2e.append(max(req.t_done - req.t_submit, 0.0))
        for name, vals in (("queue_wait", self._lat_wait),
                           ("e2e", self._lat_e2e)):
            self.stats[f"{name}_p50_ms"] = float(
                np.percentile(vals, 50) * 1e3)
            self.stats[f"{name}_p99_ms"] = float(
                np.percentile(vals, 99) * 1e3)

    def latency_stats(self) -> dict:
        """Queue-wait and end-to-end latency percentiles (seconds) over
        every completed request, monotonic-clock based (perf_counter) so
        NTP slew can never report a negative latency.  The serving bench
        and ``launch/serve`` consume this."""
        out: dict = {"n": len(self._lat_e2e)}
        for name, vals in (("queue_wait", self._lat_wait),
                           ("e2e", self._lat_e2e)):
            if vals:
                out[f"{name}_p50"] = float(np.percentile(vals, 50))
                out[f"{name}_p99"] = float(np.percentile(vals, 99))
                out[f"{name}_mean"] = float(np.mean(vals))
        return out

    def _check_deadlines(self) -> None:
        """Per-request SLO deadlines: a slot that has held the GPU past
        its ``deadline_steps`` (or the engine-wide
        ``slot_deadline_steps``) while other requests queue is
        preempted-with-spill to the queue tail — stuck or SLO-violating
        slots stop starving the batch.  With an empty queue there is
        nothing to yield to, so deadlines don't fire."""
        if not self.queue:
            return
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            ddl = (req.deadline_steps if req.deadline_steps is not None
                   else self.slot_deadline_steps)
            if ddl is not None and int(self._slot_steps[slot]) >= ddl:
                self.preempt(slot, spill=True, requeue="tail")
                self.stats["deadline_preempted"] += 1

    def _on_hung(self, ev: WatchdogEvent) -> None:
        """Watchdog escalation (shared StragglerWatchdog event): the step
        loop is persistently slow — preempt-with-spill the longest-running
        slot (tail requeue) and widen the pressure backoff so recovery
        doesn't immediately re-trigger the stall."""
        victims = [s for s, r in enumerate(self.active) if r is not None]
        if not victims:
            return
        slot = max(victims, key=lambda s: int(self._slot_steps[s]))
        self.preempt(slot, spill=True, requeue="tail")
        self.stats["watchdog_preempted"] += 1
        self.watchdog.reset()
        self._next_pressure_admit = self._admit_clock + self._pressure_backoff
        self._pressure_backoff = min(2 * self._pressure_backoff,
                                     self.pressure_backoff_max)

    def _handle_integrity_failure(self, e: m.PageIntegrityError) -> None:
        """Quarantine recovery: attribute the corruption to its owning
        request and fail exactly that one.  Unattributable corruption
        re-raises — swallowing it would serve wrong tokens."""
        req = None
        if e.rid is not None:
            for r in list(self.active) + list(self.queue):
                if r is not None and r.rid == e.rid:
                    req = r
                    break
        if req is None:
            raise e
        self._fail_request(req, e)

    # ------------------------------------------------------------- step
    # apack: hot-path-root
    def step(self) -> int:
        """One engine iteration.  Returns number of active sequences."""
        if self.scheduler == "async":
            return self._step_async()
        t0 = time.perf_counter()
        if self.faults is not None:
            d = self.faults.step_delay()
            if d:
                time.sleep(d)
        self._retire()
        if self.paged:
            self._check_deadlines()
        self._admit()
        n_active = sum(r is not None for r in self.active)
        if n_active == 0:
            return 0
        # per-slot positions: every sequence advances at its own offset
        # (attention_step takes a [B] position vector)
        slot_rids = [r.rid if r is not None else None for r in self.active]
        try:
            n_active = self._step_decode(slot_rids, n_active)
        except m.PageIntegrityError as e:
            # the guards fire before any page/seq mutation (step_meta /
            # materialize read guards, pre-swap repack verify), so failing
            # the owner here leaves every other slot consistent
            self._handle_integrity_failure(e)
            n_active = sum(r is not None for r in self.active)
        if self.watchdog is not None:
            ev = self.watchdog.observe(time.perf_counter() - t0)
            if ev is not None and ev.kind == "hung":
                self._on_hung(ev)
        return n_active

    def _step_decode(self, slot_rids: list, n_active: int) -> int:
        if self.fused and self._step_mesh is not None:
            # mesh-sharded hot path: decode + append + state re-bind run
            # as ONE jit(shard_map) program, each data shard reading and
            # scattering only its own page range.  Targets are claimed
            # BEFORE step_meta — the claim is host metadata only, and a
            # freshly claimed HOT page has fill 0, so every key slot it
            # could cover is masked and the online-softmax accumulator is
            # bit-exactly unchanged: same tokens as the single-device
            # meta->decode->claim->append order.
            targets = self.kv.claim_append_targets(slot_rids)
            meta = self.kv.step_meta(slot_rids, self.max_len)
            logits, toks_dev, self.kv.dev.planes, self.kv.dev_states = \
                self._step_mesh(
                    self.params, self.kv.dev.planes, self.kv.dev_states,
                    meta, jnp.asarray(self.last_tokens),
                    jnp.asarray(self.positions), targets)
            self.kv.note_appended(slot_rids)
            # apack: allow-transfer(the step's one sanctioned sync: token ids
            # must reach the host for EOS/retire — the greedy argmax runs
            # inside the sharded program, so this pulls batch int32s, not
            # the [batch, vocab] logits)
            toks = np.asarray(toks_dev, np.int32)
        elif self.fused:
            # device-resident hot path: pages stay on device, attention
            # gather-decodes them in the fused kernel, and the new token's
            # K/V scatters into the pool planes on-device — the only
            # per-step host<->device traffic is the i32 page-table meta
            # up and the sampled logits down
            meta = self.kv.step_meta(slot_rids, self.max_len)
            logits, new_cache = self._decode_paged(
                self.params, self.kv.dev.planes, self.kv.dev_states, meta,
                jnp.asarray(self.last_tokens), jnp.asarray(self.positions))
            targets = self.kv.claim_append_targets(slot_rids)
            self.kv.dev.planes = self._append(self.kv.dev.planes,
                                              new_cache, targets)
            self.kv.dev_states = M.states_from_step(self.cfg, new_cache)
            self.kv.note_appended(slot_rids)
            # apack: allow-transfer(the step's one sanctioned sync: token ids
            # must reach the host for EOS/retire; the d2h ledger and the
            # zero-device_get gates account for exactly this pull)
            toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        else:
            if self.paged:
                # attention read: rebuild the dense int8 cache from the
                # page pool (compressed pages decode through the Pallas
                # kernel)
                self.cache = self.kv.materialize(slot_rids, self.max_len)
            logits, new_cache = self._decode(self.params, self.cache,
                                             jnp.asarray(self.last_tokens),
                                             jnp.asarray(self.positions))
            # apack: allow-transfer(materialize parity oracle: same sanctioned
            # token-id pull as the fused branch)
            toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
            if self.paged:
                # the decode wrote each slot's quantized K/V at its
                # position; extract into the paged store and drop the
                # dense view (re-materialized from pages next step)
                self.kv.append_step_tokens(new_cache, slot_rids,
                                           self.positions)
                self.cache = None
            else:
                self.cache = new_cache
        if self.paged and self.kv_refresh:
            # drift check + budgeted re-pack ride the decode loop: all
            # host-side (sketches were fed at seal time), so the fused
            # path's zero-device_get steady state survives refresh
            rs = self.kv.refresh_step(self.kv_repack_budget)
            self.stats["kv_refreshes"] += len(rs["refreshed_layers"])
            self.stats["kv_pages_repacked"] += rs["repacked"]
        self.last_logits = logits
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.tokens.append(int(toks[slot]))
            self.last_tokens[slot, 0] = toks[slot]
            self.positions[slot] += 1
            self._slot_steps[slot] += 1
            self.stats["generated"] += 1
        self.stats["steps"] += 1
        return n_active

    # ------------------------------------------- async event-loop core
    def _step_async(self) -> int:
        """One iteration of the event-loop scheduler.  Phase order *is*
        the design (DESIGN.md §9):

        1. overlapped host work — while the previous iteration's fused
           decode is still in flight on device, run the host work the
           sync engine serializes around the kernel: injected host
           delays, adaptive refresh + budgeted re-pack, chunked prefill
           ingest, spill-tier readahead staging.  Safe because jax
           arrays are immutable and the host pool is truth only for
           sealed pages — nothing here mutates state the in-flight step
           reads, and plane/state re-binds only chain futures for the
           *next* dispatch.
        2. collect — block on the in-flight logits (the loop's only
           blocking device read) and apply tokens against the
           dispatch-time slot map.
        3. retire / deadlines / admit — every slot-binding mutation runs
           here, strictly post-collect; a bind during flight would point
           the dispatch-time ``states_from_step`` slot re-bind at the
           wrong request.
        4. dispatch — fire the next fused step and return without
           blocking on it.

        Greedy tokens are bit-identical to the sync engine: the same
        kernels see the same per-slot inputs, only host work moved."""
        t0 = time.perf_counter()
        if self.faults is not None:
            d = self.faults.step_delay()
            if d:
                time.sleep(d)
        self._overlap_host_work()
        t_host = time.perf_counter()
        self._collect()
        t_collect = time.perf_counter()
        self._retire()
        self._check_deadlines()
        self._admit_async()
        n_active = sum(r is not None for r in self.active)
        if n_active:
            try:
                self._dispatch()
            except m.PageIntegrityError as e:
                # step_meta read guards fire before any page mutation;
                # fail the owner and re-dispatch for the survivors
                self._handle_integrity_failure(e)
                n_active = sum(r is not None for r in self.active)
                if n_active:
                    self._dispatch()
        if self.watchdog is not None:
            ev = self.watchdog.observe(
                time.perf_counter() - t0,
                phases={"overlap_host": t_host - t0,
                        "collect": t_collect - t_host,
                        "schedule_dispatch":
                            time.perf_counter() - t_collect})
            if ev is not None and ev.kind == "hung":
                self._on_hung(ev)
        return n_active

    def _overlap_host_work(self) -> None:
        """Host-side work overlapped with the in-flight device step —
        everything the sync engine runs serially between kernels."""
        if self.faults is not None:
            d = self.faults.host_delay()
            if d:
                time.sleep(d)
        if self.kv_refresh and self._inflight is not None:
            # drift check + budgeted re-pack (host sketches + one h2d
            # flush chained onto the pending plane futures) — same
            # cadence as the sync engine: once per decode step
            # apack: allow-phase(refresh mutates only sealed PACKED pages
            # with whole-page plane+gen swaps; the in-flight kernel reads the
            # device planes snapshotted at dispatch, so it never observes a
            # half-swapped page)
            rs = self.kv.refresh_step(self.kv_repack_budget)
            self.stats["kv_refreshes"] += len(rs["refreshed_layers"])
            self.stats["kv_pages_repacked"] += rs["repacked"]
        for p in list(self._pump.values()):
            while not p.ready:
                self._pump_chunk(p)
                if self._inflight is not None:
                    break      # paced: one chunk per overlapped step
                # nothing in flight — chunk pacing would be pure added
                # latency, so drain the pump like a sync prefill
        self._stage_readahead()

    def _pump_chunk(self, p: _PendingPrefill) -> None:
        if p.view is None:
            # one d2h pull of the prefill caches — the forward was
            # dispatched at pump start and has been computing since
            p.view = self.kv.prefill_host_view(p.caches)
            p.caches = None
        t1 = min(p.cursor + self.prefill_chunk_tokens, p.s)
        # apack: allow-phase(pending request's pages only: the rid has no
        # slot until admission completes post-collect, so the in-flight
        # step cannot reference these page tables)
        self.kv.ingest_prefill_chunk(p.req.rid, p.view, p.cursor, t1, p.s)
        p.cursor = t1
        self.stats["prefill_chunks"] += 1
        if p.cursor >= p.s:
            # apack: allow-phase(same pending-request argument as the chunk
            # ingest above: no slot binding exists yet for this rid)
            self.kv.finish_prefill(p.req.rid, p.view, p.s)
            # apack: allow-transfer(prefill-completion event in the overlap
            # window: the wait rides the in-flight decode step)
            p.tok = int(jnp.argmax(p.logits[0, -1]))
            p.view = None

    def _stage_readahead(self) -> None:
        """Spill-tier readahead staging: re-reserve and restore the
        highest-priority spilled request during the overlap window, so
        its batched h2d + checksum verify ride the in-flight step
        instead of stalling the admission that resumes it."""
        for req in self._admission_order():
            rid = req.rid
            if rid in self._preempted and rid in self._spilled:
                need = self._pages_for(req)
                # async scheduler is single-shard (mesh rejects it)
                if self._shard_reserved[0] + need > self._shard_pages():
                    return                 # no headroom this step
                self._reserve(rid, need, 0)
                try:
                    # apack: allow-phase(restores a parked spilled request into
                    # fresh pool slots; the in-flight step was dispatched
                    # without this rid and cannot read the new pages)
                    self.kv.unspill_request(rid)
                except m.PageIntegrityError as e:
                    self._fail_request(req, e)
                    return
                self._spilled.discard(rid)
                self.stats["staged_readahead"] += 1
                return                     # one staging per step
            if rid not in self._reserved and rid not in self._pump:
                # a higher-priority request claims the headroom first
                return

    def _start_pump(self, req: Request, need: int) -> None:
        """Reserve pages and dispatch the bucketed prefill forward for a
        queued request; it keeps queueing while the overlapped host phase
        ingests its pages chunk by chunk."""
        req.t_admit = time.perf_counter()
        logits, caches = self._prefill_forward(req.prompt)
        self.kv.add_request(req.rid)
        self._reserve(req.rid, need, 0)     # async is single-shard
        self._pump[req.rid] = _PendingPrefill(
            req=req, s=len(req.prompt), logits=logits, caches=caches)

    def _bind_prefilled(self, slot: int, p: _PendingPrefill) -> None:
        """Slot-bind a fully-ingested pumped prefill.  The only
        device-touching part of admission (page h2d sync + state-slot
        write) — runs post-collect, where it chains cleanly onto the
        pending plane/state futures."""
        req = p.req
        self.kv.sync_request_to_device(req.rid)
        if self.kv.state_layers:
            self.kv.write_state_slot(slot, req.rid)
        req.tokens.append(p.tok)
        self.active[slot] = req
        self.positions[slot] = p.s
        self.last_tokens[slot, 0] = p.tok
        self._slot_steps[slot] = 0

    def _admit_async(self) -> None:
        """Continuous admission (post-collect): bind ready pumped
        prefills and resume preempted requests into free slots; start
        prefill pumps for queued requests that can reserve pages now.
        EDF-over-FIFO priority; a blocked higher-priority request stops
        lower-priority candidates from taking NEW reservations (no
        headroom stealing), but zero-cost binds of already-reserved work
        still proceed — that is the continuous-batching part."""
        if not self.queue:
            return
        self._admit_clock += 1
        free = [s for s in range(self.max_batch)
                if self.active[s] is None]
        blocked = False
        for i, req in enumerate(self._admission_order()):
            rid = req.rid
            if rid in self._preempted:
                if not free:
                    # still claims headroom while it waits for a slot
                    blocked = blocked or rid not in self._reserved
                    continue
                if blocked and rid not in self._reserved:
                    continue
                need = self._try_reserve(req, allow_relief=(i == 0))
                if need is None:
                    blocked = True
                    continue
                self.queue.remove(req)
                self._resume_request(free.pop(0), req, need)
                continue
            p = self._pump.get(rid)
            if p is None:
                if blocked or len(self._pump) >= self.max_batch:
                    blocked = True
                    continue
                need = self._try_reserve(req, allow_relief=(i == 0))
                if need is None:
                    blocked = True
                    continue
                self._start_pump(req, need)
                if free and not any(r is not None for r in self.active):
                    # idle engine: no decode to overlap the chunked
                    # ingest with, so admit like a sync prefill — drain
                    # the pump and bind in this very step
                    p = self._pump.pop(rid)
                    while not p.ready:
                        self._pump_chunk(p)
                    self.queue.remove(req)
                    self._bind_prefilled(free.pop(0), p)
                continue
            if p.ready and free:
                self.queue.remove(req)
                del self._pump[rid]
                self._bind_prefilled(free.pop(0), p)
            # pump still ingesting: it binds on a later step

    # apack: hot-path-root
    def _dispatch(self) -> None:
        """Fire the fused decode for the current binding WITHOUT blocking
        on the result: jit dispatch is async, so the logits / plane
        append / state re-bind land on device while the next iteration's
        host phase runs.  The dispatch-time slot map is recorded in
        ``_InFlight`` for collect."""
        slot_rids = [r.rid if r is not None else None for r in self.active]
        meta = self.kv.step_meta(slot_rids, self.max_len)
        logits, new_cache = self._decode_paged(
            self.params, self.kv.dev.planes, self.kv.dev_states, meta,
            jnp.asarray(self.last_tokens), jnp.asarray(self.positions))
        targets = self.kv.claim_append_targets(slot_rids)
        self.kv.dev.planes = self._append(self.kv.dev.planes,
                                          new_cache, targets)
        self.kv.dev_states = M.states_from_step(self.cfg, new_cache)
        self._inflight = _InFlight(slot_reqs=list(self.active),
                                   slot_rids=slot_rids, logits=logits)

    # apack: hot-path-root
    def _collect(self) -> None:
        """Land the in-flight device step: block on its logits, account
        the appends, and apply per-slot token updates against the
        dispatch-time slot map — bindings cannot have changed mid-flight
        because every binding mutation runs post-collect (external
        ``preempt`` drains first)."""
        inf = self._inflight
        if inf is None:
            return
        self._inflight = None
        # apack: allow-transfer(collect IS the sync point: the async loop's one
        # sanctioned token-id pull, after the step finished computing)
        toks = np.asarray(jnp.argmax(inf.logits[:, 0], axis=-1), np.int32)
        self.kv.note_appended(inf.slot_rids)
        self.last_logits = inf.logits
        for slot, req in enumerate(inf.slot_reqs):
            if req is None:
                continue
            tok = int(toks[slot])
            req.tokens.append(tok)
            self.last_tokens[slot, 0] = tok
            self.positions[slot] += 1
            self._slot_steps[slot] += 1
            self.stats["generated"] += 1
        self.stats["steps"] += 1

    def _drain(self) -> None:
        """Synchronize the pipeline: land the in-flight step (if any) so
        external mutations — ``preempt``, ``sync_host_mirror``, state
        snapshots — observe a consistent post-step engine.  No-op on the
        sync scheduler."""
        if self._inflight is not None:
            self._collect()

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        stalled = 0
        for _ in range(max_steps):
            # an idle step that still advanced a pumped prefill is
            # progress (the async scheduler ingests chunks before the
            # first slot binds)
            if self.step() > 0 or self._pump:
                stalled = 0
                continue
            if not self.queue:
                break
            # idle step with work still queued: admission is blocked and
            # nothing is in flight to unblock it.  Bounded patience (the
            # pressure backoff can legitimately hold a few retries), then
            # a structured error instead of silently burning max_steps.
            stalled += 1
            if stalled > 2 * self.pressure_backoff_max:
                head = self.queue[0]
                need = self._pages_for(head) if self.paged else 0
                pool = self._shard_pages() if self.paged else 0
                raise AdmissionImpossible(
                    head, need, pool,
                    f"{stalled} consecutive no-progress steps with zero "
                    "active slots")

    def weight_stats(self) -> dict:
        """Weight-store accounting for the packed serving path.

        With ``weights="apack-int8"`` every decode step streams the
        compressed planes (APack payload + the per-channel dequant scale)
        where the dense engine streams the full weight matrices —
        ``weight_ratio`` is that per-step read ratio against the int8
        dense baseline (the quantization is shared by both stores;
        ``native_ratio`` additionally credits the fp32->int8 narrowing).
        Cumulative totals scale with ``stats["steps"]``: weights are read
        once per step regardless of batch size."""
        if self._weight_stats is None:
            return {"weights": "dense"}
        s = dict(self._weight_stats)
        comp = s["payload_bytes"] + s["scale_bytes"]
        s["weights"] = "apack-int8"
        s["compressed_read_bytes_per_step"] = comp
        s["dense_read_bytes_per_step"] = s["int8_bytes"]
        s["weight_ratio"] = comp / max(s["int8_bytes"], 1)
        s["native_ratio"] = comp / max(s["native_bytes"], 1)
        steps = self.stats["steps"]
        s["compressed_read_bytes_total"] = comp * steps
        s["dense_read_bytes_total"] = s["int8_bytes"] * steps
        return s

    def kv_stats(self) -> dict:
        """Raw-vs-compressed KV traffic + pool occupancy (paged mode).

        ``kv_ratio`` is ``None`` until a read has actually moved bytes —
        an engine that has served nothing must not report break-even.
        ``kv_streams`` splits the accounting into the three stream kinds
        (global KV, rolling/local KV, recurrent-state snapshots)."""
        if not self.paged:
            return {}
        out = dict(self.kv.traffic)
        out["kv_ratio"] = self.kv.kv_ratio()
        out["kv_streams"] = self.kv.stream_stats()
        out["kv_repack"] = out["kv_streams"]["repack"]
        out["kv_pool_pages"] = self.kv.pool.num_pages
        out["kv_pages_allocated"] = self.kv.pool.alloc_count
        out["kv_pages_high_water"] = self.kv.pool.high_water
        out["kv_pages_evicted"] = self.kv.pool.evict_count
        out["kv_fused"] = self.fused
        out["transfers"] = dict(self.kv.transfers)
        if self._n_data > 1:
            # per-shard accounting (mesh mode): free-list depth and live
            # reservations per data shard — the invariants tests gate on
            out["kv_shard_free"] = [self.kv.pool.free_count_shard(s)
                                    for s in range(self._n_data)]
            out["kv_shard_reserved"] = list(self._shard_reserved)
        # spill tier: own stream (never folded into read ratios) + the
        # per-request accounting of what is parked on host right now
        out["kv_spill"] = out["kv_streams"]["spill"]
        out["kv_pages_spilled"] = self.kv.pool.spill_count
        out["kv_pages_unspilled"] = self.kv.pool.unspill_count
        out["kv_spilled_requests"] = {
            rid: self.kv.spilled_pages(rid)
            for rid in sorted(self._spilled) if rid in self.kv.page_tables}
        return out

    def sync_host_mirror(self) -> None:
        """Fused mode: pull device-resident HOT pages and recurrent states
        into the host mirror so ``kv.materialize`` / snapshots see the
        live data (tests + oracle path; never called by ``step``)."""
        if not self.fused:
            return
        self._drain()
        slot_rids = [r.rid if r is not None else None for r in self.active]
        self.kv.sync_hot_to_host(slot_rids)
        self.kv._pull_states(slot_rids)

"""Fault injection for the serving loop (tests + ``bench_decode
--pressure``).

The injector sits on seams the real system already has: the
host<->device transfer boundary (``PagedKVCache._fetch/_put``), the host
spill tier (``modules.HostSpillTier`` records), the page-generation
metadata, and the engine's step timing.  Nothing here mutates model
math — every injected fault must either be *detected* (checksum,
generation guard) or *absorbed* (bounded transfer retry, watchdog
preemption with backoff); silent token divergence is the failure the
test suite hunts for.

All faults are deterministic and budgeted (inject exactly N, not
probabilistically) so tests and the pressure bench are reproducible.
"""
from __future__ import annotations

import numpy as np

from repro.models.modules import (HostSpillTier, PageIntegrityError,
                                  TransferDropped)

__all__ = ["FaultInjector", "PageIntegrityError", "TransferDropped"]


class FaultInjector:
    """Deterministic, budgeted fault source for the KV/serve stack.

    Attach with ``ServeEngine(..., faults=inj)`` (or set
    ``PagedKVCache.faults`` directly), then arm individual faults:

    * ``drop_transfers("h2d", n)`` — the next ``n`` h2d uploads raise
      ``TransferDropped`` (the cache retries up to ``transfer_retries``).
    * ``flip_bit(tier, handle)`` — corrupt one bit of a spilled page's
      payload in place (detected by CRC on unspill -> quarantine).
    * ``corrupt_packed_page(kv, pid)`` — flip a bit of a *resident*
      PACKED page's planes (detected by ``verify_on_repack``).
    * ``poison_generation(kv, pid)`` — stamp an out-of-pool table
      generation (detected by the ``step_meta`` read guard).
    * ``delay_steps(seconds, n)`` / ``delay_spills(seconds, n)`` — stall
      the engine step / spill completion (drives watchdog preemption).
    * ``delay_host_work(seconds, n)`` — stall the *overlapped* host phase
      of the async scheduler (seal pulls, chunked prefill ingest, re-pack,
      readahead staging run there); the sync engine has no such phase and
      ignores it.  Lets tests prove a slow host overlap degrades latency,
      never tokens.
    """

    def __init__(self):
        self._drop_budget = {"h2d": 0, "d2h": 0}
        self._step_delays: list[float] = []
        self._spill_delays: list[float] = []
        self._host_delays: list[float] = []
        self.stats = {"h2d_dropped": 0, "d2h_dropped": 0,
                      "bits_flipped": 0, "generations_poisoned": 0,
                      "steps_delayed": 0, "spills_delayed": 0,
                      "host_work_delayed": 0}

    # ------------------------------------------------------- transfers
    def drop_transfers(self, direction: str, n: int = 1) -> None:
        if direction not in self._drop_budget:
            raise ValueError(f"unknown transfer direction {direction!r}")
        self._drop_budget[direction] += n

    def check_transfer(self, direction: str) -> None:
        """Called by ``PagedKVCache._fetch/_put`` before every transfer."""
        if self._drop_budget.get(direction, 0) > 0:
            self._drop_budget[direction] -= 1
            self.stats[f"{direction}_dropped"] += 1
            raise TransferDropped(
                f"injected {direction} transfer drop "
                f"({self._drop_budget[direction]} left in budget)",
                direction=direction)

    # ------------------------------------------------------- integrity
    def flip_bit(self, tier: HostSpillTier, handle: int, *,
                 array: str | None = None, bit: int = 0) -> None:
        """Flip one bit of a live spill record's payload, in place —
        models host-DRAM corruption while the page was parked."""
        rec = tier.get(handle, verify=False)
        name = array if array is not None else sorted(rec.payload)[0]
        arr = rec.payload[name]
        flat = arr.view(np.uint8).reshape(-1)
        flat[bit // 8] ^= np.uint8(1 << (bit % 8))
        self.stats["bits_flipped"] += 1

    def corrupt_packed_page(self, kv, pid: int, *, bit: int = 0) -> None:
        """Flip one bit of a resident PACKED page's K sym plane.
        ``sym[0, pid]`` (not ``sym[:, pid]``) so the byte view is a true
        in-place view — the kind-axis slice is non-contiguous and its
        reshape would silently mutate a copy."""
        flat = kv.pool.sym[0, pid].view(np.uint8).reshape(-1)
        flat[bit // 8] ^= np.uint8(1 << (bit % 8))
        self.stats["bits_flipped"] += 1

    def poison_generation(self, kv, pid: int, *, offset: int = 7) -> None:
        """Stamp a table generation past the live pool — a decode that
        trusted it would index garbage table rows."""
        kv.page_gen[pid] = kv.generation + offset
        self.stats["generations_poisoned"] += 1

    # ---------------------------------------------------------- delays
    def delay_steps(self, seconds: float, n: int = 1) -> None:
        self._step_delays.extend([seconds] * n)

    def step_delay(self) -> float:
        """Consumed by the engine at the top of each step."""
        if self._step_delays:
            self.stats["steps_delayed"] += 1
            return self._step_delays.pop(0)
        return 0.0

    def delay_spills(self, seconds: float, n: int = 1) -> None:
        self._spill_delays.extend([seconds] * n)

    def delay_host_work(self, seconds: float, n: int = 1) -> None:
        self._host_delays.extend([seconds] * n)

    def host_delay(self) -> float:
        """Consumed by the async engine inside the overlapped host phase
        (while a device step is in flight)."""
        if self._host_delays:
            self.stats["host_work_delayed"] += 1
            return self._host_delays.pop(0)
        return 0.0

    def spill_delay(self) -> float:
        """Consumed by ``PagedKVCache.spill_request``."""
        if self._spill_delays:
            self.stats["spills_delayed"] += 1
            return self._spill_delays.pop(0)
        return 0.0

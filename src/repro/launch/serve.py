"""Serving driver: batched requests against APack-compressed weights.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 16 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve import ServeEngine, Request, compress_params, decompress_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--no-compress", action="store_true")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if not args.no_compress:
        t0 = time.time()
        cp = compress_params(params, min_size=4096)
        print(f"APack weight compression: {cp.original_bytes/1e6:.1f} MB -> "
              f"{cp.compressed_bytes/1e6:.1f} MB "
              f"({cp.ratio:.2f}x, {time.time()-t0:.1f}s)")
        params = decompress_params(cp)

    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_len=args.prompt_len + args.max_new + 8)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    engine.run_until_drained()
    dt = time.time() - t0
    assert all(r.done for r in reqs)
    print(f"{engine.stats} in {dt:.1f}s "
          f"({engine.stats['generated']/max(dt,1e-9):.1f} tok/s)")
    print("sample output:", reqs[0].tokens[:16])


if __name__ == "__main__":
    main()

"""Serving driver: batched requests against APack-compressed weights and
(optionally) a paged APack-compressed KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 16 --prompt-len 32 --max-new 16 --kv apack-int8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import configs
from repro.models import model as M
from repro.serve import (DEFAULT_WEIGHT_MIN_SIZE, Request, ServeEngine,
                         compress_params, decompress_params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--weights", default=None, choices=["apack-int8"],
                    help="serve directly from APack-packed weights: large "
                         "projection/FFN matrices live in HBM as compressed "
                         "planes and decode/prefill matmuls run through the "
                         "fused decompress kernel (supersedes the "
                         "checkpoint-style compress/decompress round-trip)")
    ap.add_argument("--weight-min-size", type=int,
                    default=DEFAULT_WEIGHT_MIN_SIZE,
                    help="smallest element count compressed by either "
                         "weight path (--weights and the checkpoint "
                         "round-trip share this one default)")
    ap.add_argument("--kv", default=None,
                    choices=["bfloat16", "int8", "apack-int8"],
                    help="KV-cache mode (apack-int8 = paged + compressed)")
    ap.add_argument("--kv-page-size", type=int, default=16)
    ap.add_argument("--window-size", type=int, default=None,
                    help="override the rolling-attention window (small "
                         "values demo page eviction on hybrid archs)")
    ap.add_argument("--kv-materialize", action="store_true",
                    help="use the legacy materialize decode path (dense "
                         "cache rebuilt from the pool every step) instead "
                         "of the default device-resident fused path")
    ap.add_argument("--kv-refresh", action="store_true",
                    help="adaptive table refresh: re-calibrate activation "
                         "tables from drift sketches and re-pack pages "
                         "when serving traffic drifts")
    ap.add_argument("--kv-refresh-every", type=int, default=None,
                    metavar="PAGES",
                    help="also refresh unconditionally every PAGES sealed "
                         "pages per layer (default: regression trigger "
                         "only)")
    ap.add_argument("--kv-refresh-threshold", type=float, default=0.15,
                    help="refresh when the drift sketch's expected coded "
                         "size regresses this fraction past the "
                         "calibration-time expectation")
    ap.add_argument("--kv-repack-budget", type=int, default=4,
                    help="max pages re-packed per decode step (amortizes "
                         "a refresh over the serve instead of stalling)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="page-pool size (default: worst-case for "
                         "max_batch × max_len; smaller values exercise the "
                         "pressure/spill path)")
    ap.add_argument("--kv-pressure", action="store_true",
                    help="enable pressure escalation: blocked admission "
                         "may preempt-with-spill active slots (compressed "
                         "host spill tier, exponential backoff)")
    ap.add_argument("--slot-deadline", type=int, default=None,
                    metavar="STEPS",
                    help="preempt-with-spill any slot that decodes this "
                         "many steps while other requests queue")
    ap.add_argument("--scheduler", default="sync",
                    choices=["sync", "async"],
                    help="engine core: 'async' runs the event-loop "
                         "scheduler (host work overlaps the in-flight "
                         "device step, chunked prefill, continuous "
                         "admission); requires the fused apack-int8 KV")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    metavar="TOKENS",
                    help="async scheduler: prompt tokens ingested per "
                         "overlapped step (default: 4 pages' worth)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request end-to-end latency SLO; admission "
                         "orders by earliest deadline instead of FIFO")
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="mesh-sharded serving, e.g. '8x1' (decode jobs "
                         "data-parallel, kv-heads tensor-parallel): shards "
                         "the page pool, free lists and fused gather-decode "
                         "across devices; requires the fused apack-int8 KV "
                         "and DATA*MODEL visible devices (debug: "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    if args.kv:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=args.kv)
    if args.window_size is not None:
        cfg = dataclasses.replace(cfg, window_size=args.window_size)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if not args.no_compress and not args.weights:
        # checkpoint-style round-trip (legacy): compress, report, decompress
        # back to dense.  --weights apack-int8 supersedes it — the packed
        # planes ARE the weight store, no decompressed copy exists.
        t0 = time.time()
        cp = compress_params(params, min_size=args.weight_min_size)
        print(f"APack weight compression: {cp.original_bytes/1e6:.1f} MB -> "
              f"{cp.compressed_bytes/1e6:.1f} MB "
              f"({cp.ratio:.2f}x, {time.time()-t0:.1f}s)")
        params = decompress_params(cp)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_debug_mesh
        n_data, _, n_model = args.mesh.partition("x")
        mesh = make_debug_mesh(int(n_data), int(n_model or 1))
        print(f"serving mesh: {dict(mesh.shape)} over "
              f"{len(mesh.devices.flat)} devices")

    engine = ServeEngine(cfg, params, max_batch=args.max_batch,
                         max_len=args.prompt_len + args.max_new + 8,
                         mesh=mesh,
                         weights=args.weights,
                         weight_min_size=args.weight_min_size,
                         kv_page_size=args.kv_page_size,
                         kv_fused=not args.kv_materialize,
                         kv_refresh=args.kv_refresh,
                         kv_refresh_every_pages=args.kv_refresh_every,
                         kv_refresh_threshold=args.kv_refresh_threshold,
                         kv_repack_budget=args.kv_repack_budget,
                         kv_pages=args.kv_pages,
                         kv_pressure=args.kv_pressure,
                         slot_deadline_steps=args.slot_deadline,
                         scheduler=args.scheduler,
                         prefill_chunk_tokens=args.prefill_chunk)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    slo_ms=args.slo_ms)
            for i in range(args.requests)]
    for r in reqs:
        engine.submit(r)
    t0 = time.time()
    engine.run_until_drained()
    dt = time.time() - t0
    assert all(r.done for r in reqs)
    print(f"{engine.stats} in {dt:.1f}s "
          f"({engine.stats['generated']/max(dt,1e-9):.1f} tok/s)")
    if args.weights:
        ws = engine.weight_stats()
        print(f"packed weight store: {ws['packed_tensors']} tensors, "
              f"{ws['native_bytes']/1e6:.1f} MB native -> "
              f"{(ws['payload_bytes'] + ws['scale_bytes'])/1e6:.1f} MB "
              f"compressed (payload {ws['payload_bytes']/1e6:.1f} MB + "
              f"scale {ws['scale_bytes']/1e6:.2f} MB); "
              f"per-step weight reads x{ws['weight_ratio']:.3f} vs int8 "
              f"dense, x{ws['native_ratio']:.3f} vs native")
    lat = engine.latency_stats()
    if lat["n"]:
        print(f"latency ({args.scheduler} scheduler, n={lat['n']}): "
              f"queue-wait p50={lat['queue_wait_p50']*1e3:.1f}ms "
              f"p99={lat['queue_wait_p99']*1e3:.1f}ms; "
              f"e2e p50={lat['e2e_p50']*1e3:.1f}ms "
              f"p99={lat['e2e_p99']*1e3:.1f}ms")
    if engine.paged:
        ks = engine.kv_stats()
        ratio = ("n/a (no KV reads)" if ks["kv_ratio"] is None
                 else f"{ks['kv_ratio']:.3f}")
        print(f"paged KV traffic: raw={ks['kv_raw_bytes']/1e3:.1f} kB -> "
              f"read={ks['kv_read_bytes']/1e3:.1f} kB "
              f"(+{ks['kv_table_bytes']} B tables) "
              f"ratio={ratio} "
              f"packed_pages={ks['kv_pages_packed']} "
              f"evicted_pages={ks['kv_pages_evicted']} "
              f"pool={ks['kv_pages_high_water']}/{ks['kv_pool_pages']} pages")
        for kind, st in ks["kv_streams"].items():
            if kind in ("repack", "spill"):  # dedicated lines below
                continue
            r = st.get("ratio")
            print(f"  stream {kind:7s}: "
                  + " ".join(f"{k}={v}" for k, v in st.items()
                             if k != "ratio")
                  + (f" ratio={r:.3f}" if r is not None else " ratio=n/a"))
        rp = ks["kv_repack"]
        print(f"table refresh: {'on' if args.kv_refresh else 'off'}; "
              f"generation={rp['generation']} "
              f"refreshes={rp['refreshes']} "
              f"repacked={rp['pages']} pages "
              f"({rp['read_bytes']/1e3:.1f} kB read + "
              f"{rp['write_bytes']/1e3:.1f} kB written, "
              f"{rp['pending']} pending)")
        sp = ks["kv_spill"]
        spr = sp.get("ratio")
        print(f"spill tier: {sp['pages']} pages spilled "
              f"({sp['spill_bytes']/1e3:.1f} kB compressed vs "
              f"{sp['raw_bytes']/1e3:.1f} kB dense, "
              + (f"ratio={spr:.3f}" if spr is not None else "ratio=n/a")
              + f"); readahead {sp['readahead_pages']} pages "
              f"{sp['readahead_bytes']/1e3:.1f} kB; "
              f"parked={sp['live_records']} "
              f"quarantined={sp['quarantined']}; "
              f"spill_preempt={engine.stats['pressure_preempted']}"
              f"+{engine.stats['deadline_preempted']}ddl "
              f"failed={engine.stats['failed']}")
        tr = ks["transfers"]
        mode = "fused (device-resident)" if ks["kv_fused"] else "materialize"
        print(f"decode path: {mode}; host<->device "
              f"h2d={tr['h2d_bytes']/1e3:.1f} kB "
              f"d2h={tr['d2h_bytes']/1e3:.1f} kB "
              f"({tr['h2d_calls']}/{tr['d2h_calls']} calls)")
    print("sample output:", reqs[0].tokens[:16])


if __name__ == "__main__":
    main()

"""Re-run the HLO cost walker over saved dry-run artifacts (no recompile).

    PYTHONPATH=src python -m repro.launch.reanalyze --dir runs/dryrun2
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import zstandard

from repro.launch import hlo_costs
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS


def reanalyze(dir_: Path) -> None:
    dctx = zstandard.ZstdDecompressor()
    for jpath in sorted(dir_.glob("*.json")):
        with open(jpath) as f:
            cell = json.load(f)
        if cell.get("status") != "ok":
            continue
        hpath = jpath.with_suffix("").with_suffix("")  # strip .json
        hpath = dir_ / (jpath.stem + ".hlo.zst")
        if not hpath.exists():
            continue
        hlo = dctx.decompress(hpath.read_bytes()).decode()
        trips = {int(k): v for k, v in cell["trips"].items()}
        parsed = hlo_costs.analyze(hlo, trips)
        compute_s = parsed["flops"] / PEAK_FLOPS
        memory_s = parsed["bytes"] / HBM_BW
        collective_s = parsed["collective_wire_bytes"] / ICI_BW
        dominant = max(("compute", compute_s), ("memory", memory_s),
                       ("collective", collective_s), key=lambda kv: kv[1])[0]
        cell["parsed"] = parsed
        cell["roofline"] = dict(
            compute_s=compute_s, memory_s=memory_s,
            collective_s=collective_s, dominant=dominant,
            useful_flops_ratio=cell["model_flops_per_chip"]
            / max(parsed["flops"], 1.0))
        with open(jpath, "w") as f:
            json.dump(cell, f, indent=1)
        print(f"{jpath.stem}: dominant={dominant} "
              f"mem={memory_s*1e3:.1f}ms comp={compute_s*1e3:.1f}ms "
              f"coll={collective_s*1e3:.1f}ms")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun2")
    args = ap.parse_args()
    reanalyze(Path(args.dir))

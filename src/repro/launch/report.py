"""Generate BASELINE_TABLE.md from dry-run JSONs.

    PYTHONPATH=src python -m repro.launch.report --dir runs/dryrun2
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.models.config import ALL_SHAPES
from repro import configs

HEADER = ("| arch | shape | mesh | dominant | compute_ms | memory_ms | "
          "collective_ms | useful_flops | peak_GiB | compile_s |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def rows(dir_: Path, mesh: str | None = None) -> list[str]:
    out = []
    order = {s.name: i for i, s in enumerate(ALL_SHAPES)}
    cells = []
    for p in sorted(dir_.glob("*.json")):
        c = json.load(open(p))
        cells.append(c)
    arch_order = {a: i for i, a in enumerate(configs.all_arch_ids())}
    cells.sort(key=lambda c: (arch_order.get(c["arch"], 99),
                              order.get(c["shape"], 9), c["mesh"]))
    for c in cells:
        if mesh and c["mesh"] != mesh:
            continue
        if c["status"] != "ok":
            out.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                       f"SKIP: {c['reason']} | | | | | | |")
            continue
        r = c["roofline"]
        out.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {r['dominant']} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | {r['useful_flops_ratio']:.2f} | "
            f"{c['memory']['peak_bytes']/2**30:.1f} | {c['compile_s']:.0f} |")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun2")
    ap.add_argument("--out", default="BASELINE_TABLE.md")
    args = ap.parse_args()
    lines = [
        "# Baseline roofline table — every (arch x shape x mesh) cell",
        "",
        "Generated from the dry-run artifacts by `repro.launch.report`.",
        "Terms are per-device seconds-equivalents (ms shown); see",
        "EXPERIMENTS.md §Roofline for methodology and caveats.",
        "", HEADER,
    ]
    lines += rows(Path(args.dir))
    Path(args.out).write_text("\n".join(lines) + "\n")
    print(f"wrote {args.out} ({len(lines)} lines)")


if __name__ == "__main__":
    main()

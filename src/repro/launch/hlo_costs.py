"""Post-optimization HLO cost walker for the roofline analysis.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (measured — see
EXPERIMENTS.md §Dry-run), so a scanned-transformer's per-layer costs need
multiplying by trip count.  XLA doesn't expose per-computation costs, so we
parse ``compiled.as_text()`` ourselves:

  * FLOPs: 2*numel(result)*prod(contracted dims) per ``dot`` (found inside
    fusions too), numel for elementwise/reduce/transcendental ops.
  * Bytes: operand+result bytes at every instruction boundary, fusions
    counted at their boundary only (= XLA's "bytes accessed" convention).
  * Collective bytes: operand sums per op kind, plus a wire-traffic model
    (ring terms) used for the roofline's collective term.
  * While trips by nesting depth — the codebase's loop convention
    (models/config.py CHUNK) makes depth->trip unambiguous:
    depth 0 = layer-stack scans (fwd/bwd; trip = n_cycles),
    depth 1 = time-axis chunk scans (trip = S/CHUNK),
    depth 2 = sLSTM in-chunk steps (trip = CHUNK).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def _shape_numel(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        if m.group(1) not in _DTYPE_BYTES or _DTYPE_BYTES[m.group(1)] == 0:
            continue
        numel = 1
        if m.group(2):
            for d in m.group(2).split(","):
                numel *= int(d)
        total += numel
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_type: str
    operands: list[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict          # instr name -> result type str


_INSTR_RE = re.compile(
    r"^\s+(?:ROOT )?%?([\w\.\-]+) = (\(.*?\)|\S+) ([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s+\(.*?\)\s*->\s*.*\{\s*$")


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        ops = re.findall(r"%([\w\.\-]+)", rest.split("),", 1)[0])
        instr = Instr(name=name, opcode=opcode, result_type=rtype,
                      operands=ops, raw=line)
        cur.instrs.append(instr)
        cur.shapes[name] = rtype
    if cur is not None:
        comps[cur.name] = cur
    if entry is None:
        # fall back: the computation named like main
        entry = next((n for n in comps if "main" in n), next(iter(comps)))
    return comps, entry


_CALLED_RE = {
    "while": re.compile(r"condition=%?([\w\.\-]+), body=%?([\w\.\-]+)"),
    "fusion": re.compile(r"calls=%?([\w\.\-]+)"),
    "call": re.compile(r"to_apply=%?([\w\.\-]+)"),
    "conditional": re.compile(r"(?:branch_computations=\{([^}]*)\}|"
                              r"true_computation=%?([\w\.\-]+), "
                              r"false_computation=%?([\w\.\-]+))"),
    "custom-call": re.compile(r"called_computations=\{([^}]*)\}"),
}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "while", "call", "conditional",
    # convert/broadcast always fuse into their consumers on TPU; XLA:CPU's
    # float-normalization inserts bf16<->f32 converts around every op,
    # which would double-count every dtype boundary as HBM traffic (and
    # mask dtype-narrowing optimizations like int8 KV caches)
    "convert", "broadcast",
}


def _dot_flops(instr: Instr, shapes: dict) -> int:
    out_numel = _shape_numel(instr.result_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.raw)
    if not m or not instr.operands:
        return 2 * out_numel
    lhs_type = shapes.get(instr.operands[0])
    if lhs_type is None:
        return 2 * out_numel
    dims = _shape_dims(lhs_type)
    k = 1
    if m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(dims):
                k *= dims[int(d)]
    return 2 * out_numel * k


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    by_kind: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        self.collective_operand_bytes += mult * other.collective_operand_bytes
        self.collective_wire_bytes += mult * other.collective_wire_bytes
        for k, v in other.by_kind.items():
            self.by_kind[k] += mult * v


def _collective_bytes(instr: Instr, kind: str, shapes: dict) -> tuple[float, float]:
    """(operand bytes, wire-model bytes per device)."""
    op_bytes = sum(_shape_bytes(shapes.get(o, "")) for o in instr.operands)
    res_bytes = _shape_bytes(instr.result_type)
    # ring-model wire traffic per device (n-1)/n ~ 1
    if kind == "all-gather":
        wire = max(res_bytes - op_bytes, 0)
    elif kind == "all-reduce":
        wire = 2 * op_bytes
    elif kind == "reduce-scatter":
        wire = op_bytes
    elif kind == "all-to-all":
        wire = op_bytes
    else:                                  # collective-permute
        wire = op_bytes
    return op_bytes, wire


def walk(comps: dict[str, Computation], entry: str,
         trips_by_depth: dict[int, int]) -> Costs:
    """Aggregate costs from the entry computation, multiplying while bodies
    by ``trips_by_depth[depth]`` (default 1)."""
    memo: dict[tuple[str, int], Costs] = {}

    def comp_cost(name: str, depth: int) -> Costs:
        key = (name, depth)
        if key in memo:
            return memo[key]
        c = Costs()
        comp = comps.get(name)
        if comp is None:
            memo[key] = c
            return c
        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "")
            if base in _COLLECTIVES:
                ob, wb = _collective_bytes(ins, base, comp.shapes)
                c.collective_operand_bytes += ob
                c.collective_wire_bytes += wb
                c.by_kind[base] += wb
                c.bytes += ob + _shape_bytes(ins.result_type)
                continue
            if ins.opcode == "while":
                m = _CALLED_RE["while"].search(ins.raw)
                if m:
                    body = comp_cost(m.group(2), depth + 1)
                    trip = trips_by_depth.get(depth, 1)
                    c.add(body, trip)
                    cond = comp_cost(m.group(1), depth + 1)
                    c.add(cond, trip)
                continue
            if ins.opcode == "fusion":
                m = _CALLED_RE["fusion"].search(ins.raw)
                inner_comp = comps.get(m.group(1)) if m else None
                if m:
                    inner = comp_cost(m.group(1), depth)
                    # fusions: flops from inside, bytes at the boundary
                    c.flops += inner.flops
                    c.collective_operand_bytes += inner.collective_operand_bytes
                    c.collective_wire_bytes += inner.collective_wire_bytes
                ob = sum(_shape_bytes(comp.shapes.get(o, ""))
                         for o in ins.operands)
                bytes_ = ob + _shape_bytes(ins.result_type)
                # in-place fusions (KV-cache updates etc.): a DUS/scatter
                # inside + an operand type that reappears in the result
                # means the buffer is aliased on TPU — the fusion touches
                # only the updated region, not the whole buffer.
                if inner_comp is not None:
                    upd_bytes = 0
                    for fi in inner_comp.instrs:
                        if (fi.opcode == "dynamic-update-slice"
                                and len(fi.operands) > 1):
                            upd_bytes += _shape_bytes(
                                inner_comp.shapes.get(fi.operands[1], ""))
                        elif fi.opcode == "scatter" and len(fi.operands) > 1:
                            upd_bytes += sum(_shape_bytes(
                                inner_comp.shapes.get(o, ""))
                                for o in fi.operands[1:])
                    if upd_bytes:
                        res_parts = [mm.group(0) for mm in
                                     _SHAPE_RE.finditer(ins.result_type)]
                        for o in ins.operands:
                            om = _SHAPE_RE.search(comp.shapes.get(o, ""))
                            if om and om.group(0) in res_parts:
                                res_parts.remove(om.group(0))
                                bytes_ -= 2 * _shape_bytes(om.group(0))
                        bytes_ = max(bytes_, 0) + 2 * upd_bytes
                c.bytes += bytes_
                continue
            if ins.opcode in ("call", "conditional", "async-start"):
                for mname in re.findall(r"(?:to_apply|true_computation|"
                                        r"false_computation)=%?([\w\.\-]+)",
                                        ins.raw):
                    c.add(comp_cost(mname, depth))
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.raw)
                if m:
                    branches = re.findall(r"%?([\w\.\-]+)", m.group(1))
                    if branches:          # max over branches would be exact;
                        c.add(comp_cost(branches[0], depth))
                continue
            if ins.opcode == "dynamic-slice":
                # reads only the slice (result) on TPU, not the full operand
                c.bytes += 2 * _shape_bytes(ins.result_type)
                c.flops += 0
                continue
            if ins.opcode == "dynamic-update-slice":
                # in-place on TPU (donated/aliased buffers): touches the
                # written region twice (read-modify-write), not the buffer
                upd = (_shape_bytes(comp.shapes.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else 0)
                c.bytes += 2 * upd
                continue
            if ins.opcode == "scatter":
                # in-place update: touches indices + updates twice
                upd = sum(_shape_bytes(comp.shapes.get(o, ""))
                          for o in ins.operands[1:])
                c.bytes += 2 * upd
                continue
            if ins.opcode == "gather":
                # reads the gathered elements (result), not the operand
                c.bytes += 2 * _shape_bytes(ins.result_type)
                continue
            if ins.opcode == "dot":
                c.flops += _dot_flops(ins, comp.shapes)
            elif ins.opcode == "convolution":
                # rough: 2 * out_numel * prod(kernel spatial dims) — models
                # here lower no convolutions, this is a safety net
                c.flops += 2 * _shape_numel(ins.result_type)
            elif ins.opcode not in _SKIP_BYTES_OPS:
                c.flops += _shape_numel(ins.result_type)
            if ins.opcode not in _SKIP_BYTES_OPS and ins.opcode != "fusion":
                ob = sum(_shape_bytes(comp.shapes.get(o, ""))
                         for o in ins.operands)
                c.bytes += ob + _shape_bytes(ins.result_type)
        memo[key] = c
        return c

    return comp_cost(entry, 0)


def analyze(hlo_text: str, trips_by_depth: dict[int, int]) -> dict:
    comps, entry = parse_hlo(hlo_text)
    costs = walk(comps, entry, trips_by_depth)
    return {
        "flops": costs.flops,
        "bytes": costs.bytes,
        "collective_operand_bytes": costs.collective_operand_bytes,
        "collective_wire_bytes": costs.collective_wire_bytes,
        "collective_by_kind": dict(costs.by_kind),
    }

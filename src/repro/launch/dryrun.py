import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory/cost/collective analysis.

The two lines above MUST run before any other import (jax locks the device
count at first init).  Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out runs/dryrun
"""
import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path # noqa: E402

import jax               # noqa: E402
import zstandard         # noqa: E402

from repro.launch import hlo_costs, specs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import ALL_SHAPES  # noqa: E402

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link


def shape_by_name(name: str):
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Path | None, save_hlo: bool = True,
             variant: dict | None = None) -> dict:
    variant = variant or {}
    shape = shape_by_name(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "variant": {k: v for k, v in variant.items() if v}}
    reason = specs.skip_reason(arch, shape)
    if reason:
        result["status"] = "skip"
        result["reason"] = reason
        return result
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models import sharding as _shd
    with mesh, _shd.mesh_context(mesh, seq_shard=variant.get("seq_shard", False),
                                 moe_ep=variant.get("moe_ep", False)):
        # build INSIDE the context: param shardings read the moe_ep flag
        cell = specs.build_cell(arch, shape, mesh,
                                kv_int8=variant.get("kv_int8", False),
                                ga=variant.get("ga"),
                                moe_ep=variant.get("moe_ep", False))
        jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    print(compiled.memory_analysis())        # proves it fits
    ca = compiled.cost_analysis()
    print({k: ca.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    parsed = hlo_costs.analyze(hlo, cell.trips_by_depth)
    n_chips = 512 if multi_pod else 256

    n_active = cell.cfg.active_param_count()
    if shape.kind == "train":
        model_flops = 6 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        model_flops = 2 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2 * n_active * shape.global_batch          # 1 token

    # Per-device terms: parsed costs are for the per-device SPMD module.
    compute_s = parsed["flops"] / PEAK_FLOPS
    memory_s = parsed["bytes"] / HBM_BW
    collective_s = parsed["collective_wire_bytes"] / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])[0]
    result.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            peak_bytes=(ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        ),
        xla_cost=dict(flops=ca.get("flops"),
                      bytes_accessed=ca.get("bytes accessed")),
        parsed=parsed,
        trips=cell.trips_by_depth,
        model_flops_total=model_flops,
        model_flops_per_chip=model_flops / n_chips,
        roofline=dict(compute_s=compute_s, memory_s=memory_s,
                      collective_s=collective_s, dominant=dominant,
                      useful_flops_ratio=(model_flops / n_chips)
                      / max(parsed["flops"], 1.0)),
    )
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        stem = f"{arch}__{shape_name}__{mesh_name}"
        with open(out_dir / f"{stem}.json", "w") as f:
            json.dump(result, f, indent=1)
        if save_hlo:
            cctx = zstandard.ZstdCompressor(level=6)
            (out_dir / f"{stem}.hlo.zst").write_bytes(
                cctx.compress(hlo.encode()))
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "pod", "both"],
                    default="single",
                    help="production mesh topology to compile against: "
                         "single (16x16, one pod), pod (2x16x16, two "
                         "pods), or both.  Training/compile-cell meshes "
                         "only — the *serving* mesh is chosen at engine "
                         "construction (ServeEngine(mesh=...), DESIGN.md "
                         "§11) and benchmarked via bench_decode "
                         "--sharded --mesh DATAxMODEL")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--moe-ep", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--ga", type=int, default=None)
    args = ap.parse_args()
    variant = {"kv_int8": args.kv_int8, "moe_ep": args.moe_ep,
               "seq_shard": args.seq_shard, "ga": args.ga}
    out = Path(args.out)
    meshes = {"single": [False], "pod": [True], "both": [False, True]}[args.mesh]
    from repro import configs as _configs
    cells = (specs.all_cells() if args.all
             else [(args.arch, shape_by_name(args.shape))])
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            try:
                r = run_cell(arch, shape.name if hasattr(shape, "name")
                             else shape, mp, out, save_hlo=not args.no_hlo,
                             variant=variant)
                status = r["status"]
                extra = (f" dominant={r['roofline']['dominant']}"
                         if status == "ok" else f" ({r.get('reason', '')})")
                print(f"[{arch} x {shape.name if hasattr(shape, 'name') else shape}"
                      f" x {'2x16x16' if mp else '16x16'}] {status}{extra}",
                      flush=True)
            except Exception:
                failures += 1
                traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

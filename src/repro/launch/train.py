"""Training driver (CPU-runnable at reduced scale; pjit-sharded on real
meshes).

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --smoke --steps 50 --batch 8 --seq 256 --ckpt-dir runs/train
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt import checkpoint as ckpt
from repro.data import DataConfig, Prefetcher, SyntheticLM
from repro.models import model as M
from repro.runtime import Supervisor, SupervisorConfig
from repro.train import AdamWConfig, init_state
from repro.train.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="runs/train")
    ap.add_argument("--compress-ckpt", action="store_true")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    ocfg = AdamWConfig(lr=args.lr, warmup_steps=max(10, args.steps // 20),
                       total_steps=args.steps)
    data = SyntheticLM(DataConfig(batch_size=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size))
    step_fn = jax.jit(make_train_step(cfg, ocfg, grad_accum=args.grad_accum),
                      donate_argnums=(0, 1))

    def make_state():
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = init_state(ocfg, params)
        return {"params": params, "opt": opt}, {}

    def train_one(state, step_idx):
        batch = data.next_batch()
        b = {"tokens": jnp.asarray(batch["tokens"])}
        params, opt, metrics = step_fn(state["params"], state["opt"], b)
        metrics = {k: float(v) for k, v in metrics.items()}
        return {"params": params, "opt": opt}, metrics

    sup = Supervisor(
        SupervisorConfig(ckpt_dir=args.ckpt_dir, save_every=args.save_every,
                         max_steps=args.steps,
                         compress_ckpt=args.compress_ckpt),
        make_state=make_state, step_fn=train_one,
        data_state=data.state_dict, restore_data=data.load_state_dict)
    state, history = sup.run()
    for h in history[::max(1, args.log_every)]:
        print(json.dumps({k: round(v, 4) if isinstance(v, float) else v
                          for k, v in h.items()}))
    if history:
        print(f"final loss: {history[-1]['loss']:.4f} "
              f"(first: {history[0]['loss']:.4f})")


if __name__ == "__main__":
    main()

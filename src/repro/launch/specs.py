"""Per-(arch x shape) dry-run cell definitions: abstract input specs,
applicability (skips), lowering target (train/prefill/decode), shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import model as M
from repro.models import sharding as sh
from repro.models.config import (ALL_SHAPES, CHUNK, ModelConfig, ShapeConfig)
from repro.train import AdamWConfig, optimizer
from repro.train.train_step import make_train_step

N_PATCH = 256          # vlm image-token prefix inside the sequence budget

# archs whose parameter volume needs int8 optimizer state to fit (DESIGN §4)
INT8_OPT = {"command-r-plus-104b", "dbrx-132b", "kimi-k2-1t-a32b"}

# microbatch counts for train_4k (bounds the remat residual stack to one
# microbatch; production config per arch) and grad-accumulator dtypes
GRAD_ACCUM = {"command-r-plus-104b": 16, "kimi-k2-1t-a32b": 8,
              "dbrx-132b": 8, "minitron-8b": 4, "minitron-4b": 4,
              "recurrentgemma-9b": 4, "paligemma-3b": 2, "qwen3-1.7b": 2}
BF16_ACCUM = {"command-r-plus-104b", "kimi-k2-1t-a32b"}

FULL_ATTENTION_ARCHS = {
    "qwen3-1.7b", "minitron-4b", "minitron-8b", "command-r-plus-104b",
    "paligemma-3b", "dbrx-132b", "kimi-k2-1t-a32b",
}


def skip_reason(arch: str, shape: ShapeConfig) -> str | None:
    cfg = configs.get_config(arch)
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only: no decode step"
    if shape.name == "long_500k" and arch in FULL_ATTENTION_ARCHS:
        return "pure full attention: 500k decode needs sub-quadratic arch"
    if shape.name == "long_500k" and cfg.is_encoder:
        return "encoder-only"
    return None


def opt_config(arch: str) -> AdamWConfig:
    return AdamWConfig(state_dtype="int8" if arch in INT8_OPT else "float32")


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract (ShapeDtypeStruct) model inputs for a cell."""
    gb, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((gb, 1), i32)}
    if cfg.frontend == "audio":
        return {"frame_embeds": jax.ShapeDtypeStruct((gb, s, cfg.d_model),
                                                     jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((gb, s), i32)}
    batch = {"tokens": jax.ShapeDtypeStruct(
        (gb, s - (N_PATCH if cfg.frontend == "vision" else 0)), i32)}
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (gb, N_PATCH, cfg.d_model), jnp.bfloat16)
    return batch


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


def abstract_opt_state(ocfg: AdamWConfig, params):
    return jax.eval_shape(lambda p: optimizer.init_state(ocfg, p), params)


def opt_shardings(mesh: Mesh, opt_state, param_shardings) -> Any:
    """Moments inherit the param shardings exactly (ZeRO via FSDP).  Q8
    moments: q has the param's shape -> same sharding; the per-block scale
    drops the last (blocked) axis's entry."""

    def map_moment(ps_tree, m_tree):
        def one(ps, leaf):
            if isinstance(leaf, optimizer.Q8):
                spec = ps.spec
                # scale has the param's rank (last axis = blocks) — reuse
                # the param spec, dropping entries that no longer divide
                return optimizer.Q8(
                    q=NamedSharding(mesh, sh.fit_spec(spec, leaf.q.shape,
                                                      mesh)),
                    scale=NamedSharding(mesh, sh.fit_spec(
                        spec, leaf.scale.shape, mesh)))
            return ps
        return jax.tree.map(one, ps_tree, m_tree,
                            is_leaf=lambda x: isinstance(x, optimizer.Q8))

    return {
        "step": NamedSharding(mesh, P()),
        "m": map_moment(param_shardings, opt_state["m"]),
        "v": map_moment(param_shardings, opt_state["v"]),
    }


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    fn: Any                    # function to lower
    args: tuple                # abstract args
    in_shardings: tuple
    donate: tuple
    trips_by_depth: dict
    out_shardings: Any = None


def build_cell(arch: str, shape: ShapeConfig, mesh: Mesh,
               kv_int8: bool = False, ga: int | None = None,
               moe_ep: bool = False) -> Cell:
    cfg = configs.get_config(arch)
    if kv_int8:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params = abstract_params(cfg)
    p_sh = sh.param_shardings(mesh, params)
    b_spec = batch_specs(cfg, shape)
    b_sh = sh.batch_shardings(mesh, b_spec)
    chunks = max(1, shape.seq_len // CHUNK)

    if shape.kind == "train":
        import jax.numpy as _jnp
        ocfg = opt_config(arch)
        opt_state = abstract_opt_state(ocfg, params)
        o_sh = opt_shardings(mesh, opt_state, p_sh)
        ga = ga if ga is not None else GRAD_ACCUM.get(arch, 1)
        step = make_train_step(
            cfg, ocfg, grad_accum=ga,
            accum_dtype=_jnp.bfloat16 if arch in BF16_ACCUM else _jnp.float32)
        if ga == 1:
            trips = {0: cfg.n_cycles, 1: chunks, 2: CHUNK}
        else:
            # microbatch scan shifts every loop one depth down
            trips = {0: ga, 1: cfg.n_cycles, 2: chunks, 3: CHUNK}
        return Cell(arch, shape, cfg, step,
                    (params, opt_state, b_spec),
                    (p_sh, o_sh, b_sh), donate=(0, 1),
                    trips_by_depth=trips)

    if shape.kind == "prefill":
        def fn(p, b):
            return M.prefill(cfg, p, b)
        # NOTE: forcing cache out_shardings (seq-sharded for kv-head counts
        # that don't divide the model axis) trips GSPMD's replicate-fallback
        # resharding INSIDE the layer scan (measured: command-r collective
        # term 29.6s -> 1011s).  The natural layout (batch-sharded,
        # kv-heads replicated when indivisible) is kept; the int8-KV config
        # (§Perf) halves its footprint where it matters.
        return Cell(arch, shape, cfg, fn, (params, b_spec), (p_sh, b_sh),
                    donate=(),
                    trips_by_depth={0: cfg.n_cycles, 1: chunks, 2: CHUNK})

    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    c_sh = sh.cache_shardings(mesh, cache)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def fn(p, c, b, pos):
        return M.decode_step(cfg, p, c, b["tokens"], pos)

    return Cell(arch, shape, cfg, fn, (params, cache, b_spec, pos),
                (p_sh, c_sh, b_sh, NamedSharding(mesh, P())), donate=(1,),
                trips_by_depth={0: cfg.n_cycles, 1: 1, 2: 1})


def all_cells() -> list[tuple[str, ShapeConfig]]:
    out = []
    for arch in configs.all_arch_ids():
        for shape in ALL_SHAPES:
            out.append((arch, shape))
    return out

"""APack core: the paper's contribution as a composable library."""
from .tables import ApackTable, find_table, histogram, table_for, uniform_table
from .format import CompressedTensor, compress, decompress, estimate_bits
from . import ac_golden, baselines, byteplane, distributions, quant

__all__ = [
    "ApackTable", "find_table", "histogram", "table_for", "uniform_table",
    "CompressedTensor", "compress", "decompress", "estimate_bits",
    "ac_golden", "baselines", "byteplane", "distributions", "quant",
]

"""Synthetic value distributions matching the paper's workload statistics.

The paper profiles real int8-quantized models (Table II).  We cannot ship
those weights; instead these generators reproduce the *distribution shapes*
the paper identifies (Fig. 2 + §VII-A discussion), and the benchmark suite
additionally profiles the real weights/activations of this repo's own model
zoo (the 10 assigned architectures).
"""
from __future__ import annotations

import numpy as np


def gaussian_weights(n: int, sigma: float = 12.0, seed: int = 0) -> np.ndarray:
    """Symmetric-quantized conv/linear weights: int8 two's complement view —
    bimodal near 0 and 255 (paper Fig. 2)."""
    rng = np.random.default_rng(seed)
    w = np.clip(np.round(rng.normal(0.0, sigma, n)), -128, 127).astype(np.int64)
    return (w & 0xFF).astype(np.uint8)


def noisy_weights(n: int, seed: int = 0) -> np.ndarray:
    """TorchVision-style 'noisy' quantization: full range used, heavy
    near-zero mass plus uniform noise floor (paper §VII-A)."""
    rng = np.random.default_rng(seed)
    core = np.clip(np.round(rng.normal(0.0, 25.0, int(n * 0.85))), -128, 127)
    noise = rng.integers(-128, 128, n - core.size)
    w = np.concatenate([core, noise]).astype(np.int64)
    rng.shuffle(w)
    return (w & 0xFF).astype(np.uint8)


def relu_activations(n: int, sparsity: float = 0.5, scale: float = 20.0,
                     seed: int = 0) -> np.ndarray:
    """Post-ReLU uint8 activations: ``sparsity`` exact zeros + exponential
    tail (the paper's 'high sparsity ... ReLU' case)."""
    rng = np.random.default_rng(seed)
    a = rng.exponential(scale, n)
    a = np.where(rng.random(n) < sparsity, 0.0, a)
    return np.clip(np.round(a), 0, 255).astype(np.uint8)


def pruned_weights(n: int, sparsity: float = 0.85, sigma: float = 18.0,
                   seed: int = 0) -> np.ndarray:
    """Eyeriss-style pruned model weights: mostly zeros + gaussian survivors."""
    rng = np.random.default_rng(seed)
    w = np.clip(np.round(rng.normal(0.0, sigma, n)), -128, 127).astype(np.int64)
    w = np.where(rng.random(n) < sparsity, 0, w)
    return (w & 0xFF).astype(np.uint8)


def pact4_weights(n: int, seed: int = 0) -> np.ndarray:
    """4-bit PACT-quantized weights in an 8-bit container's low nibble space
    (paper's ResNet18-PACT case: int4 layers)."""
    rng = np.random.default_rng(seed)
    w = np.clip(np.round(rng.normal(0.0, 2.2, n)), -8, 7).astype(np.int64)
    return (w & 0xF).astype(np.uint8)


PAPER_LIKE = {
    "gaussian_weights": gaussian_weights,
    "noisy_weights": noisy_weights,
    "relu_activations": relu_activations,
    "pruned_weights": pruned_weights,
    "pact4_weights": pact4_weights,
}

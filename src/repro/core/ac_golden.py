"""Bit-exact pure-Python reference of the APack arithmetic codec.

This is the *contract*: ``kernels/ref.py`` (vectorized jnp) and the Pallas
kernels must produce byte-identical streams.  It implements the paper's
finite-precision arithmetic coder (Section V): 16-bit HI/LO windows, 10-bit
probability counts, common-prefix emission and underflow (UBC) handling —
i.e. the classic Witten–Neal–Cleary / Nelson integer coder the paper says it
is "inspired by", with the (symbol, offset) split of Section IV: only the
symbol index is arithmetically coded, the offset is stored verbatim.

Bitstream convention (fixed across the whole codebase):
  * a stream is a sequence of bits; bit ``i`` lives in 32-bit word ``i // 32``
    at bit position ``i % 32`` (LSB-first within a word);
  * multi-bit fields are appended LSB-first.

The paper emits offsets MSB-first into its hardware shift registers; the
order within the offset field is an internal convention with no effect on
size — we pick LSB-first so that a k-bit read returns the field directly.
"""
from __future__ import annotations

from typing import Sequence

CODE_BITS = 16
TOP = (1 << CODE_BITS) - 1          # 0xFFFF
HALF = 1 << (CODE_BITS - 1)        # 0x8000
QUARTER = 1 << (CODE_BITS - 2)     # 0x4000
THREEQ = HALF + QUARTER            # 0xC000
PCOUNT_BITS = 10
PCOUNT_TOTAL = 1 << PCOUNT_BITS    # 1024
# Max renormalization shifts after one symbol: post-renorm range > QUARTER,
# a min-probability (1/1024) symbol shrinks it to >= 16, and 16 << k > QUARTER
# needs k = 11.  We use 12 everywhere (golden asserts the bound holds).
MAX_RENORM = 12
# Pending-underflow-bit cap; exceeding it trips stored-mode (prob ~2^-24 per
# stream on real data — the golden encoder raises so tests would catch it).
MAX_PENDING = 24


class BitWriter:
    def __init__(self) -> None:
        self.bits: list[int] = []

    def put_bit(self, b: int) -> None:
        self.bits.append(b & 1)

    def put_bits(self, value: int, n: int) -> None:
        for i in range(n):                      # LSB-first
            self.bits.append((value >> i) & 1)

    def __len__(self) -> int:
        return len(self.bits)

    def to_words(self) -> list[int]:
        words = [0] * ((len(self.bits) + 31) // 32)
        for i, b in enumerate(self.bits):
            if b:
                words[i // 32] |= 1 << (i % 32)
        return words


class BitReader:
    def __init__(self, words: Sequence[int], nbits: int | None = None) -> None:
        self.words = list(words)
        self.pos = 0
        self.nbits = nbits if nbits is not None else 32 * len(self.words)

    def get_bit(self) -> int:
        # Past-the-end reads return 0 (decoder may over-read its CODE window
        # near stream end; the encoder's termination guarantees correctness).
        if self.pos >= self.nbits:
            self.pos += 1
            return 0
        b = (self.words[self.pos // 32] >> (self.pos % 32)) & 1
        self.pos += 1
        return b

    def get_bits(self, n: int) -> int:
        v = 0
        for i in range(n):                      # LSB-first
            v |= self.get_bit() << i
        return v


def encode_stream(values: Sequence[int], table) -> tuple[list[int], int, list[int], int]:
    """Encode one stream of uint values.

    Args:
      values: uint values, each in ``[0, 2^table.bits)``.
      table: an ``ApackTable`` (see core/tables.py) with fields
        ``v_min[17]`` (sentinel-terminated ascending), ``ol[16]``,
        ``cum[17]`` (cumulative probability counts, cum[16] == 1024).

    Returns:
      (sym_words, sym_bits, ofs_words, ofs_bits)
    """
    sym = BitWriter()
    ofs = BitWriter()
    low, high, pending = 0, TOP, 0

    def emit(bit: int) -> None:
        nonlocal pending
        sym.put_bit(bit)
        inv = bit ^ 1
        for _ in range(pending):
            sym.put_bit(inv)
        pending = 0

    for v in values:
        s = table.symbol_of(int(v))
        if table.cum[s + 1] <= table.cum[s]:
            raise ValueError(f"value {v} maps to zero-probability symbol {s}")
        ofs.put_bits(int(v) - table.v_min[s], table.ol[s])
        rng = high - low + 1
        high = low + (rng * table.cum[s + 1]) // PCOUNT_TOTAL - 1
        low = low + (rng * table.cum[s]) // PCOUNT_TOTAL
        shifts = 0
        while True:
            if high < HALF:
                emit(0)
            elif low >= HALF:
                emit(1)
                low -= HALF
                high -= HALF
            elif low >= QUARTER and high < THREEQ:
                pending += 1
                if pending > MAX_PENDING:
                    raise OverflowError("pending underflow bits exceeded cap")
                low -= QUARTER
                high -= QUARTER
            else:
                break
            low = low * 2
            high = high * 2 + 1
            shifts += 1
            assert shifts <= MAX_RENORM, "renormalization bound violated"

    # Termination (WNC): disambiguate the final quarter.
    pending += 1
    if low < QUARTER:
        emit(0)
    else:
        emit(1)
    return sym.to_words(), len(sym), ofs.to_words(), len(ofs)


def decode_stream(sym_words: Sequence[int], ofs_words: Sequence[int],
                  n: int, table, sym_bits: int | None = None,
                  ofs_bits: int | None = None) -> list[int]:
    """Decode ``n`` values from a (symbol, offset) stream pair."""
    sr = BitReader(sym_words, sym_bits)
    orr = BitReader(ofs_words, ofs_bits)
    low, high = 0, TOP
    code = 0
    for _ in range(CODE_BITS):                  # stream order = MSB of CODE first
        code = (code << 1) | sr.get_bit()
    out: list[int] = []
    for _ in range(n):
        rng = high - low + 1
        cum = ((code - low + 1) * PCOUNT_TOTAL - 1) // rng
        s = table.symbol_of_cum(cum)
        out.append(table.v_min[s] + orr.get_bits(table.ol[s]))
        high = low + (rng * table.cum[s + 1]) // PCOUNT_TOTAL - 1
        low = low + (rng * table.cum[s]) // PCOUNT_TOTAL
        while True:
            if high < HALF:
                pass
            elif low >= HALF:
                low -= HALF
                high -= HALF
                code -= HALF
            elif low >= QUARTER and high < THREEQ:
                low -= QUARTER
                high -= QUARTER
                code -= QUARTER
            else:
                break
            low = low * 2
            high = high * 2 + 1
            code = (code << 1) | sr.get_bit()
    return out

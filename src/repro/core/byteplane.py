"""Lossless APack compression of floating-point tensors via byte planes.

Beyond-paper extension used for checkpoint + optimizer-state compression:
bf16/fp32 tensors split into byte planes; the exponent-carrying plane of
trained weights is highly skewed (few distinct exponents), so APack's
16-range coder compresses it well, while mantissa planes are near-uniform
and fall back to stored mode automatically.  Exactly lossless — bits in,
bits out.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import format as fmt
from .tables import table_for


@dataclasses.dataclass
class CompressedPlanes:
    shape: tuple[int, ...]
    dtype: str
    planes: list[fmt.CompressedTensor]

    @property
    def total_bits(self) -> int:
        return sum(p.total_bits for p in self.planes)

    @property
    def original_bits(self) -> int:
        return sum(p.original_bits for p in self.planes)

    def ratio(self) -> float:
        return self.original_bits / max(self.total_bits, 1)


def _codec(backend: str):
    """'golden' = pure-Python reference; 'jnp' = vectorized ref codec
    (bit-identical, ~1000x faster — used for checkpoint-sized leaves)."""
    if backend == "golden":
        return fmt.compress, fmt.decompress
    from repro.kernels import fastpath          # late import: no core->kernels cycle
    return fastpath.compress_np, fastpath.decompress_np


def _plane_entropy(plane: np.ndarray) -> float:
    h = np.bincount(plane[:2 ** 20], minlength=256).astype(np.float64)
    p = h[h > 0] / h[h > 0].sum()
    return float(-(p * np.log2(p)).sum())


def compress_float(x: np.ndarray,
                   elems_per_stream: int = fmt.DEFAULT_ELEMS_PER_STREAM,
                   backend: str = "jnp",
                   table_mode: str = "activation") -> CompressedPlanes:
    """``table_mode="activation"`` (default) profiles a bounded sample per
    plane and keeps the §VI empty-range slack — right for large tensors
    where profiling everything is too slow.  ``table_mode="weight"``
    profiles the *full* plane and uses the paper's weight-mode heuristic
    (no slack needed: every byte that will ever be encoded is in the
    histogram) — right for small, fully-known tensors such as recurrent
    decode-state snapshots."""
    if table_mode not in ("activation", "weight"):
        raise ValueError(f"table_mode must be activation|weight, "
                         f"got {table_mode!r}")
    arr = np.asarray(x)
    comp, _ = _codec(backend)
    raw = arr.view(np.uint8).reshape(arr.size, arr.dtype.itemsize)
    planes = []
    for b in range(arr.dtype.itemsize):
        plane = np.ascontiguousarray(raw[:, b])
        if _plane_entropy(plane) > 7.5:
            # near-uniform (mantissa) plane: skip the coder, store verbatim
            planes.append(_stored_plane(plane, elems_per_stream))
            continue
        if table_mode == "weight":
            table = table_for(plane, bits=8, is_activation=False)
        else:
            # bounded sample; stealing keeps unseen bytes encodable
            table = table_for(plane[:2 ** 20], bits=8, is_activation=True)
        planes.append(comp(plane, table, bits=8,
                           elems_per_stream=elems_per_stream))
    return CompressedPlanes(shape=tuple(arr.shape), dtype=str(arr.dtype),
                            planes=planes)


def _stored_plane(plane: np.ndarray,
                  elems_per_stream: int) -> fmt.CompressedTensor:
    """All-streams-stored container (verbatim bit-pack, no AC)."""
    import jax.numpy as jnp
    from repro.kernels import ref as _ref
    from repro.core.tables import uniform_table
    flat = plane.reshape(-1).astype(np.int64)
    streams, n_valid = fmt.split_streams(flat, elems_per_stream)
    # apack: allow-transfer(host codec utility: raw-plane packing runs at
    # calibration/seal/spill events, never inside the decode step)
    packed = np.asarray(_ref.pack_raw(jnp.asarray(streams),
                                      streams.shape[1], 8)).astype(np.uint32)
    s, e = streams.shape
    return fmt.CompressedTensor(
        shape=tuple(plane.shape), bits=8, table=uniform_table(),
        elems_per_stream=elems_per_stream, n_valid=n_valid,
        sym_plane=np.zeros((0, s), np.uint32), ofs_plane=packed,
        sym_bits=np.zeros(s, np.int32), ofs_bits=np.full(s, e * 8, np.int32),
        stored=np.ones(s, bool))


def decompress_float(cp: CompressedPlanes, backend: str = "jnp") -> np.ndarray:
    _, decomp = _codec(backend)
    cols = [decomp(p).reshape(-1, 1) for p in cp.planes]
    raw = np.concatenate(cols, axis=1)
    return raw.reshape(-1).view(jnp_like_dtype(cp.dtype)).reshape(cp.shape)


def jnp_like_dtype(name: str):
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)

"""Fixed-point quantization utilities.

APack (the paper) operates on fixed-point quantized tensors: the value space
is ``[0, 2^B - 1]`` (uint view).  Signed int8 tensors are handled through a
bias-by-128 view so that small negative values land near 255 and small
positive values near 0 — exactly the bimodal CDF shape of paper Fig. 2.

Everything here is pure JAX/numpy and differentiability is not required
(inference-side quantization, gradient compression uses straight
quant/dequant with error feedback implemented in ``train/compress_grads``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QuantParams",
    "quantize_symmetric",
    "dequantize_symmetric",
    "quantize_affine",
    "dequantize_affine",
    "to_unsigned",
    "from_unsigned",
]


@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Metadata needed to invert a quantization."""

    scale: jax.Array          # broadcastable against the tensor
    zero_point: jax.Array     # same; 0 for symmetric
    bits: int = 8
    signed: bool = True
    axis: int | None = None   # per-channel axis, None = per-tensor


def _absmax(x: jax.Array, axis: int | None) -> jax.Array:
    if axis is None:
        return jnp.max(jnp.abs(x))
    red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    return jnp.max(jnp.abs(x), axis=red, keepdims=True)


def quantize_symmetric(x: jax.Array, bits: int = 8, axis: int | None = None):
    """Symmetric signed quantization to ``bits`` (stored in int8/int16)."""
    qmax = 2 ** (bits - 1) - 1
    amax = _absmax(x, axis)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int16
    return q.astype(dtype), QuantParams(scale=scale, zero_point=jnp.zeros_like(scale),
                                        bits=bits, signed=True, axis=axis)


def dequantize_symmetric(q: jax.Array, params: QuantParams) -> jax.Array:
    return q.astype(jnp.float32) * params.scale


def quantize_affine(x: jax.Array, bits: int = 8, axis: int | None = None):
    """Affine (asymmetric) quantization to unsigned ``bits``."""
    qmax = 2 ** bits - 1
    if axis is None:
        lo, hi = jnp.min(x), jnp.max(x)
    else:
        red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        lo = jnp.min(x, axis=red, keepdims=True)
        hi = jnp.max(x, axis=red, keepdims=True)
    scale = jnp.maximum(hi - lo, 1e-12) / qmax
    zp = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(x / scale) + zp, 0, qmax)
    dtype = jnp.uint8 if bits <= 8 else jnp.uint16
    return q.astype(dtype), QuantParams(scale=scale, zero_point=zp, bits=bits,
                                        signed=False, axis=axis)


def dequantize_affine(q: jax.Array, params: QuantParams) -> jax.Array:
    return (q.astype(jnp.float32) - params.zero_point) * params.scale


def to_unsigned(q, bits: int = 8):
    """Two's-complement reinterpretation: signed ``q`` -> uint value space.

    int8 ``v`` maps to ``v & 0xFF``: small positives stay near 0, small
    negatives land near 2^bits - 1 (paper Fig. 2's bimodal shape).  Works for
    numpy and jax arrays.
    """
    mask = (1 << bits) - 1
    if isinstance(q, np.ndarray):
        return (q.astype(np.int64) & mask).astype(np.uint16 if bits > 8 else np.uint8)
    return (q.astype(jnp.int32) & mask).astype(jnp.uint16 if bits > 8 else jnp.uint8)


def from_unsigned(u, bits: int = 8, signed: bool = True):
    """Inverse of :func:`to_unsigned`."""
    if isinstance(u, np.ndarray):
        v = u.astype(np.int64)
        if signed:
            half = 1 << (bits - 1)
            v = np.where(v >= half, v - (1 << bits), v)
        return v.astype(np.int8 if bits <= 8 else np.int16) if signed else u
    v = u.astype(jnp.int32)
    if signed:
        half = 1 << (bits - 1)
        v = jnp.where(v >= half, v - (1 << bits), v)
        return v.astype(jnp.int8 if bits <= 8 else jnp.int16)
    return u

"""APack symbol/probability-count table generation (paper Section VI).

``find_table`` is the faithful reproduction of the paper's Listing 1:
initialize the 16 value ranges uniformly over ``[0, 2^bits)``, then a
recursive local search slides range boundaries (``v_min``) one step at a
time, scoring candidates with the entropy-estimated footprint
(``encoded_size``), recursing (DEPTH_MAX=2) on the neighbours (distance 1) of
a moved entry, and repeating whole rounds until the improvement over a round
drops below 1% (THRESHOLD=0.99).

After the boundaries are fixed, the 10-bit probability-count budget (1024)
is distributed proportionally to range frequencies.  For activations, a
post-pass "steals" one count for every empty range so values never seen
during profiling remain encodable (paper §VI "Final Adjustment for
Activations").
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .ac_golden import PCOUNT_TOTAL

N_SYMBOLS = 16
DEPTH_MAX = 2
THRESHOLD = 0.99
TABLE_OVERHEAD_BITS = 298 * 8   # paper §IV: range+probability tables = 298 bytes


@dataclasses.dataclass(frozen=True)
class ApackTable:
    """Symbol + probability count table (paper Table I).

    Attributes:
      v_min: ascending starts of the 16 ranges, with a sentinel
        ``v_min[16] == 2^bits`` (so ``v_max[i] = v_min[i+1] - 1``).
      ol:   offset bit-length per range, ``ceil(log2(range_size))``.
      cum:  cumulative probability counts, ``cum[0] == 0``,
        ``cum[16] == 1024``; symbol ``s`` owns ``[cum[s], cum[s+1])``.
      bits: input value bit-width.
      mode: which partitioning heuristic produced the table — "weight"
        (paper §IV: exact histogram, empty ranges get zero counts) or
        "activation" (§VI final adjustment: empty ranges keep one stolen
        count so unprofiled values stay encodable).
    """

    v_min: tuple[int, ...]
    ol: tuple[int, ...]
    cum: tuple[int, ...]
    bits: int = 8
    mode: str = "weight"

    def symbol_of(self, v: int) -> int:
        """Largest s with v_min[s] <= v (ranges are contiguous + exhaustive)."""
        # 16 entries: linear scan is what the HW comparator array does.
        s = 0
        for i in range(N_SYMBOLS):
            if self.v_min[i] <= v:
                s = i
        return s

    def symbol_of_cum(self, cum_val: int) -> int:
        s = 0
        for i in range(N_SYMBOLS):
            if self.cum[i] <= cum_val:
                s = i
        return s

    def as_arrays(self):
        return (np.asarray(self.v_min, np.int32), np.asarray(self.ol, np.int32),
                np.asarray(self.cum, np.int32))


def _ol_bits(size: int) -> int:
    return max(0, math.ceil(math.log2(size))) if size > 1 else 0


def histogram(values: np.ndarray, bits: int = 8) -> np.ndarray:
    """Value histogram h[0 .. 2^bits - 1] (paper: 2^8 buckets)."""
    return np.bincount(np.asarray(values).reshape(-1).astype(np.int64),
                       minlength=1 << bits).astype(np.int64)


_OL_LUT = np.array([_ol_bits(s) for s in range(0, (1 << 16) + 1)], np.float64)


def _encoded_size_csum(csum: np.ndarray, total: int, v_min: list[int],
                       bits: int) -> float:
    """O(16) scoring given a precomputed histogram cumsum."""
    if total == 0:
        return 0.0
    bounds = np.asarray(list(v_min) + [1 << bits])
    cnt = (csum[bounds[1:]] - csum[bounds[:-1]]).astype(np.float64)
    ol = _OL_LUT[bounds[1:] - bounds[:-1]]
    nz = cnt > 0
    p = cnt[nz] / total
    return float(np.sum(cnt[nz] * (-np.log2(p) + ol[nz])))


def encoded_size(hist: np.ndarray, v_min: list[int], bits: int = 8) -> float:
    """Entropy-estimated footprint in bits for a boundary configuration.

    Per range r: count_r * (-log2 p_r) symbol bits (ideal AC) plus
    count_r * OL_r verbatim offset bits.  This is the paper's
    ``encoded_size`` scoring function ("calculating the entropy of each
    range").
    """
    csum = np.concatenate([[0], np.cumsum(hist)])
    return _encoded_size_csum(csum, int(hist.sum()), v_min, bits)


def _valid(v_min: list[int], bits: int) -> bool:
    if v_min[0] != 0:
        return False
    for i in range(1, N_SYMBOLS):
        if v_min[i] <= v_min[i - 1]:
            return False
    return v_min[-1] < (1 << bits)


def _search(csum: np.ndarray, total: int, v_min: list[int], minsize: float,
            depth: int, around: int, bits: int, memo: dict):
    """Paper Listing 1 ``search()``: slide each eligible v_min in both
    directions, evaluating every position; recurse on neighbours while
    depth < DEPTH_MAX."""
    best_v, best_size = list(v_min), minsize

    def score(cfg: list[int]) -> float:
        key = tuple(cfg)
        s = memo.get(key)
        if s is None:
            s = _encoded_size_csum(csum, total, cfg, bits)
            memo[key] = s
        return s

    for i in range(1, N_SYMBOLS):
        if around >= 1 and abs(i - around) != 1:
            continue
        for delta in (-1, +1):
            cand = list(v_min)
            while True:
                cand = list(cand)
                cand[i] += delta
                if not _valid(cand, bits):
                    break
                if depth < DEPTH_MAX:
                    sub_v, sub_size = _search(csum, total, cand, best_size,
                                              depth + 1, i, bits, memo)
                    if sub_size < best_size:
                        best_v, best_size = sub_v, sub_size
                size = score(cand)
                if size < best_size:
                    best_v, best_size = list(cand), size
    return best_v, best_size


def _assign_counts(hist: np.ndarray, v_min: list[int], bits: int,
                   steal_for_empty: bool) -> list[int]:
    """Distribute the 1024-count budget proportionally to range frequencies.

    Largest-remainder rounding; every non-empty range gets >= 1 count; with
    ``steal_for_empty`` every empty range also gets 1 (stolen from the
    largest entry) so unseen values stay encodable.
    """
    csum = np.concatenate([[0], np.cumsum(hist)])
    bounds = list(v_min) + [1 << bits]
    counts = np.array([int(csum[bounds[r + 1]] - csum[bounds[r]])
                       for r in range(N_SYMBOLS)], dtype=np.float64)
    total = counts.sum()
    if total == 0:
        counts[:] = 1.0
        total = counts.sum()
    raw = counts * PCOUNT_TOTAL / total
    alloc = np.floor(raw).astype(np.int64)
    # every non-empty range needs >= 1
    alloc = np.where((counts > 0) & (alloc == 0), 1, alloc)
    if steal_for_empty:
        alloc = np.where(alloc == 0, 1, alloc)
    # fix the sum to exactly PCOUNT_TOTAL via largest remainders
    diff = PCOUNT_TOTAL - int(alloc.sum())
    order = np.argsort(-(raw - np.floor(raw)))
    i = 0
    while diff != 0:
        idx = order[i % N_SYMBOLS]
        if diff > 0:
            alloc[idx] += 1
            diff -= 1
        else:
            floor_ = 1 if (counts[idx] > 0 or steal_for_empty) else 0
            if alloc[idx] > floor_:
                alloc[idx] -= 1
                diff += 1
        i += 1
        if i > 16 * PCOUNT_TOTAL:   # pragma: no cover - safety valve
            raise RuntimeError("count assignment failed to converge")
    return [int(c) for c in alloc]


def _search_rounds(csum: np.ndarray, total: int, v_min: list[int],
                   bits: int, max_rounds: int) -> list[int]:
    size = _encoded_size_csum(csum, total, v_min, bits)
    memo: dict = {}
    for _ in range(max_rounds):
        v_min, newsize = _search(csum, total, v_min, size, 1, -1, bits, memo)
        if size <= 0 or newsize / max(size, 1e-9) >= THRESHOLD:
            break
        size = newsize
    return v_min


def find_table(hist: np.ndarray, bits: int = 8, is_activation: bool = False,
               max_rounds: int = 64) -> ApackTable:
    """Paper Listing 1 ``findPT()``: uniform init, search rounds until <1% gain.

    For bits > 8 the exhaustive boundary slide over a 2^bits value space is
    intractable; we run the same search at 256-bucket granularity (each
    bucket = 2^(bits-8) values) and then refine each boundary locally at
    full resolution — the paper notes "the same process can be applied to
    input of any bit length" without prescribing the 16-bit search schedule.
    """
    hist = np.asarray(hist, np.int64)
    nvals = 1 << bits
    csum = np.concatenate([[0], np.cumsum(hist)])
    total = int(hist.sum())
    if bits <= 8:
        step = nvals // N_SYMBOLS
        v_min = [i * step for i in range(N_SYMBOLS)]
        v_min = _search_rounds(csum, total, v_min, bits, max_rounds)
    else:
        shift = bits - 8
        coarse_hist = hist.reshape(256, -1).sum(axis=1)
        ccsum = np.concatenate([[0], np.cumsum(coarse_hist)])
        cv = _search_rounds(ccsum, total, [i * 16 for i in range(N_SYMBOLS)],
                            8, max_rounds)
        v_min = [b << shift for b in cv]
        # local refinement: each boundary hill-climbs within its bucket
        size = _encoded_size_csum(csum, total, v_min, bits)
        for i in range(1, N_SYMBOLS):
            for delta in (-1, +1):
                while True:
                    cand = list(v_min)
                    cand[i] += delta
                    if not _valid(cand, bits):
                        break
                    s = _encoded_size_csum(csum, total, cand, bits)
                    if s >= size:
                        break
                    v_min, size = cand, s
    counts = _assign_counts(hist, v_min, bits, steal_for_empty=is_activation)
    cum = [0]
    for c in counts:
        cum.append(cum[-1] + c)
    bounds = v_min + [nvals]
    ol = [_ol_bits(bounds[i + 1] - bounds[i]) for i in range(N_SYMBOLS)]
    return ApackTable(v_min=tuple(v_min + [nvals]), ol=tuple(ol),
                      cum=tuple(cum), bits=bits,
                      mode="activation" if is_activation else "weight")


def expected_bits_per_value(hist: np.ndarray, table: ApackTable) -> float:
    """Entropy-model estimate of coded bits/value for data distributed as
    ``hist`` when coded with ``table``.

    Per value ``v`` in symbol range ``s``: ``-log2(pcount[s] / 1024)``
    ideal-AC symbol bits plus ``ol[s]`` verbatim offset bits.  Values whose
    range holds zero probability counts are unencodable in AC; the encoder
    falls back to stored mode for such streams, so the estimate clamps at
    ``bits`` (the stored-mode width) — this is exactly the "degrade toward
    stored-mode widths" failure mode of a drifted table, which makes the
    clamped estimate the drift-monitor cost function: the ratio of this
    number on a *recent* histogram vs. the histogram the table was built
    from is the compression-ratio regression a refresh trigger watches.

    O(2^bits) numpy; cheap enough to run per drift check."""
    hist = np.asarray(hist, np.float64)
    total = hist.sum()
    if total == 0:
        return 0.0
    nvals = hist.shape[0]
    v_min = np.asarray(table.v_min[:N_SYMBOLS])
    # symbol_of(v): largest s with v_min[s] <= v
    sym = np.searchsorted(v_min, np.arange(nvals), side="right") - 1
    pcount = np.diff(np.asarray(table.cum, np.float64))
    ol = np.asarray(table.ol, np.float64)
    per_sym = np.where(pcount > 0,
                       -np.log2(np.maximum(pcount, 1) / PCOUNT_TOTAL)
                       + ol, np.inf)
    per_val = np.minimum(per_sym[sym], float(table.bits))
    return float(np.sum(hist * per_val) / total)


def uniform_table(bits: int = 8) -> ApackTable:
    """The search's starting point — also the worst-case/fallback table."""
    nvals = 1 << bits
    step = nvals // N_SYMBOLS
    v_min = [i * step for i in range(N_SYMBOLS)]
    counts = [PCOUNT_TOTAL // N_SYMBOLS] * N_SYMBOLS
    cum = [0]
    for c in counts:
        cum.append(cum[-1] + c)
    bounds = v_min + [nvals]
    ol = [_ol_bits(bounds[i + 1] - bounds[i]) for i in range(N_SYMBOLS)]
    return ApackTable(v_min=tuple(v_min + [nvals]), ol=tuple(ol), cum=tuple(cum),
                      bits=bits)


def table_for(values: np.ndarray, bits: int = 8, is_activation: bool = False) -> ApackTable:
    return find_table(histogram(values, bits), bits, is_activation)

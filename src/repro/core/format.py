"""APack on-memory container format.

A tensor is flattened and split into ``S`` independent substreams of ``E``
values each (paper §V-B: replication requires independent streams).  Each
substream encodes into a *symbol* bitstream (arithmetically coded) and an
*offset* bitstream (verbatim), exactly as the paper's two output streams.

TPU-adapted layout: streams are **word-interleaved** — word ``w`` of stream
``s`` lives at ``plane[w, s]`` — so a lane-vectorized decoder reading word
``w_s`` for 128 streams touches (near-)contiguous rows.  A per-stream
directory records actual bit lengths; fixed-capacity planes are the
VMEM-slot view, the directory gives the dynamic-DMA view.

Beyond the paper: per-stream **stored mode** — if arithmetic coding would
inflate a stream (or the encoder's pending-bit cap trips), the stream is
stored verbatim in the offset plane.  This bounds worst-case footprint at
``orig_bits + S`` bits + metadata, a guarantee the paper lacks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import ac_golden
from .tables import ApackTable, TABLE_OVERHEAD_BITS, table_for

DEFAULT_ELEMS_PER_STREAM = 512
# Directory cost per stream: sym_bits(32) + ofs_bits(32) + stored flag(1).
DIR_BITS_PER_STREAM = 65


@dataclasses.dataclass
class CompressedTensor:
    """APack-compressed tensor + everything needed to invert it."""

    shape: tuple[int, ...]
    bits: int
    table: ApackTable
    elems_per_stream: int
    n_valid: int                 # flattened element count (excludes padding)
    sym_plane: np.ndarray        # [W_sym, S] uint32, word-interleaved
    ofs_plane: np.ndarray        # [W_ofs, S] uint32
    sym_bits: np.ndarray         # [S] int32, actual bits in each symbol stream
    ofs_bits: np.ndarray         # [S] int32
    stored: np.ndarray           # [S] bool, verbatim-mode streams

    @property
    def n_streams(self) -> int:
        return int(self.sym_bits.shape[0])

    @property
    def payload_bits(self) -> int:
        """Actual payload (paper-comparable footprint)."""
        return int(self.sym_bits.sum() + self.ofs_bits.sum())

    @property
    def total_bits(self) -> int:
        """Payload + table + directory (what a real store would hold)."""
        return (self.payload_bits + TABLE_OVERHEAD_BITS
                + DIR_BITS_PER_STREAM * self.n_streams)

    @property
    def slotted_bits(self) -> int:
        """Fixed-slot (padded-plane) footprint — the VMEM tile view."""
        return 32 * (self.sym_plane.size + self.ofs_plane.size)

    @property
    def original_bits(self) -> int:
        return self.n_valid * self.bits

    def ratio(self, include_metadata: bool = True) -> float:
        denom = self.total_bits if include_metadata else self.payload_bits
        return self.original_bits / max(denom, 1)


def _pad_value(table: ApackTable) -> int:
    """A value with maximal probability — cheapest legal padding."""
    counts = np.diff(np.asarray(table.cum))
    s = int(np.argmax(counts))
    return table.v_min[s]


def split_streams(flat: np.ndarray, elems_per_stream: int) -> tuple[np.ndarray, int]:
    """Pad + reshape to [S, E]; returns (streams, n_valid)."""
    n = flat.shape[0]
    e = elems_per_stream
    s = max(1, -(-n // e))
    padded = np.zeros(s * e, dtype=flat.dtype)
    padded[:n] = flat
    return padded.reshape(s, e), n


def compress(values: np.ndarray, table: ApackTable | None = None,
             bits: int = 8, is_activation: bool = False,
             elems_per_stream: int = DEFAULT_ELEMS_PER_STREAM) -> CompressedTensor:
    """Reference (golden-codec) compressor.  Exact but Python-speed; the
    production path is ``kernels.ops.apack_encode`` which is bit-identical."""
    arr = np.asarray(values)
    shape = arr.shape
    flat = arr.reshape(-1).astype(np.int64)
    if table is None:
        table = table_for(flat, bits, is_activation)
    streams, n_valid = split_streams(flat, elems_per_stream)
    pad = _pad_value(table)
    if n_valid < streams.size:
        streams.reshape(-1)[n_valid:] = pad
    S, E = streams.shape
    sym_words_l, ofs_words_l = [], []
    sym_bits = np.zeros(S, np.int32)
    ofs_bits = np.zeros(S, np.int32)
    stored = np.zeros(S, bool)
    for si in range(S):
        try:
            sw, sb, ow, ob = ac_golden.encode_stream(streams[si], table)
        except OverflowError:
            sw, sb, ow, ob = [], 0, None, 0
        if sb + ob >= E * bits or ow is None:
            # stored mode: verbatim values in the offset plane
            stored[si] = True
            wr = ac_golden.BitWriter()
            for v in streams[si]:
                wr.put_bits(int(v), bits)
            sw, sb, ow, ob = [], 0, wr.to_words(), len(wr)
        sym_words_l.append(sw)
        ofs_words_l.append(ow)
        sym_bits[si], ofs_bits[si] = sb, ob
    w_sym = max((len(w) for w in sym_words_l), default=0)
    w_ofs = max((len(w) for w in ofs_words_l), default=0)
    sym_plane = np.zeros((w_sym, S), np.uint32)
    ofs_plane = np.zeros((w_ofs, S), np.uint32)
    for si in range(S):
        for wi, w in enumerate(sym_words_l[si]):
            sym_plane[wi, si] = w
        for wi, w in enumerate(ofs_words_l[si]):
            ofs_plane[wi, si] = w
    return CompressedTensor(shape=tuple(shape), bits=bits, table=table,
                            elems_per_stream=elems_per_stream, n_valid=n_valid,
                            sym_plane=sym_plane, ofs_plane=ofs_plane,
                            sym_bits=sym_bits, ofs_bits=ofs_bits, stored=stored)


def decompress(ct: CompressedTensor) -> np.ndarray:
    """Reference (golden-codec) decompressor."""
    S = ct.n_streams
    E = ct.elems_per_stream
    out = np.zeros((S, E), np.int64)
    for si in range(S):
        sym = [int(w) for w in ct.sym_plane[:, si]]
        ofs = [int(w) for w in ct.ofs_plane[:, si]]
        if ct.stored[si]:
            rd = ac_golden.BitReader(ofs, int(ct.ofs_bits[si]))
            out[si] = [rd.get_bits(ct.bits) for _ in range(E)]
        else:
            out[si] = ac_golden.decode_stream(sym, ofs, E, ct.table,
                                              int(ct.sym_bits[si]),
                                              int(ct.ofs_bits[si]))
    flat = out.reshape(-1)[:ct.n_valid]
    dtype = np.uint8 if ct.bits <= 8 else np.uint16
    return flat.astype(dtype).reshape(ct.shape)


def estimate_bits(hist: np.ndarray, table: ApackTable) -> float:
    """Exact-in-expectation footprint with the *quantized* counts: per value
    of symbol s, -log2(count_s/1024) AC bits + OL_s offset bits.  Used by
    large-tensor benchmarks where running the codec on every element would
    be wasteful; accurate to O(termination bits) per stream."""
    counts = np.diff(np.asarray(table.cum)).astype(np.float64)
    bounds = np.asarray(table.v_min)
    csum = np.concatenate([[0], np.cumsum(hist)])
    per_range = (csum[bounds[1:]] - csum[bounds[:-1]]).astype(np.float64)
    nz = per_range > 0
    bits = per_range[nz] * (-np.log2(counts[nz] / 1024.0)
                            + np.asarray(table.ol)[nz])
    return float(bits.sum())

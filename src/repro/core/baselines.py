"""The paper's comparison compressors (§VII "Compression Methods").

All return footprints in bits for a uint value array; ratios are
``orig_bits / footprint``.  These are size models (the paper evaluates them
for traffic, not as hardware): RLE/RLEZ tuples and ShapeShifter group
encoding are deterministic given the value stream, so exact footprints need
no bitstream materialization.
"""
from __future__ import annotations

import numpy as np

RLE_DIST_BITS = 4           # paper: distance limited to 15 -> 4-bit overhead
SS_GROUP = 8                # paper: group of 8 values, as in ShapeShifter
SS_PREC_FIELD = 3           # log2(Pmax=8) bits to encode the group precision


def rle_bits(values: np.ndarray, bits: int = 8) -> int:
    """(value, distance) tuples; distance = following run of equal values,
    capped at 2^4 - 1."""
    v = np.asarray(values).reshape(-1)
    if v.size == 0:
        return 0
    # run-length encode
    change = np.nonzero(np.diff(v))[0]
    run_starts = np.concatenate([[0], change + 1])
    run_ends = np.concatenate([change + 1, [v.size]])
    run_lens = run_ends - run_starts
    max_run = 1 << RLE_DIST_BITS
    n_tuples = int(np.sum(-(-run_lens // max_run)))
    return n_tuples * (bits + RLE_DIST_BITS)


def rlez_bits(values: np.ndarray, bits: int = 8) -> int:
    """(value, zero-distance) tuples; each tuple stores one value and the
    count of zeros following it (capped at 15)."""
    v = np.asarray(values).reshape(-1)
    if v.size == 0:
        return 0
    nz_idx = np.nonzero(v)[0]
    # zeros before the first nonzero need carrier tuples too
    n_tuples = 0
    prev_end = 0
    max_run = (1 << RLE_DIST_BITS) - 1
    # leading zeros: emit (0, run) tuples
    first_nz = nz_idx[0] if nz_idx.size else v.size
    lead = first_nz
    n_tuples += -(-lead // (max_run + 1)) if lead else 0
    # each nonzero emits one tuple covering itself + up to 15 zeros after;
    # longer zero runs need (0, run) filler tuples
    if nz_idx.size:
        gaps = np.diff(np.concatenate([nz_idx, [v.size]])) - 1
        n_tuples += nz_idx.size
        over = np.maximum(gaps - max_run, 0)
        n_tuples += int(np.sum(-(-over // (max_run + 1))))
    return n_tuples * (bits + RLE_DIST_BITS)


def shapeshifter_bits(values: np.ndarray, bits: int = 8,
                      group: int = SS_GROUP, zero_vector: bool = True) -> int:
    """ShapeShifter [36]: per group of G values, the minimal precision P
    covering the group, costing G*P + log2(Pmax) bits.  The 8-bit-optimized
    variant adds a per-value zero bit-vector and packs only nonzeros.

    Returns the better of the two encodings per tensor (the paper evaluates
    its tuned variant; we give it the benefit of both)."""
    v = np.asarray(values).reshape(-1).astype(np.int64)
    n = v.size
    if n == 0:
        return 0
    pad = (-n) % group
    if pad:
        v = np.concatenate([v, np.zeros(pad, np.int64)])
    g = v.reshape(-1, group)
    # ShapeShifter drops prefixes of 0s (near zero) *or* 1s (near 2^bits, i.e.
    # small negatives in two's complement): precision P(v) = smallest p such
    # that sign-extending the low p bits reproduces v.
    half = 1 << (bits - 1)
    signed = np.where(g >= half, g - (1 << bits), g)
    mag = np.where(signed >= 0, signed + 1, -signed)   # needs ceil(log2(mag))+1
    nbits = np.ceil(np.log2(np.maximum(mag, 1))).astype(np.int64) + 1
    nbits = np.clip(nbits, 1, bits)
    p_plain = nbits.max(axis=1)
    plain = int(np.sum(group * p_plain + SS_PREC_FIELD))
    # zero-vector variant: G mask bits + count(nonzero)*P + precision field
    nz_mask = g != 0
    nbits_nz = np.where(nz_mask, nbits, 0)
    p_zv = nbits_nz.max(axis=1)
    p_zv = np.maximum(p_zv, 1)
    zv = int(np.sum(group + nz_mask.sum(axis=1) * p_zv + SS_PREC_FIELD))
    return min(plain, zv)


def baseline_bits(values: np.ndarray, bits: int = 8) -> int:
    return int(np.asarray(values).size) * bits

"""Training supervisor: checkpoint/restart fault tolerance, preemption
handling, straggler watchdog, elastic rescale.

On a real multi-pod deployment each host runs this loop; failure detection
is jax.distributed heartbeats + the coordinator restarting the job, and the
elastic path re-slices the (host-complete) checkpoint onto the surviving
mesh.  In this container the same code paths are exercised with injected
failures (tests/test_runtime.py): the supervisor catches step exceptions,
restores the latest atomic checkpoint, rebuilds the step function, and
continues — bit-exact with an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import signal
import time
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt

log = logging.getLogger("repro.supervisor")


@dataclasses.dataclass(frozen=True)
class WatchdogEvent:
    """Structured straggler-watchdog emission: consumable by the serving
    engine's pressure policy as well as the training supervisor (one code
    path for both — ISSUE 6 satellite).

    ``kind`` is ``"straggler"`` (flagged, below patience) or ``"hung"``
    (``consecutive`` flags reached patience — the caller should act:
    supervisor raises, engine preempts-with-spill).

    ``phases`` (optional): per-phase wall-time breakdown of the observed
    step.  The async serve engine reports its overlapped host work /
    collect / dispatch split here, so a hung event attributes the stall
    (host-side seal/re-pack/prefill vs the device step itself) instead
    of reporting one opaque duration."""
    kind: str
    dt: float
    ema: float
    consecutive: int
    phases: dict | None = None


class StragglerWatchdog:
    """Step-time watchdog shared by ``Supervisor`` and
    ``serve.ServeEngine``: a step slower than ``ratio`` × the trailing
    ``window``-step *median* is flagged; ``patience`` consecutive flags
    escalate to a ``hung`` event.  The baseline is a median, not a
    mean: jit-bucket growth (prefill buckets, per-job page-count
    buckets) legitimately drops a multi-second compile into an
    otherwise-millisecond step stream, and one such spike in a mean
    window would inflate the threshold enough to mask a genuinely hung
    step for the next ``window`` steps.  Policy (raise / preempt /
    re-mesh) stays with the caller — this class only observes and
    emits."""

    def __init__(self, ratio: float = 5.0, patience: int = 3,
                 window: int = 8, on_event=None):
        self.ratio = ratio
        self.patience = patience
        self.window = window
        self.on_event = on_event
        self.step_times: list[float] = []
        self.events = 0                      # consecutive flagged steps
        self.event_log: list[WatchdogEvent] = []

    def observe(self, dt: float,
                phases: dict | None = None) -> WatchdogEvent | None:
        ev = None
        if len(self.step_times) >= self.window:
            ema = float(np.median(self.step_times[-self.window:]))
            if dt > self.ratio * max(ema, 1e-6):
                self.events += 1
                kind = "hung" if self.events >= self.patience \
                    else "straggler"
                ev = WatchdogEvent(kind=kind, dt=dt, ema=ema,
                                   consecutive=self.events, phases=phases)
            else:
                self.events = 0
        self.step_times.append(dt)
        if ev is not None:
            self.event_log.append(ev)
            if self.on_event is not None:
                self.on_event(ev)
        return ev

    def reset(self) -> None:
        self.events = 0


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    save_every: int = 100
    max_steps: int = 1000
    keep: int = 3
    compress_ckpt: bool = False
    max_restarts: int = 10
    # straggler watchdog: a step slower than ratio*EMA is flagged; after
    # ``straggler_patience`` consecutive flags the step is treated as hung
    # (on a cluster: trigger backup workers / re-mesh; here: raise).
    straggler_ratio: float = 5.0
    straggler_patience: int = 3
    async_save: bool = True


class Supervisor:
    def __init__(self, cfg: SupervisorConfig, *,
                 make_state: Callable[[], tuple[Any, dict]],
                 step_fn: Callable[[Any, dict], tuple[Any, dict]],
                 data_state: Callable[[], dict] | None = None,
                 restore_data: Callable[[dict], None] | None = None,
                 on_watchdog_event: Callable[[WatchdogEvent], None]
                 | None = None):
        """Args:
          make_state: () -> (train_state, extra) fresh initialization.
          step_fn: (train_state, step_idx) -> (train_state, metrics).
          data_state / restore_data: data-pipeline cursor hooks.
          on_watchdog_event: structured straggler/hung event sink.
        """
        self.cfg = cfg
        self.make_state = make_state
        self.step_fn = step_fn
        self.data_state = data_state or (lambda: {})
        self.restore_data = restore_data or (lambda s: None)
        self.preempted = False
        self.restarts = 0
        self.watchdog = StragglerWatchdog(ratio=cfg.straggler_ratio,
                                          patience=cfg.straggler_patience,
                                          on_event=on_watchdog_event)
        self._saver = ckpt.AsyncCheckpointer(cfg.ckpt_dir,
                                             compress=cfg.compress_ckpt,
                                             keep=cfg.keep)

    # back-compat views onto the shared watchdog (tests/callers pin these)
    @property
    def step_times(self) -> list[float]:
        return self.watchdog.step_times

    @property
    def straggler_events(self) -> int:
        return self.watchdog.events

    @straggler_events.setter
    def straggler_events(self, v: int) -> None:
        self.watchdog.events = v

    def _install_signal_handler(self):
        def handler(signum, frame):
            log.warning("preemption signal %s received", signum)
            self.preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGUSR1, handler)
        except ValueError:
            pass                                   # non-main thread (tests)

    def _resume_or_init(self):
        latest = ckpt.latest_step(self.cfg.ckpt_dir)
        if latest is not None:
            state, extra, step = ckpt.restore(self.cfg.ckpt_dir)
            self.restore_data(extra.get("data", {}))
            log.info("restored step %d from %s", step, self.cfg.ckpt_dir)
            return state, step
        state, extra = self.make_state()
        return state, 0

    def _watchdog(self, dt: float) -> None:
        ev = self.watchdog.observe(dt)
        if ev is not None:
            log.warning("straggler step: %.3fs vs EMA %.3fs "
                        "(%d consecutive)", ev.dt, ev.ema, ev.consecutive)
            if ev.kind == "hung":
                raise TimeoutError(
                    "persistent straggler — on a cluster this triggers "
                    "backup-worker promotion / re-meshing")

    def _save(self, step: int, state: Any) -> None:
        extra = {"data": self.data_state(), "wall_time": time.time()}
        if self.cfg.async_save:
            self._saver.save(step, state, extra)
        else:
            ckpt.save(self.cfg.ckpt_dir, step, state, extra,
                      compress=self.cfg.compress_ckpt, keep=self.cfg.keep)

    def run(self) -> tuple[Any, list[dict]]:
        """Run to max_steps with restart-on-failure.  Returns (state, log)."""
        self._install_signal_handler()
        history: list[dict] = []
        state, step = self._resume_or_init()
        while step < self.cfg.max_steps and not self.preempted:
            t0 = time.time()
            try:
                state, metrics = self.step_fn(state, step)
            except (TimeoutError, RuntimeError, ValueError, FloatingPointError) as e:
                self.restarts += 1
                log.error("step %d failed (%s); restart %d/%d", step, e,
                          self.restarts, self.cfg.max_restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                self._saver.wait()
                state, step = self._resume_or_init()
                self.straggler_events = 0
                continue
            dt = time.time() - t0
            self._watchdog(dt)
            step += 1
            metrics = dict(metrics)
            metrics.update(step=step, dt=dt)
            history.append(metrics)
            if step % self.cfg.save_every == 0 or step == self.cfg.max_steps:
                self._save(step, state)
        if self.preempted:
            self._save(step, state)
        self._saver.wait()
        return state, history

from .supervisor import Supervisor, SupervisorConfig

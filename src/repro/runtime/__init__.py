from .supervisor import (StragglerWatchdog, Supervisor, SupervisorConfig,
                         WatchdogEvent)

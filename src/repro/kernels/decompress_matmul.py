"""Fused APack-decompress + matmul Pallas kernel.

This is the TPU materialization of the paper's Figure 1: the accelerator's
compute units (here: the MXU ``jnp.dot``) consume *decompressed* values that
never exist in off-chip memory.  The weight matrix lives in HBM as
word-interleaved APack planes; each grid step DMAs one compressed tile's
slot into VMEM (BlockSpec), lane-decodes it (``decode_block``), dequantizes,
and feeds the MXU — so HBM traffic for weights is the compressed footprint,
exactly the saving the paper's memory-controller codec achieves.

Weight layout: W[K, N] is tiled into (K // E) x (N // NS) tiles; stream
``c`` of tile (k, j) holds column ``j*NS + c`` over rows ``k*E..(k+1)*E``.
Streams of one tile are adjacent columns of the planes, so the BlockSpec
slice [*, NS] is one tile's slot.  Fixed-size slots (global max words) keep
the layout BlockSpec-indexable; on real hardware the per-stream directory
enables dynamic-length DMA instead (documented trade-off: the *slotted*
ratio vs the *payload* ratio of ``CompressedTensor``).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import format as fmt
from repro.core import quant
from repro.core.tables import ApackTable, find_table, histogram
from .apack_decode import decode_block
from . import ref as _ref

I32 = jnp.int32
U32 = jnp.uint32
TILE_N = 128      # streams per tile == lane count
DEFAULT_TILE_K = 512
# Smallest element count for which the serving layer compresses a weight
# tensor.  Shared by ``serve.compress_params``, ``model.pack_weights`` and
# the ``--weight-min-size`` CLI flag — one default, no silent divergence.
DEFAULT_WEIGHT_MIN_SIZE = 16384


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedLinear:
    """An APack-compressed [K, N] weight matrix + dequant metadata."""

    sym_plane: jax.Array     # u32[Ws, S_total]
    ofs_plane: jax.Array     # u32[Wo, S_total]
    stored: jax.Array        # i32[S_total]
    v_min: jax.Array
    ol: jax.Array
    cum: jax.Array
    scale: jax.Array         # f32[N_pad] per-output-channel dequant scale
    k: int                   # original K
    n: int                   # original N
    tile_k: int
    payload_bits: int        # actual compressed payload (for traffic models)

    def tree_flatten(self):
        return ((self.sym_plane, self.ofs_plane, self.stored, self.v_min,
                 self.ol, self.cum, self.scale),
                (self.k, self.n, self.tile_k, self.payload_bits))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def k_pad(self) -> int:
        return -(-self.k // self.tile_k) * self.tile_k

    @property
    def n_pad(self) -> int:
        return -(-self.n // TILE_N) * TILE_N


def compress_quantized(q: np.ndarray, scale: np.ndarray,
                       tile_k: int = DEFAULT_TILE_K,
                       table: ApackTable | None = None) -> CompressedLinear:
    """APack-compress an already-quantized int8 weight matrix.

    ``q``: int8-valued [K, N]; ``scale``: f32 [N] per-output-column dequant
    scale.  This is the shared encode tail of ``compress_linear`` and the
    serving layer's ``pack_weights`` — both quantize through
    ``quant.quantize_symmetric`` first, so a tensor compressed by either
    path dequantizes bit-identically through the other."""
    q = np.asarray(q)
    k, n = q.shape
    scale = np.asarray(scale, np.float32).reshape(-1)
    assert scale.shape == (n,), (scale.shape, n)
    u = (q.astype(np.int64) & 0xFF).astype(np.uint8)     # two's complement view
    k_pad = -(-k // tile_k) * tile_k
    n_pad = -(-n // TILE_N) * TILE_N
    up = np.zeros((k_pad, n_pad), np.uint8)              # pad with 0 == q 0
    up[:k, :n] = u
    if table is None:
        table = find_table(histogram(up), bits=8, is_activation=False)
    # stream layout: tile (kt, jt), stream c -> column of planes
    nk, nn = k_pad // tile_k, n_pad // TILE_N
    streams = (up.reshape(nk, tile_k, nn, TILE_N)
                 .transpose(0, 2, 3, 1)                  # [nk, nn, NS, E]
                 .reshape(nk * nn * TILE_N, tile_k))
    ta = _ref.TableArrays.from_table(table)
    sp, op, sb, ob, stored = _ref.encode(jnp.asarray(streams.astype(np.int64)),
                                         ta, tile_k, 8)
    payload = int(np.asarray(sb).sum() + np.asarray(ob).sum())
    scale_pad = np.zeros(n_pad, np.float32)
    scale_pad[:n] = scale
    return CompressedLinear(sym_plane=sp, ofs_plane=op,
                            stored=stored.astype(I32), v_min=ta.v_min,
                            ol=ta.ol, cum=ta.cum,
                            scale=jnp.asarray(scale_pad), k=k, n=n,
                            tile_k=tile_k, payload_bits=payload)


def compress_linear(w: np.ndarray, tile_k: int = DEFAULT_TILE_K,
                    table: ApackTable | None = None) -> CompressedLinear:
    """Quantize (symmetric int8 per output column) + APack-compress a
    weight matrix.

    Quantization goes through ``quant.quantize_symmetric(..., axis=-1)``
    — the same call ``serve.compress_params`` makes — so the two weight
    codecs share one convention (per-channel over the LAST axis, reduced
    over all leading axes) and cross-path dequantization is bit-exact.
    The previous private ``np.abs(w).max(axis=0)`` formula was the
    quantization-axis mismatch bug for >2-D tensors."""
    w = np.asarray(w, np.float32)
    q, qp = quant.quantize_symmetric(jnp.asarray(w), axis=-1)
    return compress_quantized(np.asarray(q),
                              np.asarray(qp.scale, np.float32).reshape(-1),
                              tile_k, table)


def stack_compressed(cws: list[CompressedLinear]) -> CompressedLinear:
    """Stack per-layer ``CompressedLinear``s into one whose array leaves
    carry a leading layer axis — the shape ``jax.lax.scan`` consumes for
    the scanned block stack (scan slices pytree leaves per iteration and
    rebuilds a per-layer ``CompressedLinear`` with the shared static aux).

    Per-layer sym/ofs planes are zero-padded to the stack's max word
    count (``decode_block`` reads exactly ``tile_k`` values per stream,
    so trailing pad words are never touched).  Static aux (k, n, tile_k)
    must match across layers; ``payload_bits`` becomes the stack total
    (it only feeds traffic accounting)."""
    assert cws, "empty stack"
    k, n, tile_k = cws[0].k, cws[0].n, cws[0].tile_k
    assert all((c.k, c.n, c.tile_k) == (k, n, tile_k) for c in cws)
    ws = max(c.sym_plane.shape[0] for c in cws)
    wo = max(c.ofs_plane.shape[0] for c in cws)

    def pad_rows(p, rows):
        return jnp.pad(p, ((0, rows - p.shape[0]), (0, 0)))

    return CompressedLinear(
        sym_plane=jnp.stack([pad_rows(c.sym_plane, ws) for c in cws]),
        ofs_plane=jnp.stack([pad_rows(c.ofs_plane, wo) for c in cws]),
        stored=jnp.stack([c.stored for c in cws]),
        v_min=jnp.stack([c.v_min for c in cws]),
        ol=jnp.stack([c.ol for c in cws]),
        cum=jnp.stack([c.cum for c in cws]),
        scale=jnp.stack([c.scale for c in cws]),
        k=k, n=n, tile_k=tile_k,
        payload_bits=sum(c.payload_bits for c in cws))


def _fused_kernel(x_ref, sym_ref, ofs_ref, stored_ref, vmin_ref, ol_ref,
                  cum_ref, scale_ref, out_ref, w_tile_ref, acc_ref, *,
                  tile_k: int, nk: int):
    kt = pl.program_id(1)
    i = pl.program_id(2)
    block_m = x_ref.shape[0]

    # The grid iterates M innermost, so each compressed weight tile (j, kt)
    # is decoded exactly once — at its first row-block visit — and the
    # dequantized tile persists in VMEM scratch for the remaining
    # m_pad // block_m - 1 visits (EIE-style decode-once amortization).
    @pl.when(i == 0)
    def _decode_tile():
        vals = decode_block(sym_ref[...].astype(U32), ofs_ref[...].astype(U32),
                            stored_ref[...] != 0, vmin_ref[...], ol_ref[...],
                            cum_ref[...], n_steps=tile_k, bits=8)   # [NS, E]
        # two's-complement reinterpret + per-channel dequant
        signed = jnp.where(vals >= 128, vals - 256, vals).astype(jnp.float32)
        w_tile_ref[...] = signed.T * scale_ref[...][None, :]   # [E, NS] f32

    part = jnp.dot(x_ref[...].astype(jnp.float32), w_tile_ref[...],
                   preferred_element_type=jnp.float32)

    # Accumulate in a VMEM scratch strip, not in out_ref: the out-block
    # revisits across kt are non-consecutive (other M-blocks run in
    # between), and Mosaic only guarantees a revisited output block's
    # prior contents for *consecutive* grid steps.  Scratch persists for
    # the whole kernel, so the strip holds each row-block's running sum
    # across the interleaved visits; out_ref is written exactly once, at
    # the final K-tile.
    rows = pl.ds(i * block_m, block_m)

    @pl.when(kt == 0)
    def _init():
        acc_ref[rows, :] = part

    @pl.when(kt > 0)
    def _accum():
        acc_ref[rows, :] += part

    @pl.when(kt == nk - 1)
    def _flush():
        out_ref[...] = acc_ref[rows, :]


@functools.partial(jax.jit, static_argnames=("interpret", "block_m"))
def compressed_matmul(x: jax.Array, cw: CompressedLinear,
                      interpret: bool = True, block_m: int = 256) -> jax.Array:
    """``x @ W`` where W is APack-compressed; x: f32/bf16 [M, K].

    Grid order is (N-tiles, K-tiles, M-blocks) with M innermost: decode work
    is independent of M (each tile decoded once into scratch), at the cost
    of revisiting output blocks once per K-tile — the decode is orders of
    magnitude more expensive than the extra out-block traffic.

    Partial products accumulate in a VMEM scratch strip [m_pad, TILE_N]
    and flush to the output block exactly once, at kt == nk - 1, so the
    kernel never relies on Mosaic preserving a revisited output block
    across non-consecutive grid steps — safe for compiled TPU mode, and
    bit-identical to interpret mode (same kt-major summation order)."""
    m, k = x.shape
    assert k == cw.k, f"K mismatch: {k} vs {cw.k}"
    k_pad, n_pad = cw.k_pad, cw.n_pad
    nk, nn = k_pad // cw.tile_k, n_pad // TILE_N
    m_pad = -(-m // block_m) * block_m
    xp = jnp.pad(x, ((0, m_pad - m), (0, k_pad - k)))
    ws, wo = cw.sym_plane.shape[0], cw.ofs_plane.shape[0]
    grid = (nn, nk, m_pad // block_m)
    out = pl.pallas_call(
        functools.partial(_fused_kernel, tile_k=cw.tile_k, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, cw.tile_k), lambda j, kt, i: (i, kt)),
            pl.BlockSpec((ws, TILE_N), lambda j, kt, i: (0, kt * nn + j)),
            pl.BlockSpec((wo, TILE_N), lambda j, kt, i: (0, kt * nn + j)),
            pl.BlockSpec((TILE_N,), lambda j, kt, i: (kt * nn + j,)),
            pl.BlockSpec((17,), lambda j, kt, i: (0,)),
            pl.BlockSpec((16,), lambda j, kt, i: (0,)),
            pl.BlockSpec((17,), lambda j, kt, i: (0,)),
            pl.BlockSpec((TILE_N,), lambda j, kt, i: (j,)),
        ],
        out_specs=pl.BlockSpec((block_m, TILE_N), lambda j, kt, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((cw.tile_k, TILE_N), jnp.float32),
                        pltpu.VMEM((m_pad, TILE_N), jnp.float32)],
        interpret=interpret,
    )(xp, cw.sym_plane, cw.ofs_plane, cw.stored, cw.v_min, cw.ol, cw.cum,
      cw.scale)
    return out[:m, :cw.n]


def reference_matmul(x: jax.Array, cw: CompressedLinear) -> jax.Array:
    """Oracle: decode with the jnp reference, dequant, dense matmul."""
    e = cw.tile_k
    table = _ref.TableArrays(cw.v_min, cw.ol, cw.cum)
    vals = _ref.decode(cw.sym_plane, cw.ofs_plane, cw.stored.astype(bool),
                       table, e, 8)
    nk, nn = cw.k_pad // e, cw.n_pad // TILE_N
    w = (vals.reshape(nk, nn, TILE_N, e).transpose(0, 3, 1, 2)
             .reshape(cw.k_pad, cw.n_pad))
    signed = jnp.where(w >= 128, w - 256, w).astype(jnp.float32)
    wf = signed * cw.scale[None, :]
    return (x.astype(jnp.float32) @ wf[:cw.k])[:, :cw.n]

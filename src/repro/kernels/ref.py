"""Vectorized pure-jnp multi-stream APack codec — the kernel oracle.

This is the paper's §V-B replication strategy in TPU-native form: instead of
64 discrete encoder/decoder engines, S independent substreams are coded in
lockstep, one stream per vector lane, with ``lax.scan`` playing the role of
the hardware's per-cycle step.  The arithmetic is the *identical*
finite-precision coder as ``core/ac_golden.py`` (16-bit HI/LO windows,
10-bit counts, WNC renormalization) and is asserted bit-exact against it.

The Pallas kernels in ``apack_decode.py`` / ``apack_encode.py`` mirror this
file operation-for-operation; this module doubles as the production software
path on CPU.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ac_golden import (HALF, MAX_PENDING, MAX_RENORM, PCOUNT_BITS,
                                  QUARTER, TOP)
from repro.core.tables import ApackTable

U32 = jnp.uint32
I32 = jnp.int32


class TableArrays(NamedTuple):
    """jnp view of an ApackTable (17/16/17-entry vectors)."""
    v_min: jax.Array   # i32[17]
    ol: jax.Array      # i32[16]
    cum: jax.Array     # i32[17]

    @classmethod
    def from_table(cls, t: ApackTable) -> "TableArrays":
        return cls(jnp.asarray(t.v_min, I32), jnp.asarray(t.ol, I32),
                   jnp.asarray(t.cum, I32))


# --------------------------------------------------------------- bit helpers
def shr32(x: jax.Array, k: jax.Array) -> jax.Array:
    """Logical right shift, correct for k in [0, 32]."""
    kc = jnp.minimum(k, 31).astype(U32)
    return jnp.where(k >= 32, U32(0), (x.astype(U32) >> kc))


def shl32(x: jax.Array, k: jax.Array) -> jax.Array:
    """Left shift, correct for k in [0, 32]."""
    kc = jnp.minimum(k, 31).astype(U32)
    return jnp.where(k >= 32, U32(0), (x.astype(U32) << kc))


def bitlen16(x: jax.Array) -> jax.Array:
    """Bit length of x in [0, 0xFFFF] (0 -> 0), branch-free binary search."""
    x = x.astype(I32)
    b = jnp.zeros_like(x)
    for s in (8, 4, 2, 1):
        big = x >= (1 << s)
        b = b + jnp.where(big, s, 0)
        x = jnp.where(big, x >> s, x)
    return b + (x > 0).astype(I32)


def rev16(w: jax.Array) -> jax.Array:
    """Reverse the low 16 bits (bit 0 <-> bit 15), u32 in/out."""
    w = w.astype(U32)
    w = ((w & U32(0x5555)) << 1) | ((w >> 1) & U32(0x5555))
    w = ((w & U32(0x3333)) << 2) | ((w >> 2) & U32(0x3333))
    w = ((w & U32(0x0F0F)) << 4) | ((w >> 4) & U32(0x0F0F))
    w = ((w & U32(0x00FF)) << 8) | ((w >> 8) & U32(0x00FF))
    return w & U32(0xFFFF)


def renorm_counts(low: jax.Array, high: jax.Array):
    """O(1) replacement for the per-bit WNC renormalization loop.

    After a range update the loop is provably a run of ``m`` emit-shifts
    (the matched leading bits of low/high) followed by a run of ``u``
    underflow-shifts (the straddle positions ``low=..01x``/``high=..10x``
    directly below the matched prefix), then it stops: an underflow shift
    clears bit15 of low and sets bit15 of high, so an emit can never follow
    an underflow within one symbol.  Returns ``(m, u, low', high')`` where
    ``low'``/``high'`` are the fully renormalized interval bounds.
    """
    m = 16 - bitlen16(low ^ high)
    low_m = (shl32(low.astype(U32), m) & U32(0xFFFF)).astype(I32)
    high_m = ((shl32(high.astype(U32), m)
               | (shl32(jnp.ones_like(low, U32), m) - U32(1)))
              & U32(0xFFFF)).astype(I32)
    # straddle run: consecutive positions below the MSB where low has 1 and
    # high has 0; count-leading-ones of (low & ~high) << 1
    t = (low_m & ~high_m) & 0xFFFF
    u = 16 - bitlen16(~(t << 1) & 0xFFFF)
    ufill = (shl32(jnp.ones_like(low, U32), u) - U32(1)).astype(I32)
    low_f = (shl32(low_m.astype(U32), u) & U32(0x7FFF)).astype(I32)
    high_f = ((shl32(high_m.astype(U32), u) & U32(0x7FFF)).astype(I32)
              | HALF | ufill)
    return m, u, low_f, high_f


def decode_renorm(low, high, code, spos, low2, high2, sym_plane, stored):
    """Decoder side of the multi-bit renormalization: renormalize the
    post-update interval ``low2``/``high2``, consume all m+u stream bits in
    one read, and update the CODE register in closed form.  Shared by
    ``decode`` and the Pallas ``decode_block``.

    ``low``/``high``/``code``/``spos`` are the pre-update values, returned
    unchanged for stored lanes.  Valid streams need at most 16 bits per
    step (m + u <= MAX_RENORM); the clamp guards the garbage padding lanes
    whose output is discarded.
    """
    m, u, low3, high3 = renorm_counts(low2, high2)
    k = jnp.minimum(m + u, 16)
    u = jnp.minimum(u, k - jnp.minimum(m, k))
    w = read_bits(sym_plane, spos, k)
    r = shr32(rev16(w), 16 - k).astype(I32)           # first-read bit = MSB
    r_m = shr32(r.astype(U32), u).astype(I32)
    ufill = (shl32(jnp.ones_like(u, U32), u) - U32(1)).astype(I32)
    code_m = (shl32(code.astype(U32), m) & U32(0xFFFF)).astype(I32) | r_m
    code3 = (shl32(code_m.astype(U32), u).astype(I32)
             - HALF * ufill + (r & ufill))
    # stored streams keep AC state frozen
    low3 = jnp.where(stored, low, low3)
    high3 = jnp.where(stored, high, high3)
    code3 = jnp.where(stored, code, code3)
    spos3 = spos + jnp.where(stored, 0, k)
    return low3, high3, code3, spos3


def encode_renorm(low2, high2, pending):
    """Encoder side of the multi-bit renormalization: renormalize the
    post-update interval and express the emitted bits as two append
    patterns.  Shared by ``encode_ac`` and the Pallas encoder kernel.

    Returns ``(low, high, pending', pat1, k1, pat2, k2)``: append ``pat1``
    (``k1`` bits — the first matched bit followed by the pending inverse
    run, LSB-first emission order) then ``pat2`` (``k2`` bits — the
    remaining matched leading bits of ``low2``).  ``k1``/``k2`` are zero
    when nothing is emitted; the caller flags overflow when ``pending'``
    exceeds ``MAX_PENDING``.
    """
    m, u, low, high = renorm_counts(low2, high2)
    has = m > 0
    ones = jnp.ones_like(low2).astype(U32)
    prefix = rev16(low2.astype(U32)) & (shl32(ones, m) - U32(1))
    b1 = prefix & U32(1)
    inv_run = (shl32(ones, pending) - U32(1)) * (U32(1) - b1)
    k1 = jnp.where(has, 1 + pending, 0)
    pat1 = jnp.where(has, b1 | (inv_run << 1), U32(0))
    k2 = jnp.where(has, m - 1, 0)
    pending = jnp.where(has, u, pending + u)
    return low, high, pending, pat1, k1, prefix >> 1, k2


def gather_word(plane: jax.Array, w: jax.Array) -> jax.Array:
    """plane[w[s], s] for each stream s.  plane: u32[W, S], w: i32[S]."""
    wc = jnp.clip(w, 0, plane.shape[0] - 1)
    return jnp.take_along_axis(plane, wc[None, :], axis=0)[0]


def read_bits(plane: jax.Array, pos: jax.Array, k: jax.Array) -> jax.Array:
    """Read k (<=16) bits LSB-first at bit position pos, per stream.

    Reads past the padded plane return zero bits (the decoder legitimately
    over-reads its CODE window by up to 16 bits near stream end)."""
    w = pos >> 5
    off = (pos & 31).astype(U32)
    r0 = gather_word(plane, w)
    r1 = gather_word(plane, w + 1)
    in0 = w < plane.shape[0]
    in1 = (w + 1) < plane.shape[0]
    r0 = jnp.where(in0, r0, U32(0))
    r1 = jnp.where(in1, r1, U32(0))
    window = shr32(r0, off) | shl32(r1, 32 - off.astype(I32))
    mask = shl32(jnp.ones_like(window), k) - U32(1)
    return window & mask


# ------------------------------------------------------------------- decode
@partial(jax.jit, static_argnames=("n_steps", "bits"))
def decode(sym_plane: jax.Array, ofs_plane: jax.Array, stored: jax.Array,
           table: TableArrays, n_steps: int, bits: int = 8) -> jax.Array:
    """Decode S streams of ``n_steps`` values each.

    Args:
      sym_plane: u32[W_s, S] word-interleaved symbol bitstreams.
      ofs_plane: u32[W_o, S] word-interleaved offset bitstreams.
      stored:    bool[S] verbatim-mode flags.
      table:     TableArrays.
      n_steps:   values per stream (E).
      bits:      value bit width.

    Returns: i32[S, n_steps] decoded values.
    """
    S = sym_plane.shape[1]
    sym_plane = sym_plane.astype(U32)
    ofs_plane = ofs_plane.astype(U32)
    cum = table.cum
    v_min = table.v_min
    ol = table.ol

    # initial CODE register: one 16-bit read; stream order = MSB of CODE first
    zeros = jnp.zeros((S,), I32)
    code0 = rev16(read_bits(sym_plane, zeros,
                            jnp.full((S,), 16, I32))).astype(I32)
    spos0 = jnp.full((S,), 16, I32)

    def step(carry, _):
        low, high, code, spos, opos = carry
        rng = high - low + 1
        cum_val = ((code - low + 1) * (1 << PCOUNT_BITS) - 1) // rng
        # largest s with cum[s] <= cum_val  (the HW comparator array)
        s_idx = jnp.sum((cum_val[:, None] >= cum[None, :-1]).astype(I32),
                        axis=1) - 1
        ol_s = jnp.take(ol, s_idx)
        clo = jnp.take(cum, s_idx)
        chi = jnp.take(cum, s_idx + 1)
        off_val = read_bits(ofs_plane, opos, ol_s).astype(I32)
        value_ac = jnp.take(v_min, s_idx) + off_val
        # stored-mode bypass
        value_st = read_bits(ofs_plane, opos, jnp.full_like(opos, bits)).astype(I32)
        value = jnp.where(stored, value_st, value_ac)
        opos = opos + jnp.where(stored, bits, ol_s)
        high2 = low + ((rng * chi) >> PCOUNT_BITS) - 1
        low2 = low + ((rng * clo) >> PCOUNT_BITS)
        low3, high3, code3, spos3 = decode_renorm(
            low, high, code, spos, low2, high2, sym_plane, stored)
        return (low3, high3, code3, spos3, opos), value

    init = (zeros, jnp.full((S,), TOP, I32), code0, spos0, zeros)
    _, values = jax.lax.scan(step, init, None, length=n_steps)
    return values.T   # [S, n_steps]


# ------------------------------------------------------------------- encode
def _append(buf_lo, buf_hi, buflen, val, k):
    """Append k (<=25) bits of val into the 64-bit stream buffer."""
    buf_lo = buf_lo | shl32(val, buflen)
    buf_hi = buf_hi | shr32(val, 32 - buflen)
    return buf_lo, buf_hi, buflen + k


def _flush(plane, widx, sidx, buf_lo, buf_hi, buflen):
    """Write one full word where buflen >= 32."""
    do = buflen >= 32
    cur = gather_word(plane, widx)
    new = jnp.where(do, buf_lo, cur)
    plane = plane.at[jnp.clip(widx, 0, plane.shape[0] - 1), sidx].set(new)
    buf_lo = jnp.where(do, buf_hi, buf_lo)
    buf_hi = jnp.where(do, U32(0), buf_hi)
    buflen = jnp.where(do, buflen - 32, buflen)
    widx = widx + do.astype(I32)
    return plane, widx, buf_lo, buf_hi, buflen


def sym_capacity_words(n_steps: int) -> int:
    # <= MAX_RENORM bits/step sustained + termination & slack
    return (n_steps * (MAX_RENORM + 2) + MAX_PENDING + 64 + 31) // 32


def ofs_capacity_words(n_steps: int, bits: int) -> int:
    return (n_steps * bits + 63) // 32


@partial(jax.jit, static_argnames=("n_steps", "bits"))
def encode_ac(values: jax.Array, table: TableArrays, n_steps: int,
              bits: int = 8):
    """Arithmetic-encode S streams (no stored-mode selection — see encode()).

    Args:
      values: i32[S, n_steps] uint values.

    Returns: (sym_plane u32[Ws,S], ofs_plane u32[Wo,S],
              sym_bits i32[S], ofs_bits i32[S], overflow bool[S])
    """
    S = values.shape[0]
    cum, v_min, ol = table.cum, table.v_min, table.ol
    Ws = sym_capacity_words(n_steps)
    Wo = ofs_capacity_words(n_steps, bits)
    sidx = jnp.arange(S)

    # hoisted symbol search + table gathers: one vectorized pass over the
    # whole [S, E] block; the serial scan below only touches AC state and
    # the bit buffers.
    vals = values.astype(I32)
    s_idx = (jnp.searchsorted(v_min[:-1], vals.reshape(-1),
                              side="right").astype(I32) - 1).reshape(vals.shape)
    ol_all = jnp.take(ol, s_idx)                         # [S, E]
    off_all = (vals - jnp.take(v_min, s_idx)).astype(U32)
    clo_all = jnp.take(cum, s_idx)
    chi_all = jnp.take(cum, s_idx + 1)

    def step(carry, xs):
        (low, high, pending, overflow,
         s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
         o_plane, o_widx, o_lo, o_hi, o_len, o_bits) = carry
        off, ol_s, clo, chi = xs
        # offset emission
        o_lo, o_hi, o_len = _append(o_lo, o_hi, o_len, off, ol_s)
        o_bits = o_bits + ol_s
        o_plane, o_widx, o_lo, o_hi, o_len = _flush(o_plane, o_widx, sidx,
                                                    o_lo, o_hi, o_len)
        # range update
        rng = high - low + 1
        high2 = low + ((rng * chi) >> PCOUNT_BITS) - 1
        low2 = low + ((rng * clo) >> PCOUNT_BITS)

        # multi-bit renormalization: all matched leading bits + pending
        # underflow bits emitted in two appends (see encode_renorm)
        low, high, pending, pat1, k1, pat2, k2 = encode_renorm(
            low2, high2, pending)
        s_lo, s_hi, s_len = _append(s_lo, s_hi, s_len, pat1, k1)
        s_bits = s_bits + k1
        s_plane, s_widx, s_lo, s_hi, s_len = _flush(s_plane, s_widx, sidx,
                                                    s_lo, s_hi, s_len)
        s_lo, s_hi, s_len = _append(s_lo, s_hi, s_len, pat2, k2)
        s_bits = s_bits + k2
        s_plane, s_widx, s_lo, s_hi, s_len = _flush(s_plane, s_widx, sidx,
                                                    s_lo, s_hi, s_len)
        overflow = overflow | (pending > MAX_PENDING)
        return (low, high, pending, overflow,
                s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
                o_plane, o_widx, o_lo, o_hi, o_len, o_bits), None

    zeros = jnp.zeros((S,), I32)
    zerosu = jnp.zeros((S,), U32)
    init = (zeros, jnp.full((S,), TOP, I32), zeros, jnp.zeros((S,), bool),
            jnp.zeros((Ws, S), U32), zeros, zerosu, zerosu, zeros, zeros,
            jnp.zeros((Wo, S), U32), zeros, zerosu, zerosu, zeros, zeros)
    carry, _ = jax.lax.scan(step, init,
                            (off_all.T, ol_all.T, clo_all.T, chi_all.T))
    (low, high, pending, overflow,
     s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
     o_plane, o_widx, o_lo, o_hi, o_len, o_bits) = carry

    # termination: disambiguate the final quarter (golden encode_stream)
    pending = pending + 1
    b = (low >= QUARTER).astype(U32)
    inv_run = (shl32(jnp.ones_like(b), pending) - U32(1)) * (U32(1) - b)
    pattern = b | (inv_run << 1)
    k = 1 + pending
    s_lo, s_hi, s_len = _append(s_lo, s_hi, s_len, pattern, k)
    s_bits = s_bits + k
    for _ in range(3):      # drain buffer (<= 56 + 25 bits)
        s_plane, s_widx, s_lo, s_hi, s_len = _flush(
            s_plane, s_widx, sidx, s_lo, s_hi, s_len)
    # final partial words
    def drain(plane, widx, blo, blen):
        do = blen > 0
        cur = gather_word(plane, widx)
        new = jnp.where(do, blo, cur)
        return plane.at[jnp.clip(widx, 0, plane.shape[0] - 1), sidx].set(new)
    s_plane = drain(s_plane, s_widx, s_lo, s_len)
    o_plane = drain(o_plane, o_widx, o_lo, o_len)
    return s_plane, o_plane, s_bits, o_bits, overflow


@partial(jax.jit, static_argnames=("n_steps", "bits"))
def pack_raw(values: jax.Array, n_steps: int, bits: int = 8):
    """Verbatim bit-pack (stored mode): i32[S, E] -> u32[Wo, S]."""
    S = values.shape[0]
    Wo = ofs_capacity_words(n_steps, bits)
    sidx = jnp.arange(S)
    zeros = jnp.zeros((S,), I32)
    zerosu = jnp.zeros((S,), U32)

    def step(carry, v):
        plane, widx, blo, bhi, blen = carry
        blo, bhi, blen = _append(blo, bhi, blen, v.astype(U32),
                                 jnp.full((S,), bits, I32))
        plane, widx, blo, bhi, blen = _flush(plane, widx, sidx, blo, bhi, blen)
        return (plane, widx, blo, bhi, blen), None

    init = (jnp.zeros((Wo, S), U32), zeros, zerosu, zerosu, zeros)
    (plane, widx, blo, bhi, blen), _ = jax.lax.scan(step, init,
                                                    values.T.astype(I32))
    do = blen > 0
    cur = gather_word(plane, widx)
    plane = plane.at[jnp.clip(widx, 0, plane.shape[0] - 1), sidx].set(
        jnp.where(do, blo, cur))
    return plane


def encode(values: jax.Array, table: TableArrays, n_steps: int,
           bits: int = 8):
    """Full encoder: AC encode + per-stream stored-mode selection.

    Returns (sym_plane, ofs_plane, sym_bits, ofs_bits, stored).
    Stored streams hold verbatim values in the offset plane; their symbol
    column is zeroed.  Bit-identical to ``core.format.compress``.
    """
    s_plane, o_plane, s_bits, o_bits, overflow = encode_ac(
        values, table, n_steps, bits)
    raw_plane = pack_raw(values, n_steps, bits)
    stored = overflow | ((s_bits + o_bits) >= n_steps * bits)
    Wo = max(o_plane.shape[0], raw_plane.shape[0])

    def pad_to(p, w):
        return jnp.pad(p, ((0, w - p.shape[0]), (0, 0)))

    o_plane = jnp.where(stored[None, :], pad_to(raw_plane, Wo),
                        pad_to(o_plane, Wo))
    s_plane = jnp.where(stored[None, :], U32(0), s_plane)
    s_bits = jnp.where(stored, 0, s_bits)
    o_bits = jnp.where(stored, n_steps * bits, o_bits)
    return s_plane, o_plane, s_bits, o_bits, stored

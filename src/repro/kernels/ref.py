"""Vectorized pure-jnp multi-stream APack codec — the kernel oracle.

This is the paper's §V-B replication strategy in TPU-native form: instead of
64 discrete encoder/decoder engines, S independent substreams are coded in
lockstep, one stream per vector lane, with ``lax.scan`` playing the role of
the hardware's per-cycle step.  The arithmetic is the *identical*
finite-precision coder as ``core/ac_golden.py`` (16-bit HI/LO windows,
10-bit counts, WNC renormalization) and is asserted bit-exact against it.

The Pallas kernels in ``apack_decode.py`` / ``apack_encode.py`` mirror this
file operation-for-operation; this module doubles as the production software
path on CPU.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ac_golden import (HALF, MAX_PENDING, MAX_RENORM, PCOUNT_BITS,
                                  QUARTER, THREEQ, TOP)
from repro.core.tables import ApackTable

U32 = jnp.uint32
I32 = jnp.int32


class TableArrays(NamedTuple):
    """jnp view of an ApackTable (17/16/17-entry vectors)."""
    v_min: jax.Array   # i32[17]
    ol: jax.Array      # i32[16]
    cum: jax.Array     # i32[17]

    @classmethod
    def from_table(cls, t: ApackTable) -> "TableArrays":
        return cls(jnp.asarray(t.v_min, I32), jnp.asarray(t.ol, I32),
                   jnp.asarray(t.cum, I32))


# --------------------------------------------------------------- bit helpers
def shr32(x: jax.Array, k: jax.Array) -> jax.Array:
    """Logical right shift, correct for k in [0, 32]."""
    kc = jnp.minimum(k, 31).astype(U32)
    return jnp.where(k >= 32, U32(0), (x.astype(U32) >> kc))


def shl32(x: jax.Array, k: jax.Array) -> jax.Array:
    """Left shift, correct for k in [0, 32]."""
    kc = jnp.minimum(k, 31).astype(U32)
    return jnp.where(k >= 32, U32(0), (x.astype(U32) << kc))


def gather_word(plane: jax.Array, w: jax.Array) -> jax.Array:
    """plane[w[s], s] for each stream s.  plane: u32[W, S], w: i32[S]."""
    wc = jnp.clip(w, 0, plane.shape[0] - 1)
    return jnp.take_along_axis(plane, wc[None, :], axis=0)[0]


def read_bits(plane: jax.Array, pos: jax.Array, k: jax.Array) -> jax.Array:
    """Read k (<=16) bits LSB-first at bit position pos, per stream.

    Reads past the padded plane return zero bits (the decoder legitimately
    over-reads its CODE window by up to 16 bits near stream end)."""
    w = pos >> 5
    off = (pos & 31).astype(U32)
    r0 = gather_word(plane, w)
    r1 = gather_word(plane, w + 1)
    in0 = w < plane.shape[0]
    in1 = (w + 1) < plane.shape[0]
    r0 = jnp.where(in0, r0, U32(0))
    r1 = jnp.where(in1, r1, U32(0))
    window = shr32(r0, off) | shl32(r1, 32 - off.astype(I32))
    mask = shl32(jnp.ones_like(window), k) - U32(1)
    return window & mask


# ------------------------------------------------------------------- decode
@partial(jax.jit, static_argnames=("n_steps", "bits"))
def decode(sym_plane: jax.Array, ofs_plane: jax.Array, stored: jax.Array,
           table: TableArrays, n_steps: int, bits: int = 8) -> jax.Array:
    """Decode S streams of ``n_steps`` values each.

    Args:
      sym_plane: u32[W_s, S] word-interleaved symbol bitstreams.
      ofs_plane: u32[W_o, S] word-interleaved offset bitstreams.
      stored:    bool[S] verbatim-mode flags.
      table:     TableArrays.
      n_steps:   values per stream (E).
      bits:      value bit width.

    Returns: i32[S, n_steps] decoded values.
    """
    S = sym_plane.shape[1]
    sym_plane = sym_plane.astype(U32)
    ofs_plane = ofs_plane.astype(U32)
    cum = table.cum
    v_min = table.v_min
    ol = table.ol

    # initial CODE register: 16 bits, stream order = MSB first
    def load_code(i, st):
        code, spos = st
        b = read_bits(sym_plane, spos, jnp.ones_like(spos)).astype(I32)
        return code * 2 + b, spos + 1

    zeros = jnp.zeros((S,), I32)
    code0, spos0 = jax.lax.fori_loop(0, 16, load_code, (zeros, zeros))

    def step(carry, _):
        low, high, code, spos, opos = carry
        rng = high - low + 1
        cum_val = ((code - low + 1) * (1 << PCOUNT_BITS) - 1) // rng
        # largest s with cum[s] <= cum_val  (the HW comparator array)
        s_idx = jnp.sum((cum_val[:, None] >= cum[None, :-1]).astype(I32),
                        axis=1) - 1
        ol_s = jnp.take(ol, s_idx)
        clo = jnp.take(cum, s_idx)
        chi = jnp.take(cum, s_idx + 1)
        off_val = read_bits(ofs_plane, opos, ol_s).astype(I32)
        value_ac = jnp.take(v_min, s_idx) + off_val
        # stored-mode bypass
        value_st = read_bits(ofs_plane, opos, jnp.full_like(opos, bits)).astype(I32)
        value = jnp.where(stored, value_st, value_ac)
        opos = opos + jnp.where(stored, bits, ol_s)
        high2 = low + ((rng * chi) >> PCOUNT_BITS) - 1
        low2 = low + ((rng * clo) >> PCOUNT_BITS)

        def renorm(i, st):
            lo, hi, cd, sp, act = st
            c1 = hi < HALF
            c2 = lo >= HALF
            c3 = (lo >= QUARTER) & (hi < THREEQ)
            do = act & (c1 | c2 | c3)
            sub = jnp.where(c1, 0, jnp.where(c2, HALF, QUARTER))
            bit = read_bits(sym_plane, sp, jnp.ones_like(sp)).astype(I32)
            lo_n = (lo - sub) * 2
            hi_n = (hi - sub) * 2 + 1
            cd_n = (cd - sub) * 2 + bit
            return (jnp.where(do, lo_n, lo), jnp.where(do, hi_n, hi),
                    jnp.where(do, cd_n, cd), sp + do.astype(I32), do)

        low3, high3, code3, spos3, _ = jax.lax.fori_loop(
            0, MAX_RENORM, renorm,
            (low2, high2, code, spos, jnp.logical_not(stored)))
        # stored streams keep AC state frozen
        low3 = jnp.where(stored, low, low3)
        high3 = jnp.where(stored, high, high3)
        return (low3, high3, code3, spos3, opos), value

    init = (zeros, jnp.full((S,), TOP, I32), code0, spos0, zeros)
    _, values = jax.lax.scan(step, init, None, length=n_steps)
    return values.T   # [S, n_steps]


# ------------------------------------------------------------------- encode
def _append(buf_lo, buf_hi, buflen, val, k):
    """Append k (<=25) bits of val into the 64-bit stream buffer."""
    buf_lo = buf_lo | shl32(val, buflen)
    buf_hi = buf_hi | shr32(val, 32 - buflen)
    return buf_lo, buf_hi, buflen + k


def _flush(plane, widx, sidx, buf_lo, buf_hi, buflen):
    """Write one full word where buflen >= 32."""
    do = buflen >= 32
    cur = gather_word(plane, widx)
    new = jnp.where(do, buf_lo, cur)
    plane = plane.at[jnp.clip(widx, 0, plane.shape[0] - 1), sidx].set(new)
    buf_lo = jnp.where(do, buf_hi, buf_lo)
    buf_hi = jnp.where(do, U32(0), buf_hi)
    buflen = jnp.where(do, buflen - 32, buflen)
    widx = widx + do.astype(I32)
    return plane, widx, buf_lo, buf_hi, buflen


def sym_capacity_words(n_steps: int) -> int:
    # <= MAX_RENORM bits/step sustained + termination & slack
    return (n_steps * (MAX_RENORM + 2) + MAX_PENDING + 64 + 31) // 32


def ofs_capacity_words(n_steps: int, bits: int) -> int:
    return (n_steps * bits + 63) // 32


@partial(jax.jit, static_argnames=("n_steps", "bits"))
def encode_ac(values: jax.Array, table: TableArrays, n_steps: int,
              bits: int = 8):
    """Arithmetic-encode S streams (no stored-mode selection — see encode()).

    Args:
      values: i32[S, n_steps] uint values.

    Returns: (sym_plane u32[Ws,S], ofs_plane u32[Wo,S],
              sym_bits i32[S], ofs_bits i32[S], overflow bool[S])
    """
    S = values.shape[0]
    cum, v_min, ol = table.cum, table.v_min, table.ol
    Ws = sym_capacity_words(n_steps)
    Wo = ofs_capacity_words(n_steps, bits)
    sidx = jnp.arange(S)

    def step(carry, v):
        (low, high, pending, overflow,
         s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
         o_plane, o_widx, o_lo, o_hi, o_len, o_bits) = carry
        # symbol lookup (largest s with v_min[s] <= v)
        s_idx = jnp.sum((v[:, None] >= v_min[None, :-1]).astype(I32), axis=1) - 1
        ol_s = jnp.take(ol, s_idx)
        # offset emission
        off = (v - jnp.take(v_min, s_idx)).astype(U32)
        o_lo, o_hi, o_len = _append(o_lo, o_hi, o_len, off, ol_s)
        o_bits = o_bits + ol_s
        o_plane, o_widx, o_lo, o_hi, o_len = _flush(o_plane, o_widx, sidx,
                                                    o_lo, o_hi, o_len)
        # range update
        rng = high - low + 1
        chi = jnp.take(cum, s_idx + 1)
        clo = jnp.take(cum, s_idx)
        high = low + ((rng * chi) >> PCOUNT_BITS) - 1
        low = low + ((rng * clo) >> PCOUNT_BITS)

        def renorm(i, st):
            (lo, hi, pend, ovf, plane, widx, blo, bhi, blen, bits_out, act) = st
            c1 = hi < HALF
            c2 = lo >= HALF
            c3 = (lo >= QUARTER) & (hi < THREEQ)
            do = act & (c1 | c2 | c3)
            emit = do & (c1 | c2)
            b = c2.astype(U32)                         # emitted bit
            # bit + pending inverted bits, LSB-first: b | (~b)*pending << 1
            inv_run = (shl32(jnp.ones_like(b), pend) - U32(1)) * (U32(1) - b)
            pattern = b | (inv_run << 1)
            k = jnp.where(emit, 1 + pend, 0)
            blo, bhi, blen = _append(blo, bhi, blen,
                                     jnp.where(emit, pattern, U32(0)), k)
            bits_out = bits_out + k
            pend_n = jnp.where(emit, 0, jnp.where(do, pend + 1, pend))
            ovf = ovf | (pend_n > MAX_PENDING)
            sub = jnp.where(c1, 0, jnp.where(c2, HALF, QUARTER))
            lo_n = (lo - sub) * 2
            hi_n = (hi - sub) * 2 + 1
            lo = jnp.where(do, lo_n, lo)
            hi = jnp.where(do, hi_n, hi)
            plane, widx, blo, bhi, blen = _flush(plane, widx, sidx,
                                                 blo, bhi, blen)
            return (lo, hi, pend_n, ovf, plane, widx, blo, bhi, blen,
                    bits_out, do)

        (low, high, pending, overflow, s_plane, s_widx, s_lo, s_hi, s_len,
         s_bits, _) = jax.lax.fori_loop(
            0, MAX_RENORM, renorm,
            (low, high, pending, overflow, s_plane, s_widx, s_lo, s_hi,
             s_len, s_bits, jnp.ones((S,), bool)))
        return (low, high, pending, overflow,
                s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
                o_plane, o_widx, o_lo, o_hi, o_len, o_bits), None

    zeros = jnp.zeros((S,), I32)
    zerosu = jnp.zeros((S,), U32)
    init = (zeros, jnp.full((S,), TOP, I32), zeros, jnp.zeros((S,), bool),
            jnp.zeros((Ws, S), U32), zeros, zerosu, zerosu, zeros, zeros,
            jnp.zeros((Wo, S), U32), zeros, zerosu, zerosu, zeros, zeros)
    carry, _ = jax.lax.scan(step, init, values.T.astype(I32))
    (low, high, pending, overflow,
     s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
     o_plane, o_widx, o_lo, o_hi, o_len, o_bits) = carry

    # termination: disambiguate the final quarter (golden encode_stream)
    pending = pending + 1
    b = (low >= QUARTER).astype(U32)
    inv_run = (shl32(jnp.ones_like(b), pending) - U32(1)) * (U32(1) - b)
    pattern = b | (inv_run << 1)
    k = 1 + pending
    s_lo, s_hi, s_len = _append(s_lo, s_hi, s_len, pattern, k)
    s_bits = s_bits + k
    for _ in range(3):      # drain buffer (<= 56 + 25 bits)
        s_plane, s_widx, s_lo, s_hi, s_len = _flush(
            s_plane, s_widx, sidx, s_lo, s_hi, s_len)
    # final partial words
    def drain(plane, widx, blo, blen):
        do = blen > 0
        cur = gather_word(plane, widx)
        new = jnp.where(do, blo, cur)
        return plane.at[jnp.clip(widx, 0, plane.shape[0] - 1), sidx].set(new)
    s_plane = drain(s_plane, s_widx, s_lo, s_len)
    o_plane = drain(o_plane, o_widx, o_lo, o_len)
    return s_plane, o_plane, s_bits, o_bits, overflow


@partial(jax.jit, static_argnames=("n_steps", "bits"))
def pack_raw(values: jax.Array, n_steps: int, bits: int = 8):
    """Verbatim bit-pack (stored mode): i32[S, E] -> u32[Wo, S]."""
    S = values.shape[0]
    Wo = ofs_capacity_words(n_steps, bits)
    sidx = jnp.arange(S)
    zeros = jnp.zeros((S,), I32)
    zerosu = jnp.zeros((S,), U32)

    def step(carry, v):
        plane, widx, blo, bhi, blen = carry
        blo, bhi, blen = _append(blo, bhi, blen, v.astype(U32),
                                 jnp.full((S,), bits, I32))
        plane, widx, blo, bhi, blen = _flush(plane, widx, sidx, blo, bhi, blen)
        return (plane, widx, blo, bhi, blen), None

    init = (jnp.zeros((Wo, S), U32), zeros, zerosu, zerosu, zeros)
    (plane, widx, blo, bhi, blen), _ = jax.lax.scan(step, init,
                                                    values.T.astype(I32))
    do = blen > 0
    cur = gather_word(plane, widx)
    plane = plane.at[jnp.clip(widx, 0, plane.shape[0] - 1), sidx].set(
        jnp.where(do, blo, cur))
    return plane


def encode(values: jax.Array, table: TableArrays, n_steps: int,
           bits: int = 8):
    """Full encoder: AC encode + per-stream stored-mode selection.

    Returns (sym_plane, ofs_plane, sym_bits, ofs_bits, stored).
    Stored streams hold verbatim values in the offset plane; their symbol
    column is zeroed.  Bit-identical to ``core.format.compress``.
    """
    s_plane, o_plane, s_bits, o_bits, overflow = encode_ac(
        values, table, n_steps, bits)
    raw_plane = pack_raw(values, n_steps, bits)
    stored = overflow | ((s_bits + o_bits) >= n_steps * bits)
    Wo = max(o_plane.shape[0], raw_plane.shape[0])

    def pad_to(p, w):
        return jnp.pad(p, ((0, w - p.shape[0]), (0, 0)))

    o_plane = jnp.where(stored[None, :], pad_to(raw_plane, Wo),
                        pad_to(o_plane, Wo))
    s_plane = jnp.where(stored[None, :], U32(0), s_plane)
    s_bits = jnp.where(stored, 0, s_bits)
    o_bits = jnp.where(stored, n_steps * bits, o_bits)
    return s_plane, o_plane, s_bits, o_bits, stored

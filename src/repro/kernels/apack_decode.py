"""Pallas TPU decoder kernel for APack streams.

TPU mapping of the paper's decoder array (§V-A): one *grid program* decodes a
block of ``BLOCK_STREAMS`` substreams, one stream per vector lane, stepping
``fori_loop`` over symbols — the lane dimension plays the role of the paper's
replicated decoder engines, the loop plays the per-cycle step.  BlockSpecs
tile the word-interleaved planes so each program's working set (compressed
words in + decoded block out) sits in VMEM; on real hardware the HBM->VMEM
DMA moves only compressed words, which is exactly where the paper's off-chip
traffic saving materializes.

Per-step state (HI/LO/CODE registers, bit cursors) is a handful of
[BLOCK_STREAMS] i32 vectors — the Pallas analogue of the paper's "3 16b and
1 8b registers" per engine.  The per-lane dynamic word fetch
(``take_along_axis`` on the VMEM-resident plane) lowers to a TPU vector
gather along the sublane dimension; validated bit-exact in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ac_golden import PCOUNT_BITS, TOP
from .ref import decode_renorm, read_bits, rev16

I32 = jnp.int32
U32 = jnp.uint32
BLOCK_STREAMS = 128


def decode_block(sym_plane, ofs_plane, stored, v_min, ol, cum,
                 *, n_steps: int, bits: int):
    """Decode a [*, NS] stream block to values i32[NS, n_steps].

    Pure-jnp body shared by the standalone decoder kernel and the fused
    decompress+matmul kernel."""
    ns = sym_plane.shape[1]
    zeros = jnp.zeros((ns,), I32)

    # initial CODE register: one 16-bit read, bit-reversed to MSB-first
    code0 = rev16(read_bits(sym_plane, zeros,
                            jnp.full((ns,), 16, I32))).astype(I32)
    spos0 = jnp.full((ns,), 16, I32)

    def step(i, carry):
        low, high, code, spos, opos, out = carry
        rng = high - low + 1
        cum_val = ((code - low + 1) * (1 << PCOUNT_BITS) - 1) // rng
        s_idx = jnp.sum((cum_val[:, None] >= cum[None, :-1]).astype(I32),
                        axis=1) - 1
        ol_s = jnp.take(ol, s_idx)
        clo = jnp.take(cum, s_idx)
        chi = jnp.take(cum, s_idx + 1)
        off_val = read_bits(ofs_plane, opos, ol_s).astype(I32)
        value_ac = jnp.take(v_min, s_idx) + off_val
        value_st = read_bits(ofs_plane, opos,
                             jnp.full_like(opos, bits)).astype(I32)
        value = jnp.where(stored, value_st, value_ac)
        opos = opos + jnp.where(stored, bits, ol_s)
        high2 = low + ((rng * chi) >> PCOUNT_BITS) - 1
        low2 = low + ((rng * clo) >> PCOUNT_BITS)
        low3, high3, code3, spos3 = decode_renorm(
            low, high, code, spos, low2, high2, sym_plane, stored)
        out = jax.lax.dynamic_update_slice(out, value[:, None], (0, i))
        return (low3, high3, code3, spos3, opos, out)

    init = (zeros, jnp.full((ns,), TOP, I32), code0, spos0, zeros,
            jnp.zeros((ns, n_steps), I32))
    carry = jax.lax.fori_loop(0, n_steps, step, init)
    return carry[-1]


def _decode_kernel(sym_ref, ofs_ref, stored_ref, vmin_ref, ol_ref, cum_ref,
                   out_ref, *, n_steps: int, bits: int):
    out_ref[...] = decode_block(
        sym_ref[...].astype(U32), ofs_ref[...].astype(U32),
        stored_ref[...] != 0, vmin_ref[...], ol_ref[...], cum_ref[...],
        n_steps=n_steps, bits=bits)


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "bits", "block_streams",
                                    "interpret"))
def decode_pallas(sym_plane: jax.Array, ofs_plane: jax.Array,
                  stored: jax.Array, v_min: jax.Array, ol: jax.Array,
                  cum: jax.Array, *, n_steps: int, bits: int = 8,
                  block_streams: int = BLOCK_STREAMS,
                  interpret: bool = True) -> jax.Array:
    """Decode S streams (S must be a multiple of ``block_streams``;
    ``ops.apack_decode`` handles padding).  Returns i32[S, n_steps]."""
    ws, s = sym_plane.shape
    wo = ofs_plane.shape[0]
    assert s % block_streams == 0, "pad streams before calling the kernel"
    grid = (s // block_streams,)
    kernel = functools.partial(_decode_kernel, n_steps=n_steps, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ws, block_streams), lambda j: (0, j)),
            pl.BlockSpec((wo, block_streams), lambda j: (0, j)),
            pl.BlockSpec((block_streams,), lambda j: (j,)),
            pl.BlockSpec((17,), lambda j: (0,)),
            pl.BlockSpec((16,), lambda j: (0,)),
            pl.BlockSpec((17,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_streams, n_steps), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((s, n_steps), I32),
        interpret=interpret,
    )(sym_plane.astype(U32), ofs_plane.astype(U32), stored.astype(I32),
      v_min.astype(I32), ol.astype(I32), cum.astype(I32))

"""Pallas TPU encoder kernel for APack streams.

Mirror of ``apack_decode``: one grid program arithmetically encodes a block
of ``BLOCK_STREAMS`` substreams lane-parallel (paper §V "each encoder can
encode one value per cycle" -> one value per lane per loop step).  The
64-bit software bit-buffer (two u32 vectors + length) plays the role of the
paper's CODE_out/OUT_u port pair: each renormalization iteration appends the
emitted bit plus any pending underflow bits, and full words retire into the
word-interleaved output plane.

The kernel always produces the AC encoding plus per-stream bit counts and
overflow flags; stored-mode selection (AC-inflated or overflowed streams
fall back to verbatim packing) happens in ``ops.apack_encode`` exactly as in
the jnp reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ac_golden import MAX_PENDING, PCOUNT_BITS, QUARTER, TOP
from .ref import (encode_renorm, ofs_capacity_words, shl32, shr32,
                  sym_capacity_words)

I32 = jnp.int32
U32 = jnp.uint32
BLOCK_STREAMS = 128


def _append(buf_lo, buf_hi, buflen, val, k):
    buf_lo = buf_lo | shl32(val, buflen)
    buf_hi = buf_hi | shr32(val, 32 - buflen)
    return buf_lo, buf_hi, buflen + k


def _flush(plane, widx, buf_lo, buf_hi, buflen):
    """Retire one full word per stream where buflen >= 32 (functional)."""
    do = buflen >= 32
    w = jnp.clip(widx, 0, plane.shape[0] - 1)
    cur = jnp.take_along_axis(plane, w[None, :], axis=0)[0]
    new = jnp.where(do, buf_lo, cur)
    plane = plane.at[w, jnp.arange(plane.shape[1])].set(new)
    buf_lo = jnp.where(do, buf_hi, buf_lo)
    buf_hi = jnp.where(do, U32(0), buf_hi)
    buflen = jnp.where(do, buflen - 32, buflen)
    return plane, widx + do.astype(I32), buf_lo, buf_hi, buflen


def _encode_kernel(values_ref, vmin_ref, ol_ref, cum_ref,
                   sym_ref, ofs_ref, sym_bits_ref, ofs_bits_ref, ovf_ref,
                   *, n_steps: int, bits: int):
    values = values_ref[...]                  # [NS, E] i32
    v_min = vmin_ref[...]
    ol = ol_ref[...]
    cum = cum_ref[...]
    ns = values.shape[0]
    ws = sym_ref.shape[0]
    wo = ofs_ref.shape[0]
    zeros = jnp.zeros((ns,), I32)
    zerosu = jnp.zeros((ns,), U32)

    # hoisted symbol search + table gathers, vectorized over the whole
    # [NS, E] block (16 unrolled compares stand in for the HW comparator
    # array); the serial loop below only touches AC state and bit buffers.
    s_idx = -jnp.ones(values.shape, I32)
    for i in range(16):
        s_idx = s_idx + (values >= v_min[i]).astype(I32)
    ol_all = jnp.take(ol, s_idx)                         # [NS, E]
    off_all = (values - jnp.take(v_min, s_idx)).astype(U32)
    clo_all = jnp.take(cum, s_idx)
    chi_all = jnp.take(cum, s_idx + 1)

    def step(i, carry):
        (low, high, pending, overflow,
         s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
         o_plane, o_widx, o_lo, o_hi, o_len, o_bits) = carry
        ol_s = jax.lax.dynamic_slice(ol_all, (0, i), (ns, 1))[:, 0]
        off = jax.lax.dynamic_slice(off_all, (0, i), (ns, 1))[:, 0]
        clo = jax.lax.dynamic_slice(clo_all, (0, i), (ns, 1))[:, 0]
        chi = jax.lax.dynamic_slice(chi_all, (0, i), (ns, 1))[:, 0]
        o_lo, o_hi, o_len = _append(o_lo, o_hi, o_len, off, ol_s)
        o_bits = o_bits + ol_s
        o_plane, o_widx, o_lo, o_hi, o_len = _flush(o_plane, o_widx,
                                                    o_lo, o_hi, o_len)
        rng = high - low + 1
        high2 = low + ((rng * chi) >> PCOUNT_BITS) - 1
        low2 = low + ((rng * clo) >> PCOUNT_BITS)

        # multi-bit renormalization: all matched leading bits + pending
        # underflow bits emitted in two appends (see ref.encode_renorm)
        low, high, pending, pat1, k1, pat2, k2 = encode_renorm(
            low2, high2, pending)
        s_lo, s_hi, s_len = _append(s_lo, s_hi, s_len, pat1, k1)
        s_bits = s_bits + k1
        s_plane, s_widx, s_lo, s_hi, s_len = _flush(s_plane, s_widx,
                                                    s_lo, s_hi, s_len)
        s_lo, s_hi, s_len = _append(s_lo, s_hi, s_len, pat2, k2)
        s_bits = s_bits + k2
        s_plane, s_widx, s_lo, s_hi, s_len = _flush(s_plane, s_widx,
                                                    s_lo, s_hi, s_len)
        overflow = overflow | (pending > MAX_PENDING)
        return (low, high, pending, overflow,
                s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
                o_plane, o_widx, o_lo, o_hi, o_len, o_bits)

    init = (zeros, jnp.full((ns,), TOP, I32), zeros, jnp.zeros((ns,), bool),
            jnp.zeros((ws, ns), U32), zeros, zerosu, zerosu, zeros, zeros,
            jnp.zeros((wo, ns), U32), zeros, zerosu, zerosu, zeros, zeros)
    (low, high, pending, overflow,
     s_plane, s_widx, s_lo, s_hi, s_len, s_bits,
     o_plane, o_widx, o_lo, o_hi, o_len, o_bits) = jax.lax.fori_loop(
        0, n_steps, step, init)

    # termination
    pending = pending + 1
    b = (low >= QUARTER).astype(U32)
    inv_run = (shl32(jnp.ones_like(b), pending) - U32(1)) * (U32(1) - b)
    pattern = b | (inv_run << 1)
    s_lo, s_hi, s_len = _append(s_lo, s_hi, s_len, pattern, 1 + pending)
    s_bits = s_bits + 1 + pending
    for _ in range(3):
        s_plane, s_widx, s_lo, s_hi, s_len = _flush(s_plane, s_widx,
                                                    s_lo, s_hi, s_len)

    def drain(plane, widx, blo, blen):
        do = blen > 0
        w = jnp.clip(widx, 0, plane.shape[0] - 1)
        cur = jnp.take_along_axis(plane, w[None, :], axis=0)[0]
        return plane.at[w, jnp.arange(ns)].set(jnp.where(do, blo, cur))

    sym_ref[...] = drain(s_plane, s_widx, s_lo, s_len)
    ofs_ref[...] = drain(o_plane, o_widx, o_lo, o_len)
    sym_bits_ref[...] = s_bits
    ofs_bits_ref[...] = o_bits
    ovf_ref[...] = overflow.astype(I32)


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "bits", "block_streams",
                                    "interpret"))
def encode_pallas(values: jax.Array, v_min: jax.Array, ol: jax.Array,
                  cum: jax.Array, *, n_steps: int, bits: int = 8,
                  block_streams: int = BLOCK_STREAMS,
                  interpret: bool = True):
    """AC-encode S streams of values i32[S, E].  S % block_streams == 0.

    Returns (sym_plane u32[Ws,S], ofs_plane u32[Wo,S], sym_bits, ofs_bits,
    overflow) — identical contract to ``ref.encode_ac``."""
    s, e = values.shape
    assert e == n_steps and s % block_streams == 0
    ws = sym_capacity_words(n_steps)
    wo = ofs_capacity_words(n_steps, bits)
    grid = (s // block_streams,)
    kernel = functools.partial(_encode_kernel, n_steps=n_steps, bits=bits)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_streams, n_steps), lambda j: (j, 0)),
            pl.BlockSpec((17,), lambda j: (0,)),
            pl.BlockSpec((16,), lambda j: (0,)),
            pl.BlockSpec((17,), lambda j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((ws, block_streams), lambda j: (0, j)),
            pl.BlockSpec((wo, block_streams), lambda j: (0, j)),
            pl.BlockSpec((block_streams,), lambda j: (j,)),
            pl.BlockSpec((block_streams,), lambda j: (j,)),
            pl.BlockSpec((block_streams,), lambda j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((ws, s), U32),
            jax.ShapeDtypeStruct((wo, s), U32),
            jax.ShapeDtypeStruct((s,), I32),
            jax.ShapeDtypeStruct((s,), I32),
            jax.ShapeDtypeStruct((s,), I32),
        ],
        interpret=interpret,
    )(values.astype(I32), v_min.astype(I32), ol.astype(I32), cum.astype(I32))
    return outs

"""Fused paged gather-decode + attention Pallas kernel.

Decode-side analogue of ``decompress_matmul``: the APack-compressed KV page
pool stays in HBM and each grid step decodes ONE page tile into VMEM
scratch and immediately computes its QK^T / PV contribution with an
online-softmax accumulator — attention never reads a dense materialized
cache for PACKED pages, so the off-chip KV stream is the *compressed*
footprint (paper Fig. 1 applied to the decode read path).

Grid is ``(jobs, pages)`` with pages innermost; a job is one (batch slot)
of one attention layer.  Two scalar-prefetch vectors drive the BlockSpec
index maps exactly like ``kernels/paged_decode.py``: ``page_idx`` selects
which pool page each grid step DMAs, ``table_idx`` selects the K-table row
of the stacked activation tables (the V row is always ``table_idx + 1``).
Table rows are the flat ``(generation, layer, kind)`` address of
``paged_decode.table_row`` — the pool is ``[(G+1) * 2 * n_layers, ...]``
with one generation appended per table refresh, so pages packed before and
after a refresh attend side by side in one launch, each decoding with the
table generation it was coded under (the per-page id rides the scalar
prefetch, nothing in the kernel body changes across refreshes).

Per-page state dispatch happens in-kernel (``pl.when`` on the page
lifecycle):

* ``HOT``    — raw per-token int8 + per-(token, head) scales, read directly
               (the newest, not-yet-sealed tokens);
* ``COLD``   — page-requantized int8 + per-(page, head) scales;
* ``PACKED`` — APack planes, decoded via the shared ``decode_block`` body.

Masking is by *absolute* token position: ``t0 + offset < qpos`` (causal;
the current token's contribution is merged by the caller, see
``modules.paged_attention_step``) and ``t0 + offset > qpos - window`` for
rolling layers — evicted and partially-rolled-out pages mask in-kernel, no
ring buffer is ever materialized.  The online-softmax accumulator
``(acc, m, l)`` is returned *unnormalized* so the caller can merge the
current token's self-attention term before dividing.

Interpret mode is the validated contract on CPU (bit-identical to the
pure-jnp ``fused_page_attention_ref``); the same kernel compiles on TPU
with the pool planes resident in HBM.  The output block for a job is
revisited across the page-innermost grid steps — the same Mosaic revisit
caveat as ``decompress_matmul`` applies before enabling compiled mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as _ref
from .apack_decode import decode_block

I32 = jnp.int32
U32 = jnp.uint32
F32 = jnp.float32

# page lifecycle states — must match models/modules.py (re-declared here so
# the kernel module has no model dependency)
PAGE_FREE, PAGE_HOT, PAGE_COLD, PAGE_PACKED = 0, 1, 2, 3

NEG_INF = -1e30          # same mask value as the dense attention paths


def _page_tile(state, h0, tok_ref, tok_s_ref, cold_ref, pscale_ref, sym_ref,
               ofs_ref, stored_ref, vm_ref, ol_ref, cum_ref, tile_ref, *,
               ps, h, dh, h_full, n_steps, bits):
    """Fill ``tile_ref`` ([ps, H, dh] f32 VMEM scratch) with the
    dequantized K or V payload of the current page, by lifecycle state.

    Under head tensor-parallelism the dense planes hold only this shard's
    ``h`` heads but a PACKED page always decodes all ``h_full`` heads —
    the APack streams interleave heads, so the compressed payload cannot
    be split — and the shard's block is sliced out at the traced ``h0``
    offset (0 and ``h == h_full`` on a single device: the slice is the
    identity)."""

    @pl.when(state == PAGE_HOT)
    def _hot():
        tile_ref[...] = (tok_ref[0].astype(F32)
                         * tok_s_ref[0].astype(F32)[..., None])

    @pl.when(state == PAGE_COLD)
    def _cold():
        tile_ref[...] = (cold_ref[0].astype(F32)
                         * pscale_ref[0].astype(F32)[None, :, None])

    @pl.when(state == PAGE_PACKED)
    def _packed():
        u = decode_block(sym_ref[0].astype(U32), ofs_ref[0].astype(U32),
                         stored_ref[0] != 0, vm_ref[0], ol_ref[0],
                         cum_ref[0], n_steps=n_steps, bits=bits)
        signed = jnp.where(u >= 128, u - 256, u).astype(F32)
        local = jax.lax.dynamic_slice_in_dim(
            signed.reshape(ps, h_full, dh), h0, h, axis=1)
        tile_ref[...] = local * pscale_ref[0].astype(F32)[None, :, None]


def _fused_kernel(idx_ref, tid_ref, q_ref, jm_ref, meta_ref,
                  tok_k_ref, tok_sk_ref, tok_v_ref, tok_sv_ref,
                  cold_k_ref, cold_v_ref, pscale_k_ref, pscale_v_ref,
                  sym_k_ref, ofs_k_ref, st_k_ref,
                  sym_v_ref, ofs_v_ref, st_v_ref,
                  vm_k_ref, ol_k_ref, cum_k_ref,
                  vm_v_ref, ol_v_ref, cum_v_ref,
                  acc_ref, m_ref, l_ref,
                  kt_ref, vt_ref, acc_s, m_s, l_s, *,
                  ps: int, hkv: int, g: int, dh: int, h_full: int,
                  n_steps: int, bits: int, softcap: float):
    del idx_ref, tid_ref                 # consumed by BlockSpec index_maps
    p = pl.program_id(1)
    n_pages = pl.num_programs(1)

    @pl.when(p == 0)
    def _init():
        acc_s[...] = jnp.zeros((hkv, g, dh), F32)
        m_s[...] = jnp.full((hkv, g), NEG_INF, F32)
        l_s[...] = jnp.zeros((hkv, g), F32)

    state = meta_ref[0, 0, 0]
    t0 = meta_ref[0, 0, 1]
    qpos = jm_ref[0, 0]
    window = jm_ref[0, 1]
    h0 = jm_ref[0, 2]

    _page_tile(state, h0, tok_k_ref, tok_sk_ref, cold_k_ref, pscale_k_ref,
               sym_k_ref, ofs_k_ref, st_k_ref, vm_k_ref, ol_k_ref,
               cum_k_ref, kt_ref, ps=ps, h=hkv, dh=dh, h_full=h_full,
               n_steps=n_steps, bits=bits)
    _page_tile(state, h0, tok_v_ref, tok_sv_ref, cold_v_ref, pscale_v_ref,
               sym_v_ref, ofs_v_ref, st_v_ref, vm_v_ref, ol_v_ref,
               cum_v_ref, vt_ref, ps=ps, h=hkv, dh=dh, h_full=h_full,
               n_steps=n_steps, bits=bits)

    q = q_ref[0].reshape(hkv, g, dh).astype(F32)
    k_tile = kt_ref[...]                                     # [ps, H, dh]
    v_tile = vt_ref[...]
    scores = jnp.einsum("kgd,skd->kgs", q, k_tile) * (dh ** -0.5)
    pos = t0 + jnp.arange(ps, dtype=I32)
    valid = (pos < qpos) & (state != PAGE_FREE)
    valid &= jnp.where(window > 0, pos > qpos - window, True)
    scores = jnp.where(valid[None, None, :], scores, NEG_INF)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    m_new = jnp.maximum(m_s[...], jnp.max(scores, axis=-1))
    # explicit * valid: with a fully-masked page m stays at NEG_INF and
    # exp(NEG_INF - NEG_INF) == 1 would otherwise pollute l
    w = jnp.exp(scores - m_new[..., None]) * valid[None, None, :]
    alpha = jnp.exp(m_s[...] - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(w, axis=-1)
    acc_s[...] = (acc_s[...] * alpha[..., None]
                  + jnp.einsum("kgs,skd->kgd", w, v_tile))
    m_s[...] = m_new

    @pl.when(p == n_pages - 1)
    def _flush():
        acc_ref[0] = acc_s[...].reshape(hkv * g, dh)
        m_ref[0] = m_s[...].reshape(hkv * g)
        l_ref[0] = l_s[...].reshape(hkv * g)


# apack: allow-jit-cache(softcap is one value per served model config --
# bounded by the config set, unlike per-request shapes)
@functools.partial(
    jax.jit, static_argnames=("n_steps", "num_heads", "h_full", "bits",
                              "softcap", "interpret"))
def fused_page_attention_pallas(
        q: jax.Array, page_idx: jax.Array, table_idx: jax.Array,
        meta: jax.Array, jobmeta: jax.Array,
        tok_k, tok_sk, tok_v, tok_sv, cold_k, cold_v, pscale_k, pscale_v,
        sym_k, ofs_k, stored_k, sym_v, ofs_v, stored_v, vm, ol, cum, *,
        n_steps: int, num_heads: int, h_full: int | None = None,
        bits: int = 8, softcap: float = 0.0, interpret: bool = True):
    """Fused paged attention over a job batch.

    Args:
      q:         f32[J, Hq, dh] per-job queries (rope'd, unscaled).
      page_idx:  i32[J, P] pool page id per (job, page slot); padding slots
                 may carry any in-range id — they are masked by state.
      table_idx: i32[J, P] K-table row in the stacked table arrays
                 (``2 * layer``); the V row is ``table_idx + 1``.
      meta:      i32[J, P, 2] per-(job, page): (lifecycle state, absolute
                 position of the page's first token).
      jobmeta:   i32[J, 3] per job: (qpos, window, h0) — ``window == 0``
                 means global (no lower bound); ``h0`` is the first kv
                 head of this shard's dense-plane block (0 off-mesh).  A
                 legacy [J, 2] jobmeta is padded with h0 = 0.
      tok_* / cold_* / pscale_* / sym_* / ofs_* / stored_*: per-kind pool
                 planes ([P_pool, ...], kind split by the caller; under
                 head-TP the dense planes carry only the shard's heads
                 while sym/ofs/stored stay full — see ``h_full``).
      vm/ol/cum: stacked table arrays [T, 17] / [T, 16] / [T, 17].
      h_full:    total kv heads a PACKED page decodes to (defaults to the
                 dense planes' head count; differs only under head-TP).

    Returns (acc f32[J, Hq, dh], m f32[J, Hq], l f32[J, Hq]) — the
    *unnormalized* online-softmax state; callers merge the current token
    and divide (see ``modules.paged_attention_step``).
    """
    j, hq, dh = q.shape
    p_slots = page_idx.shape[1]
    ps = tok_k.shape[1]
    hkv = tok_k.shape[2]
    g = hq // hkv
    if h_full is None:
        h_full = hkv
    if jobmeta.shape[1] == 2:
        jobmeta = jnp.concatenate(
            [jobmeta, jnp.zeros((j, 1), jobmeta.dtype)], axis=1)
    ws, s = sym_k.shape[1], sym_k.shape[2]
    wo = ofs_k.shape[1]
    idx_flat = page_idx.reshape(-1).astype(I32)
    tid_flat = table_idx.reshape(-1).astype(I32)
    kernel = functools.partial(
        _fused_kernel, ps=ps, hkv=hkv, g=g, dh=dh, h_full=h_full,
        n_steps=n_steps, bits=bits, softcap=float(softcap))

    def page_spec(shape):
        return pl.BlockSpec((1, *shape),
                            lambda i, p, idx, tid:
                            (idx[i * p_slots + p],) + (0,) * len(shape))

    def ktab_spec(n):
        return pl.BlockSpec((1, n),
                            lambda i, p, idx, tid: (tid[i * p_slots + p], 0))

    def vtab_spec(n):
        return pl.BlockSpec(
            (1, n), lambda i, p, idx, tid: (tid[i * p_slots + p] + 1, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(j, p_slots),
        in_specs=[
            pl.BlockSpec((1, hq, dh), lambda i, p, idx, tid: (i, 0, 0)),
            pl.BlockSpec((1, 3), lambda i, p, idx, tid: (i, 0)),
            pl.BlockSpec((1, 1, 2), lambda i, p, idx, tid: (i, p, 0)),
            page_spec((ps, hkv, dh)),          # tok_k
            page_spec((ps, hkv)),              # tok_sk
            page_spec((ps, hkv, dh)),          # tok_v
            page_spec((ps, hkv)),              # tok_sv
            page_spec((ps, hkv, dh)),          # cold_k
            page_spec((ps, hkv, dh)),          # cold_v
            page_spec((hkv,)),                 # pscale_k
            page_spec((hkv,)),                 # pscale_v
            page_spec((ws, s)),                # sym_k
            page_spec((wo, s)),                # ofs_k
            page_spec((s,)),                   # stored_k
            page_spec((ws, s)),                # sym_v
            page_spec((wo, s)),                # ofs_v
            page_spec((s,)),                   # stored_v
            ktab_spec(17), ktab_spec(16), ktab_spec(17),
            vtab_spec(17), vtab_spec(16), vtab_spec(17),
        ],
        out_specs=[
            pl.BlockSpec((1, hq, dh), lambda i, p, idx, tid: (i, 0, 0)),
            pl.BlockSpec((1, hq), lambda i, p, idx, tid: (i, 0)),
            pl.BlockSpec((1, hq), lambda i, p, idx, tid: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((ps, hkv, dh), F32),    # k tile
            pltpu.VMEM((ps, hkv, dh), F32),    # v tile
            pltpu.VMEM((hkv, g, dh), F32),     # acc
            pltpu.VMEM((hkv, g), F32),         # m
            pltpu.VMEM((hkv, g), F32),         # l
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((j, hq, dh), F32),
            jax.ShapeDtypeStruct((j, hq), F32),
            jax.ShapeDtypeStruct((j, hq), F32),
        ],
        interpret=interpret,
    )(idx_flat, tid_flat, q.astype(F32), jobmeta.astype(I32),
      meta.astype(I32), tok_k, tok_sk.astype(F32), tok_v,
      tok_sv.astype(F32), cold_k, cold_v, pscale_k.astype(F32),
      pscale_v.astype(F32), sym_k.astype(U32), ofs_k.astype(U32),
      stored_k.astype(I32), sym_v.astype(U32), ofs_v.astype(U32),
      stored_v.astype(I32), vm.astype(I32), ol.astype(I32), cum.astype(I32),
      vm.astype(I32), ol.astype(I32), cum.astype(I32))


# apack: allow-jit-cache(softcap is one value per served model config --
# bounded by the config set, unlike per-request shapes)
@functools.partial(
    jax.jit, static_argnames=("n_steps", "num_heads", "h_full", "bits",
                              "softcap"))
def fused_page_attention_ref(
        q, page_idx, table_idx, meta, jobmeta,
        tok_k, tok_sk, tok_v, tok_sv, cold_k, cold_v, pscale_k, pscale_v,
        sym_k, ofs_k, stored_k, sym_v, ofs_v, stored_v, vm, ol, cum, *,
        n_steps: int, num_heads: int, h_full: int | None = None,
        bits: int = 8, softcap: float = 0.0):
    """jnp reference for the fused kernel: identical page-by-page
    online-softmax update order (bit-comparable in interpret mode)."""
    j, hq, dh = q.shape
    p_slots = page_idx.shape[1]
    ps, hkv = tok_k.shape[1], tok_k.shape[2]
    g = hq // hkv
    if h_full is None:
        h_full = hkv
    if jobmeta.shape[1] == 2:
        jobmeta = jnp.concatenate(
            [jobmeta, jnp.zeros((j, 1), jobmeta.dtype)], axis=1)

    def dequant_page(pid, tid, state, h0):
        hot = tok_k[pid].astype(F32), tok_v[pid].astype(F32)
        hot = (hot[0] * tok_sk[pid].astype(F32)[..., None],
               hot[1] * tok_sv[pid].astype(F32)[..., None])
        cold = (cold_k[pid].astype(F32)
                * pscale_k[pid].astype(F32)[None, :, None],
                cold_v[pid].astype(F32)
                * pscale_v[pid].astype(F32)[None, :, None])

        def dec(sym, ofs, stored, t):
            u = _ref.decode(sym[pid].astype(U32), ofs[pid].astype(U32),
                            stored[pid].astype(bool),
                            _ref.TableArrays(vm[t], ol[t], cum[t]),
                            n_steps, bits)
            sgn = jnp.where(u >= 128, u - 256, u).astype(F32)
            # full-head decode, local-head slice — see _page_tile
            return jax.lax.dynamic_slice_in_dim(
                sgn.reshape(ps, h_full, dh), h0, hkv, axis=1)

        packed = (dec(sym_k, ofs_k, stored_k, tid)
                  * pscale_k[pid].astype(F32)[None, :, None],
                  dec(sym_v, ofs_v, stored_v, tid + 1)
                  * pscale_v[pid].astype(F32)[None, :, None])
        kt = jnp.where(state == PAGE_HOT, hot[0],
                       jnp.where(state == PAGE_COLD, cold[0], packed[0]))
        vt = jnp.where(state == PAGE_HOT, hot[1],
                       jnp.where(state == PAGE_COLD, cold[1], packed[1]))
        return kt, vt

    def one_job(qj, pids, tids, mj, jm):
        q3 = qj.reshape(hkv, g, dh).astype(F32)
        acc = jnp.zeros((hkv, g, dh), F32)
        m_run = jnp.full((hkv, g), NEG_INF, F32)
        l_run = jnp.zeros((hkv, g), F32)
        for p in range(p_slots):
            state, t0 = mj[p, 0], mj[p, 1]
            kt, vt = dequant_page(pids[p], tids[p], state, jm[2])
            scores = jnp.einsum("kgd,skd->kgs", q3, kt) * (dh ** -0.5)
            pos = t0 + jnp.arange(ps, dtype=I32)
            valid = (pos < jm[0]) & (state != PAGE_FREE)
            valid &= jnp.where(jm[1] > 0, pos > jm[0] - jm[1], True)
            scores = jnp.where(valid[None, None, :], scores, NEG_INF)
            if softcap > 0:
                scores = softcap * jnp.tanh(scores / softcap)
            m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))
            w = jnp.exp(scores - m_new[..., None]) * valid[None, None, :]
            alpha = jnp.exp(m_run - m_new)
            l_run = l_run * alpha + jnp.sum(w, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("kgs,skd->kgd", w, vt)
            m_run = m_new
        return (acc.reshape(hq, dh), m_run.reshape(hq), l_run.reshape(hq))

    return jax.vmap(one_job)(q.astype(F32), page_idx.astype(I32),
                             table_idx.astype(I32), meta.astype(I32),
                             jobmeta.astype(I32))


def fused_page_attention(q, page_idx, table_idx, meta, jobmeta, planes, *,
                         n_steps: int, num_heads: int,
                         h_full: int | None = None, bits: int = 8,
                         softcap: float = 0.0, backend: str | None = None):
    """Backend dispatch (mirrors ``paged_decode.gather_decode``): pallas on
    TPU, pallas-interpret on CPU, ``backend="ref"`` for the pure-jnp path.
    ``planes`` is the device plane dict built by
    ``model.DevicePoolPlanes`` (kind-split pool arrays + table stacks)."""
    if backend is None:
        from .ops import _default_backend
        backend = _default_backend()
    args = (q, page_idx, table_idx, meta, jobmeta,
            planes["tok_k"], planes["tok_sk"], planes["tok_v"],
            planes["tok_sv"], planes["cold_k"], planes["cold_v"],
            planes["pscale_k"], planes["pscale_v"],
            planes["sym_k"], planes["ofs_k"], planes["stored_k"],
            planes["sym_v"], planes["ofs_v"], planes["stored_v"],
            planes["vm"], planes["ol"], planes["cum"])
    if backend == "ref":
        return fused_page_attention_ref(
            *args, n_steps=n_steps, num_heads=num_heads, h_full=h_full,
            bits=bits, softcap=softcap)
    return fused_page_attention_pallas(
        *args, n_steps=n_steps, num_heads=num_heads, h_full=h_full,
        bits=bits, softcap=softcap,
        interpret=(backend == "pallas_interpret"))

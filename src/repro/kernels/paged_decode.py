"""Pallas paged gather-decode kernel for the APack-compressed KV cache.

The serving engine stores cold KV pages as fixed-capacity APack planes
stacked in a block pool (``models/modules.py::KVPagePool``): page ``p``'s
symbol plane lives at ``sym[p]`` (u32[Ws, S]), its offset plane at
``ofs[p]``.  On every attention read the engine needs an arbitrary *subset*
of pages — the per-request page tables of the active batch — decoded into
dense int8 K/V.

This kernel is that read path: a scalar-prefetched page-index vector drives
the BlockSpec index_map, so grid program ``g`` DMAs exactly page
``page_idx[g]``'s compressed words HBM->VMEM and decodes it with the shared
``decode_block`` body (one stream per lane, ``fori_loop`` over symbols).
A *second* scalar-prefetch vector carries a per-page table id into the
table-array BlockSpecs: pages encoded with different tables batch into ONE
kernel launch — the engine issues two calls per step (one per K/V kind)
instead of two per layer.  Off-chip traffic is the *compressed* footprint —
the paper's Figure-1 saving applied to KV-cache decode reads instead of
weight reads.

The table id is a flat ``(generation, layer, kind)`` address (``table_row``
below) into the stacked table pool: activation tables are *refreshed* on
drifting serving traffic (``model.PagedKVCache.maybe_refresh``), each
refresh appending a new generation of ``2 * n_layers`` rows, and every
PACKED page carries the generation it was coded under — so pages from
before and after a refresh coexist in one gather/attention call and decode
bit-exactly with *their own* table while the background re-pack migrates
them generation by generation.

Interpret mode is bit-exact with ``fastpath.decompress_np`` per page
(tests/test_paged_kv.py); on TPU the same kernel compiles with the pages
resident in HBM.
"""
from __future__ import annotations

import functools
import logging

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as _ref
from .apack_decode import decode_block

I32 = jnp.int32
U32 = jnp.uint32
_log = logging.getLogger(__name__)

# jit-compile buckets for the gather size: pad the page-index vector up to
# the next bucket so a serving loop with a growing working set compiles
# O(log pages) kernels, not one per distinct page count.  Beyond the fixed
# table the bucket keeps doubling (next power of two) — the compiled-size
# set stays O(log pages) for arbitrarily large pools instead of one kernel
# per 1024-page increment.
GATHER_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)

# recompile-storm guard: a long-running serve should settle into a handful
# of gather sizes; warn (once per new size past the threshold) if the set
# of distinct buckets keeps growing — each one is a fresh XLA compile.
# Deliberately process-global (not per pool/engine): the jit cache whose
# growth this tracks is process-global too.
GATHER_BUCKET_WARN_THRESHOLD = 12
_seen_buckets: set[int] = set()


def table_row(gen: int, layer: int, kind: int, n_layers: int) -> int:
    """Flat row of table ``(generation, layer, kind)`` in the stacked
    ``[(G+1) * 2 * n_layers, ...]`` table pool.

    ``kind`` (0 = K, 1 = V) is the fastest-varying axis — a hard contract:
    ``kernels/fused_page_attention.py`` receives only the K row per page
    and addresses the V table as ``row + 1``.  Generation is the slowest
    axis so a refresh appends rows without renumbering existing pages'
    table ids (old PACKED pages stay decodable mid-refresh)."""
    return (gen * n_layers + layer) * 2 + kind


def gather_bucket(n: int) -> int:
    for b in GATHER_BUCKETS:
        if n <= b:
            bucket = b
            break
    else:
        bucket = GATHER_BUCKETS[-1]
        while bucket < n:
            bucket *= 2
    if bucket not in _seen_buckets:
        _seen_buckets.add(bucket)
        if len(_seen_buckets) > GATHER_BUCKET_WARN_THRESHOLD:
            _log.warning(
                "gather_decode has now been asked for %d distinct jit "
                "bucket sizes (latest: %d) — each is a fresh kernel "
                "compile; a long-running serve hitting this repeatedly "
                "indicates a recompile storm (consider a larger fixed "
                "bucket or pre-warming)", len(_seen_buckets), bucket)
    return bucket


# Per-job page-count buckets for the fused attention grid: the engine sizes
# the kernel's pages axis to the next bucket above the *occupied* page count
# of the busiest active slot instead of the static per-slot maximum, so a
# batch of mostly-short requests stops paying for the max-pages grid.
# Powers of two keep the distinct compiled grid set O(log pages); masked
# (FREE/out-of-range) pages leave the online-softmax accumulator bit-exactly
# unchanged, so any bucket >= the true count decodes identically.
PAGE_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
PAGE_BUCKET_WARN_THRESHOLD = 12
_seen_page_buckets: set[int] = set()


def page_bucket(n: int) -> int:
    n = max(int(n), 1)
    for b in PAGE_BUCKETS:
        if n <= b:
            bucket = b
            break
    else:
        bucket = PAGE_BUCKETS[-1]
        while bucket < n:
            bucket *= 2
    if bucket not in _seen_page_buckets:
        _seen_page_buckets.add(bucket)
        if len(_seen_page_buckets) > PAGE_BUCKET_WARN_THRESHOLD:
            _log.warning(
                "fused_page_attention has now been asked for %d distinct "
                "page-grid bucket sizes (latest: %d) — each is a fresh "
                "kernel compile; a long-running serve hitting this "
                "repeatedly indicates a recompile storm (consider a larger "
                "fixed bucket or pre-warming)",
                len(_seen_page_buckets), bucket)
    return bucket


def _as_table_stack(v_min, ol, cum, page_idx, table_idx):
    """Canonicalize table arrays to stacked [T, ...] form + per-page ids.

    1-D tables (the single-table call signature) become a one-row stack
    with every page pointing at row 0."""
    v_min = jnp.asarray(v_min)
    if v_min.ndim == 1:
        v_min, ol, cum = (v_min[None], jnp.asarray(ol)[None],
                          jnp.asarray(cum)[None])
    if table_idx is None:
        table_idx = jnp.zeros(page_idx.shape, I32)
    return v_min, jnp.asarray(ol), jnp.asarray(cum), table_idx


def _gather_decode_kernel(idx_ref, tid_ref, sym_ref, ofs_ref, stored_ref,
                          vmin_ref, ol_ref, cum_ref, out_ref, *, n_steps: int,
                          bits: int):
    del idx_ref, tid_ref            # consumed by the BlockSpec index_maps
    out_ref[0] = decode_block(
        sym_ref[0].astype(U32), ofs_ref[0].astype(U32), stored_ref[0] != 0,
        vmin_ref[0], ol_ref[0], cum_ref[0],
        n_steps=n_steps, bits=bits)


@functools.partial(jax.jit,
                   static_argnames=("n_steps", "bits", "interpret"))
def gather_decode_pallas(sym: jax.Array, ofs: jax.Array, stored: jax.Array,
                         page_idx: jax.Array, v_min: jax.Array,
                         ol: jax.Array, cum: jax.Array, *, n_steps: int,
                         bits: int = 8, interpret: bool = True,
                         table_idx: jax.Array | None = None) -> jax.Array:
    """Decode pages ``page_idx`` out of a pooled compressed-plane stack.

    Args:
      sym:      u32[P, Ws, S] pooled symbol planes (word-interleaved).
      ofs:      u32[P, Wo, S] pooled offset planes.
      stored:   bool/i32[P, S] per-stream verbatim-mode flags.
      page_idx: i32[G] page ids to decode (duplicates allowed — callers pad
                to a jit bucket by repeating a valid id).
      v_min/ol/cum: table arrays — either a single table ([17]/[16]/[17])
                or a stack ([T, 17]/[T, 16]/[T, 17]) indexed per page by
                ``table_idx``.
      table_idx: i32[G] table-stack row for each gathered page (None with
                1-D tables: every page uses the single table).
      n_steps:  values per stream (E).

    Returns: i32[G, S, n_steps] decoded unsigned values, gather order.
    """
    p, ws, s = sym.shape
    wo = ofs.shape[1]
    g = page_idx.shape[0]
    v_min, ol, cum, table_idx = _as_table_stack(v_min, ol, cum, page_idx,
                                                table_idx)
    kernel = functools.partial(_gather_decode_kernel, n_steps=n_steps,
                               bits=bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, ws, s), lambda i, idx, tid: (idx[i], 0, 0)),
            pl.BlockSpec((1, wo, s), lambda i, idx, tid: (idx[i], 0, 0)),
            pl.BlockSpec((1, s), lambda i, idx, tid: (idx[i], 0)),
            pl.BlockSpec((1, 17), lambda i, idx, tid: (tid[i], 0)),
            pl.BlockSpec((1, 16), lambda i, idx, tid: (tid[i], 0)),
            pl.BlockSpec((1, 17), lambda i, idx, tid: (tid[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, s, n_steps), lambda i, idx, tid: (i, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, s, n_steps), I32),
        interpret=interpret,
    )(page_idx.astype(I32), table_idx.astype(I32), sym.astype(U32),
      ofs.astype(U32), stored.astype(I32), v_min.astype(I32), ol.astype(I32),
      cum.astype(I32))


@functools.partial(jax.jit, static_argnames=("n_steps", "bits"))
def gather_decode_ref(sym: jax.Array, ofs: jax.Array, stored: jax.Array,
                      page_idx: jax.Array, v_min: jax.Array, ol: jax.Array,
                      cum: jax.Array, *, n_steps: int, bits: int = 8,
                      table_idx: jax.Array | None = None) -> jax.Array:
    """jnp reference for ``gather_decode_pallas`` (bit-identical)."""
    v_min, ol, cum, table_idx = _as_table_stack(v_min, ol, cum, page_idx,
                                                table_idx)
    sym_g = jnp.take(sym.astype(U32), page_idx, axis=0)
    ofs_g = jnp.take(ofs.astype(U32), page_idx, axis=0)
    st_g = jnp.take(stored.astype(bool), page_idx, axis=0)
    vm_g = jnp.take(v_min.astype(I32), table_idx, axis=0)
    ol_g = jnp.take(ol.astype(I32), table_idx, axis=0)
    cum_g = jnp.take(cum.astype(I32), table_idx, axis=0)
    return jax.vmap(
        lambda sp, op, st, vm, olr, cm: _ref.decode(
            sp, op, st, _ref.TableArrays(vm, olr, cm), n_steps, bits)
    )(sym_g, ofs_g, st_g, vm_g, ol_g, cum_g)


def gather_decode(sym, ofs, stored, page_idx, v_min, ol, cum, *,
                  n_steps: int, bits: int = 8, backend: str | None = None,
                  table_idx=None) -> jax.Array:
    """Backend dispatch, shared with ``ops``: pallas on TPU,
    pallas-interpret on CPU, ``backend="ref"`` for the pure-jnp path."""
    if backend is None:
        from .ops import _default_backend
        backend = _default_backend()
    if backend == "ref":
        return gather_decode_ref(sym, ofs, stored, page_idx, v_min, ol, cum,
                                 n_steps=n_steps, bits=bits,
                                 table_idx=table_idx)
    return gather_decode_pallas(sym, ofs, stored, page_idx, v_min, ol, cum,
                                n_steps=n_steps, bits=bits,
                                interpret=(backend == "pallas_interpret"),
                                table_idx=table_idx)

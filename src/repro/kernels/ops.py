"""Public jit'd wrappers for the APack kernels.

``apack_encode`` / ``apack_decode`` operate on ``CompressedArrays`` — the
jnp-native view of ``core.format.CompressedTensor`` — and dispatch to the
Pallas kernels (interpret mode on CPU, compiled on TPU) or to the jnp
reference (``backend="ref"``).  All paths are bit-identical; tests assert it.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import format as fmt
from repro.core.tables import ApackTable
from . import ref as _ref
from .apack_decode import BLOCK_STREAMS, decode_pallas
from .apack_encode import encode_pallas

I32 = jnp.int32
U32 = jnp.uint32


def _default_backend() -> str:
    return "pallas_interpret" if jax.default_backend() == "cpu" else "pallas"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedArrays:
    """jnp container for one APack-compressed tensor."""

    sym_plane: jax.Array     # u32[Ws, S]
    ofs_plane: jax.Array     # u32[Wo, S]
    sym_bits: jax.Array      # i32[S]
    ofs_bits: jax.Array      # i32[S]
    stored: jax.Array        # bool[S]
    v_min: jax.Array         # i32[17]
    ol: jax.Array            # i32[16]
    cum: jax.Array           # i32[17]
    shape: tuple[int, ...]   # static
    bits: int                # static
    elems_per_stream: int    # static
    n_valid: int             # static

    def tree_flatten(self):
        leaves = (self.sym_plane, self.ofs_plane, self.sym_bits,
                  self.ofs_bits, self.stored, self.v_min, self.ol, self.cum)
        aux = (self.shape, self.bits, self.elems_per_stream, self.n_valid)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def payload_bits(self) -> int:
        return int(jnp.sum(self.sym_bits) + jnp.sum(self.ofs_bits))

    @classmethod
    def from_compressed_tensor(cls, ct: fmt.CompressedTensor) -> "CompressedArrays":
        v_min, ol, cum = ct.table.as_arrays()
        return cls(sym_plane=jnp.asarray(ct.sym_plane.astype(np.uint32)),
                   ofs_plane=jnp.asarray(ct.ofs_plane.astype(np.uint32)),
                   sym_bits=jnp.asarray(ct.sym_bits), ofs_bits=jnp.asarray(ct.ofs_bits),
                   stored=jnp.asarray(ct.stored), v_min=jnp.asarray(v_min),
                   ol=jnp.asarray(ol), cum=jnp.asarray(cum), shape=tuple(ct.shape),
                   bits=ct.bits, elems_per_stream=ct.elems_per_stream,
                   n_valid=ct.n_valid)


def _pad_streams(x: jax.Array, s_padded: int, axis: int) -> jax.Array:
    pad = s_padded - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def apack_encode(values: Any, table: ApackTable,
                 elems_per_stream: int = fmt.DEFAULT_ELEMS_PER_STREAM,
                 backend: str | None = None) -> CompressedArrays:
    """Compress an unsigned-value tensor with a given table."""
    backend = backend or _default_backend()
    arr = jnp.asarray(values)
    shape = tuple(arr.shape)
    flat = arr.reshape(-1).astype(I32)
    n = flat.shape[0]
    e = elems_per_stream
    s = max(1, -(-n // e))
    ta = _ref.TableArrays.from_table(table)
    pad_val = int(table.v_min[int(np.argmax(np.diff(np.asarray(table.cum))))])
    flat = jnp.pad(flat, (0, s * e - n), constant_values=pad_val)
    streams = flat.reshape(s, e)
    if backend == "ref":
        sp, op, sb, ob, ovf = _ref.encode_ac(streams, ta, e, table.bits)
    else:
        s_pad = -(-s // BLOCK_STREAMS) * BLOCK_STREAMS
        streams_p = _pad_streams(streams, s_pad, 0)
        sp, op, sb, ob, ovf = encode_pallas(
            streams_p, ta.v_min, ta.ol, ta.cum, n_steps=e, bits=table.bits,
            interpret=(backend == "pallas_interpret"))
        sp, op = sp[:, :s], op[:, :s]
        sb, ob, ovf = sb[:s], ob[:s], ovf[:s].astype(bool)
    # stored-mode selection (shared logic)
    raw = _ref.pack_raw(streams, e, table.bits)
    stored = jnp.asarray(ovf).astype(bool) | ((sb + ob) >= e * table.bits)
    wo = max(op.shape[0], raw.shape[0])

    def pad_to(p, w):
        return jnp.pad(p, ((0, w - p.shape[0]), (0, 0)))

    op = jnp.where(stored[None, :], pad_to(raw, wo), pad_to(op, wo))
    sp = jnp.where(stored[None, :], U32(0), sp)
    sb = jnp.where(stored, 0, sb)
    ob = jnp.where(stored, e * table.bits, ob)
    return CompressedArrays(sym_plane=sp, ofs_plane=op, sym_bits=sb,
                            ofs_bits=ob, stored=stored, v_min=ta.v_min,
                            ol=ta.ol, cum=ta.cum, shape=shape,
                            bits=table.bits, elems_per_stream=e, n_valid=n)


def apack_decode(ca: CompressedArrays, backend: str | None = None,
                 dtype=None) -> jax.Array:
    """Decompress back to the original unsigned-value tensor."""
    backend = backend or _default_backend()
    e = ca.elems_per_stream
    s = ca.sym_bits.shape[0]
    table = _ref.TableArrays(ca.v_min, ca.ol, ca.cum)
    sym = ca.sym_plane if ca.sym_plane.shape[0] > 0 else jnp.zeros((1, s), U32)
    ofs = ca.ofs_plane if ca.ofs_plane.shape[0] > 0 else jnp.zeros((1, s), U32)
    if backend == "ref":
        vals = _ref.decode(sym, ofs, ca.stored, table, e, ca.bits)
    else:
        s_pad = -(-s // BLOCK_STREAMS) * BLOCK_STREAMS
        vals = decode_pallas(
            _pad_streams(sym, s_pad, 1), _pad_streams(ofs, s_pad, 1),
            # padding streams decode as stored zeros (discarded)
            _pad_streams(ca.stored.astype(I32), s_pad, 0),
            ca.v_min, ca.ol, ca.cum, n_steps=e, bits=ca.bits,
            interpret=(backend == "pallas_interpret"))
        vals = vals[:s]
    flat = vals.reshape(-1)[:ca.n_valid]
    if dtype is None:
        dtype = jnp.uint8 if ca.bits <= 8 else jnp.uint16
    return flat.astype(dtype).reshape(ca.shape)


def apack_roundtrip_check(values, table: ApackTable, **kw) -> bool:
    ca = apack_encode(values, table, **kw)
    out = apack_decode(ca)
    return bool(jnp.all(out.astype(I32) == jnp.asarray(values).astype(I32)))

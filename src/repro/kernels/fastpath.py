"""numpy-in/numpy-out fast codec path (vectorized jnp ref under the hood).

Produces/consumes ``core.format.CompressedTensor`` bit-identically to the
golden compressor — used by checkpoint compression and benchmarks where the
pure-Python golden codec would be too slow.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import format as fmt
from repro.core.tables import ApackTable
from . import ref as _ref


def compress_np(values: np.ndarray, table: ApackTable | None = None,
                bits: int = 8, is_activation: bool = False,
                elems_per_stream: int = fmt.DEFAULT_ELEMS_PER_STREAM
                ) -> fmt.CompressedTensor:
    arr = np.asarray(values)
    flat = arr.reshape(-1).astype(np.int64)
    if table is None:
        table = fmt.table_for(flat, bits, is_activation)
    streams, n_valid = fmt.split_streams(flat, elems_per_stream)
    pad = fmt._pad_value(table)
    if n_valid < streams.size:
        streams.reshape(-1)[n_valid:] = pad
    ta = _ref.TableArrays.from_table(table)
    e = streams.shape[1]
    sp, op, sb, ob, stored = _ref.encode(jnp.asarray(streams), ta, e, bits)
    sb = np.asarray(sb, np.int32)
    ob = np.asarray(ob, np.int32)
    stored = np.asarray(stored, bool)
    # trim planes to the golden container's width (max actual words)
    ws = int(np.max(np.where(stored, 0, (sb + 31) // 32), initial=0))
    wo = int(np.max((ob + 31) // 32, initial=0))
    return fmt.CompressedTensor(
        shape=tuple(arr.shape), bits=bits, table=table,
        elems_per_stream=elems_per_stream, n_valid=n_valid,
        sym_plane=np.asarray(sp)[:ws].astype(np.uint32),
        ofs_plane=np.asarray(op)[:wo].astype(np.uint32),
        sym_bits=sb, ofs_bits=ob, stored=stored)


def decompress_np(ct: fmt.CompressedTensor) -> np.ndarray:
    ta = _ref.TableArrays.from_table(ct.table)
    s = ct.n_streams
    sym = ct.sym_plane if ct.sym_plane.shape[0] else np.zeros((1, s), np.uint32)
    ofs = ct.ofs_plane if ct.ofs_plane.shape[0] else np.zeros((1, s), np.uint32)
    vals = _ref.decode(jnp.asarray(sym.astype(np.uint32)),
                       jnp.asarray(ofs.astype(np.uint32)),
                       jnp.asarray(ct.stored), ta, ct.elems_per_stream,
                       ct.bits)
    flat = np.asarray(vals).reshape(-1)[:ct.n_valid]
    dtype = np.uint8 if ct.bits <= 8 else np.uint16
    return flat.astype(dtype).reshape(ct.shape)

"""Compressed data-parallel gradient all-reduce with error feedback.

The paper's thesis — biased value distributions make fixed-point streams
cheap to move — applied to the *training* interconnect: gradients are
int8-quantized (per-block scales) before the DP all-reduce, and the
quantization error is fed back into the next step (EF-SGD), preserving
convergence.  Cuts DP gradient traffic ~4x vs bf16 (int8 payload + one fp32
scale per 512 values).

Implemented with shard_map + explicit psum so the quantized representation
is what actually crosses the links (GSPMD would otherwise all-reduce the
full-precision tensor).  To make the sum exact with per-device scales, a
cheap pmax first unifies each block's scale across the replicas, payloads
are requantized to the shared scale, then a single int32-accumulated psum
reduces them.
"""
from __future__ import annotations

import inspect
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:                                     # newer jax: top-level API
    _shard_map = jax.shard_map
except AttributeError:                   # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
# the replication-check kwarg was renamed check_rep -> check_vma
# independently of the namespace promotion, so key on the signature
_SHMAP_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")

F32 = jnp.float32
BLOCK = 512


def quantize_blockwise(g: jax.Array):
    flat = g.reshape(-1).astype(F32)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1, keepdims=True),
                        1e-20) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0], n


def dequantize_blockwise(q: jax.Array, scale: jax.Array, n: int,
                         shape) -> jax.Array:
    return (q.astype(F32) * scale[:, None]).reshape(-1)[:n].reshape(shape)


def compressed_psum_mean(grads: Any, mesh: Mesh, axes: tuple[str, ...],
                         error: Any | None = None):
    """Mean-all-reduce a gradient pytree across ``axes``, int8 on the wire.

    Args:
      grads: locally computed gradients (each device holds its own shard's
        grad; leaves replicated w.r.t. ``axes`` specs).
      error: error-feedback pytree from the previous step, or None.

    Returns (mean grads, new error-feedback pytree).
    """
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]

    def one(g, e):
        g = g.astype(F32) + (e if e is not None else 0.0)
        q, scale, n = quantize_blockwise(g)
        new_e = g - dequantize_blockwise(q, scale, n, g.shape)

        def inner(qq, ss):
            smax = jax.lax.pmax(ss, axes)
            req = jnp.clip(jnp.round(qq.astype(F32) * (ss / smax)[:, None]),
                           -127, 127).astype(jnp.int8)
            tot = jax.lax.psum(req.astype(jnp.int32), axes)
            return tot, smax

        spec = P()
        tot, smax = _shard_map(inner, mesh=mesh, in_specs=(spec, spec),
                               out_specs=(spec, spec),
                               **{_SHMAP_KW: False})(q, scale)
        mean = dequantize_blockwise(tot, smax, n, g.shape) / n_dev
        return mean, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = (jax.tree.leaves(error) if error is not None
              else [None] * len(flat_g))
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_error_feedback(grads_shape: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, F32), grads_shape)

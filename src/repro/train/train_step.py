"""Train-step factory: loss + grad (+ microbatch accumulation) + AdamW.

The returned step is a single jit-able function of (params, opt_state,
batch) suitable for pjit with the shardings from models/sharding.py; ZeRO
falls out of the param/opt shardings, remat from models.forward, and
compute/comm overlap from XLA's scheduling of the scan's all-gathers.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from . import optimizer as opt

F32 = jnp.float32


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss_fn(params, batch):
        logits, _, aux = M.forward(cfg, params, batch)
        return M.loss_fn(cfg, logits, batch, aux)
    return loss_fn


def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig,
                    grad_accum: int = 1,
                    accum_dtype=jnp.float32) -> Callable:
    """grad_accum > 1 scans over microbatches (slices of the leading batch
    dim) — the production config for the large archs, bounding the remat
    residual stack to one microbatch.  ``accum_dtype=bfloat16`` halves the
    accumulator for trillion-param state budgets (kimi)."""
    loss_fn = make_loss_fn(cfg)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (acc_loss + l,
                        jax.tree.map(lambda a, b: a + b.astype(accum_dtype),
                                     acc_g, g)), ()

            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(micro, (0.0, zero_g), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        params, opt_state, metrics = opt.apply_updates(ocfg, params, grads,
                                                       opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss_fn = make_loss_fn(cfg)

    def step(params, batch):
        return loss_fn(params, batch)

    return step

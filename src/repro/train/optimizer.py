"""AdamW from scratch, with optional block-wise 8-bit first/second moments.

No optax in this container — and the 8-bit state is a deliberate
beyond-paper feature in the spirit of APack: the optimizer moments are a
large off-chip-resident stream; quantizing them (with per-block scales,
Dettmers-style) cuts their footprint 4x, which is what lets the 1T-param
kimi config train on 512 v5e chips (DESIGN.md §4).  ZeRO sharding falls out
of GSPMD: moments inherit the FSDP param shardings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
BLOCK = 32             # elements per quantization block: must divide every
                       # per-device shard of a blocked axis (7168/32-way
                       # FSDP = 224 -> block 256 forced involuntary
                       # resharding; 32 divides all our shards)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"       # float32 | int8


class Q8(NamedTuple):
    """Block-quantized tensor: int8 payload + per-block fp32 absmax scale.

    ``q`` keeps the source tensor's SHAPE (blocks run along the last axis)
    so the moments inherit the parameter's sharding exactly — a flat
    [nblocks, 256] layout forces an arbitrary reshape that GSPMD cannot
    re-shard (measured: involuntary full remat replicating 315 GiB of
    expert-grad tensors on the kimi config)."""
    q: jax.Array
    scale: jax.Array


def _block_of(last: int) -> int:
    return BLOCK if last >= BLOCK and last % BLOCK == 0 else max(last, 1)


def _q8_encode(x: jax.Array) -> Q8:
    xf = x.astype(F32)
    last = xf.shape[-1] if xf.ndim else 1
    blk = _block_of(last)
    blocks = xf.reshape(*xf.shape[:-1], max(last // blk, 1), blk)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    return Q8(q=q.reshape(xf.shape).astype(jnp.int8), scale=scale)


def _q8_decode(s: Q8, shape, n: int) -> jax.Array:
    last = s.q.shape[-1] if s.q.ndim else 1
    blk = _block_of(last)
    blocks = s.q.astype(F32).reshape(*s.q.shape[:-1], max(last // blk, 1), blk)
    return (blocks * s.scale[..., None]).reshape(shape)


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(cfg: AdamWConfig, params: Any) -> dict:
    def zeros_like_state(p):
        if cfg.state_dtype == "int8":
            return _q8_encode(jnp.zeros(p.shape, F32))
        return jnp.zeros(p.shape, F32)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_state, params),
        "v": jax.tree.map(zeros_like_state, params),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(F32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: dict) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(F32)
    b2c = 1 - cfg.b2 ** step.astype(F32)
    q8 = cfg.state_dtype == "int8"

    def upd(p, g, m, v):
        g = g.astype(F32) * clip
        n = p.size
        mf = _q8_decode(m, p.shape, n) if q8 else m
        vf = _q8_decode(v, p.shape, n) if q8 else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decoupled decay, matrices only
            delta = delta + cfg.weight_decay * p.astype(F32)
        new_p = (p.astype(F32) - lr * delta).astype(p.dtype)
        if q8:
            return new_p, _q8_encode(mf), _q8_encode(vf)
        return new_p, mf, vf

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_q8 = lambda x: isinstance(x, Q8)   # noqa: E731
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_q8)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_q8)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics

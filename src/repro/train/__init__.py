from .optimizer import AdamWConfig, init_state, apply_updates, lr_schedule
from .train_step import make_train_step, make_eval_step, make_loss_fn
from . import compress_grads

__all__ = ["AdamWConfig", "init_state", "apply_updates", "lr_schedule",
           "make_train_step", "make_eval_step", "make_loss_fn",
           "compress_grads"]

from .pipeline import DataConfig, SyntheticLM, BinTokenDataset, Prefetcher, write_bin

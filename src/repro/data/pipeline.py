"""Data pipeline: deterministic synthetic LM streams + memmap token-bin
files.  Both are host-shardable (disjoint slices per host), checkpointable
(state dicts), and prefetch via a background thread.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Any, Iterator

import numpy as np


@dataclasses.dataclass
class DataConfig:
    batch_size: int                 # per-host batch
    seq_len: int
    vocab_size: int
    host_index: int = 0
    host_count: int = 1
    seed: int = 0


class SyntheticLM:
    """Deterministic pseudo-text: Zipfian tokens from a counter-based PRNG;
    identical across restarts given the same state (step counter)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0
        # zipf-ish distribution over the vocab (real text is far from
        # uniform — this also makes the loss actually decrease)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.p = p / p.sum()

    def state_dict(self) -> dict:
        return {"step": self.step}

    def load_state_dict(self, s: dict) -> None:
        self.step = int(s["step"])

    def next_batch(self) -> dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, c.host_index, self.step]))
        tok = rng.choice(c.vocab_size, size=(c.batch_size, c.seq_len + 1),
                         p=self.p).astype(np.int32)
        # inject learnable bigram structure: every even position repeats
        tok[:, 1::2] = (tok[:, 0::2][:, :tok[:, 1::2].shape[1]] + 1) % c.vocab_size
        self.step += 1
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


class BinTokenDataset:
    """Flat binary token file (uint16/uint32), memmap'd; hosts read disjoint
    strided windows; sequential within a host for locality.  Exact-resume
    via (epoch, cursor)."""

    def __init__(self, path: str | Path, cfg: DataConfig,
                 dtype: str = "uint16"):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        need = cfg.batch_size * (cfg.seq_len + 1)
        self.per_host = (len(self.tokens) // cfg.host_count) // need * need
        if self.per_host == 0:
            raise ValueError("dataset smaller than one host batch")
        self.base = cfg.host_index * (len(self.tokens) // cfg.host_count)
        self.cursor = 0
        self.epoch = 0

    def state_dict(self) -> dict:
        return {"cursor": self.cursor, "epoch": self.epoch}

    def load_state_dict(self, s: dict) -> None:
        self.cursor = int(s["cursor"])
        self.epoch = int(s["epoch"])

    def next_batch(self) -> dict[str, np.ndarray]:
        c = self.cfg
        need = c.batch_size * (c.seq_len + 1)
        if self.cursor + need > self.per_host:
            self.cursor = 0
            self.epoch += 1
        start = self.base + self.cursor
        flat = np.asarray(self.tokens[start:start + need], dtype=np.int32)
        self.cursor += need
        tok = flat.reshape(c.batch_size, c.seq_len + 1)
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next_batch()


class Prefetcher:
    """Background-thread prefetch with a bounded queue."""

    def __init__(self, src, depth: int = 2):
        self.src = src
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        it = iter(self.src)
        while not self.stop.is_set():
            try:
                self.q.put(next(it), timeout=0.5)
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self.stop.set()


def write_bin(path: str | Path, tokens: np.ndarray,
              dtype: str = "uint16") -> None:
    np.asarray(tokens, dtype=dtype).tofile(path)

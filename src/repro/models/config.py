"""Model configuration for all supported architecture families."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "encoder", "vlm", "moe", "xlstm", "hybrid"]

# Global chunk size for all time-axis loops (attention q-chunks, mLSTM /
# sLSTM chunkwise scans).  Keeping it uniform makes every depth-1 while loop
# in the lowered HLO have trip count S/CHUNK — the roofline accounting
# relies on this convention (see launch/roofline.py).
CHUNK = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    qk_norm: bool = False
    # per-layer block pattern, cycled: "global" | "local" | "recurrent"
    # | "mlstm" | "slstm"
    block_pattern: tuple[str, ...] = ("global",)
    # unscanned leading layers (kimi's dense-FFN first layer, griffin's
    # leading recurrent pair); for MoE families prefix blocks use the dense
    # d_ff MLP instead of the MoE.
    prefix_pattern: tuple[str, ...] = ()
    window_size: int = 4096
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    parallel_block: bool = False          # command-r style attn ∥ mlp

    # mlp
    mlp_variant: str = "swiglu"           # swiglu | geglu | gelu | relu2

    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0                     # per-expert hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # hybrid (RG-LRU)
    lru_width: int = 0

    # xlstm
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # embeddings / output
    tie_embeddings: bool = True
    frontend: str | None = None           # None | "vision" | "audio"
    causal: bool = True

    # numerics
    param_dtype: str = "float32"          # float32 | bfloat16
    # bfloat16 | int8 (per-token-head scales) | apack-int8 (int8 compute
    # view + paged APack-compressed off-chip storage, serve-layer only)
    kv_cache_dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    @property
    def kv_int8(self) -> bool:
        """int8 KV compute path (both the raw and the APack-paged modes —
        the compressed storage layer is transparent to the block math)."""
        return self.kv_cache_dtype in ("int8", "apack-int8")

    @property
    def cycle(self) -> tuple[str, ...]:
        return self.block_pattern

    @property
    def n_cycles(self) -> int:
        layers = self.num_layers - len(self.prefix_pattern)
        assert layers % len(self.cycle) == 0, (
            f"{self.name}: {layers} scanned layers not divisible by "
            f"pattern {self.cycle}")
        return layers // len(self.cycle)

    def _layer_params(self, kind: str, *, moe: bool) -> int:
        d, dh = self.d_model, self.head_dim
        p = 2 * d                                      # two norms
        if kind in ("global", "local"):
            p += d * self.num_heads * dh + 2 * d * self.num_kv_heads * dh
            p += self.num_heads * dh * d
            if self.qk_norm:
                p += 2 * dh
        elif kind == "recurrent":
            w = self.lru_width or d
            p += 2 * d * w + w * d + 4 * w + 3 * w     # proj + conv + gates
        elif kind == "mlstm":
            f = int(self.mlstm_proj_factor * d)
            h = max(self.num_heads, 1)
            p += 2 * d * f + f * d + 3 * f * (f // h) + 2 * f + f
        elif kind == "slstm":
            h = max(self.num_heads, 1)
            f = int(self.slstm_proj_factor * d)
            p += 4 * d * d + 4 * h * (d // h) ** 2 + 2 * d * f + f * d + d
        if kind in ("global", "local", "recurrent"):
            if moe:
                p += d * self.num_experts              # router
                p += self.num_experts * 3 * d * self.moe_d_ff
                p += self.n_shared_experts * 3 * d * self.moe_d_ff
            elif self.d_ff > 0:
                mult = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
                p += mult * d * self.d_ff
        return p

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        n = self.vocab_size * self.d_model             # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        moe = self.num_experts > 0
        for kind in self.prefix_pattern:               # prefix uses dense ffn
            n += self._layer_params(kind, moe=False)
        for kind in self.cycle:
            n += self._layer_params(kind, moe=moe) * self.n_cycles
        return n + self.d_model                        # final norm

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        moe_layers = self.num_layers - len(self.prefix_pattern)
        inactive = (self.num_experts - self.num_experts_per_tok)
        per_expert = 3 * self.d_model * self.moe_d_ff
        return full - moe_layers * inactive * per_expert


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)

"""Model building blocks — pure JAX, pure functions, params as pytrees.

Every time-axis loop (attention q-chunks, mLSTM/sLSTM chunkwise scans) uses
``config.CHUNK``-sized chunks via ``lax.scan`` so the lowered HLO has a
uniform depth->trip-count structure (see launch/roofline.py).
"""
from __future__ import annotations

import dataclasses
import zlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import sharding as shd
from .config import CHUNK, ModelConfig

F32 = jnp.float32
BF16 = jnp.bfloat16


# ------------------------------------------------------------------ basics
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(F32))).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, dh]; positions: broadcastable to [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))
    ang = positions[..., None].astype(F32) * freqs            # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                          # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _kv_quantize(x: jax.Array):
    """Per-(position, head) absmax int8 quantization of K/V.

    x: [..., H, dh] -> (int8 same shape, f32 scale [..., H])."""
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(F32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def _kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(F32) * scale[..., None].astype(F32)


def _chunk_of(s: int) -> int:
    """Largest chunk <= CHUNK dividing s (smoke tests use tiny sequences)."""
    c = min(CHUNK, s)
    while s % c:
        c -= 1
    return c


# -------------------------------------------------------- packed weights
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """An APack-compressed projection weight living in the param tree.

    Wraps a ``kernels.decompress_matmul.CompressedLinear`` (the 2-D
    [K, N] compressed view) plus the metadata needed to stand in for the
    original dense tensor at its einsum site: the original ``shape``,
    how many *leading* axes contract (``n_contract`` — projection
    weights in this codebase always contract their leading axes: wq
    [d, h, dh] contracts d, wo [h, dh, d] contracts h and dh), and the
    dense ``dtype`` string the activation path expects back.

    Registered as a pytree whose single child is the CompressedLinear,
    so ``jax.lax.scan`` over a stacked block tree slices the plane
    leaves per layer and rebuilds a per-layer ``PackedWeight`` with the
    shared static aux — dense and packed params flow through the same
    model code."""

    cw: object               # CompressedLinear (child pytree)
    shape: tuple             # original dense weight shape
    n_contract: int          # leading axes folded into K
    dtype: str               # original dense dtype

    def tree_flatten(self):
        return ((self.cw,), (self.shape, self.n_contract, self.dtype))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], *aux)


# apack: hot-path-root(traced)
def packed_proj(x: jax.Array, pw: PackedWeight,
                tp: tuple[str, int] | None = None) -> jax.Array:
    """Apply a packed projection: flatten ``x``'s trailing contraction
    axes into K, run the fused decompress-matmul, restore output axes.

    ``tp=(axis_name, size)``: inside a ``shard_map`` body whose packed
    planes were K-split over the mesh axis (stream layout is kt-major,
    so a contiguous stream-axis shard == a contiguous K-tile range),
    each shard multiplies its local K rows and the partial products are
    reassembled with a ``psum`` — row-parallel tensor parallelism.  The
    local view is detected by comparing the plane's stream count to the
    global layout; replicated planes (indivisible nk) take the plain
    path on every shard identically."""
    from repro.kernels import decompress_matmul as dm
    cw = pw.cw
    nc = pw.n_contract
    lead = x.shape[:-nc]
    kdim = 1
    for s in x.shape[-nc:]:
        kdim *= s
    x2 = x.reshape(-1, kdim).astype(F32)
    m = x2.shape[0]
    block_m = max(8, min(256, -(-m // 8) * 8))
    nn = cw.n_pad // dm.TILE_N
    s_global = (cw.k_pad // cw.tile_k) * nn * dm.TILE_N
    s_local = cw.sym_plane.shape[-1]
    if tp is not None and s_local != s_global:
        t = s_global // s_local
        k_loc = cw.k // t
        cw_loc = dataclasses.replace(cw, k=k_loc)
        r0 = jax.lax.axis_index(tp[0]) * k_loc
        x_loc = jax.lax.dynamic_slice_in_dim(x2, r0, k_loc, axis=1)
        y = dm.compressed_matmul(x_loc, cw_loc, block_m=block_m)
        y = jax.lax.psum(y, tp[0])
    else:
        y = dm.compressed_matmul(x2, cw, block_m=block_m)
    return y.reshape(*lead, *pw.shape[nc:]).astype(x.dtype)


def proj(x: jax.Array, w, eq: str,
         tp: tuple[str, int] | None = None) -> jax.Array:
    """Projection dispatch: dense einsum, or the fused APack path when
    the param tree holds a ``PackedWeight`` at this site."""
    if isinstance(w, PackedWeight):
        return packed_proj(x, w, tp=tp)
    return jnp.einsum(eq, x, w.astype(x.dtype))


# --------------------------------------------------------------- attention
def init_attention(cfg: ModelConfig, key) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "wq": (jax.random.normal(k1, (d, h, dh)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, hkv, dh)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, hkv, dh)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (h, dh, d)) * s).astype(dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dt)
        p["k_norm"] = jnp.zeros((dh,), dt)
    return p


def _mask(qpos, kpos, *, causal: bool, window: int) -> jax.Array:
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def attention_full(p: dict, x: jax.Array, cfg: ModelConfig, *,
                   local: bool, true_len=None) -> tuple[jax.Array, dict]:
    """Training/prefill attention, chunked over queries.

    Returns (out [B,S,D], cache {k, v}) — cache is the rolling window for
    local layers, the full sequence otherwise.

    ``true_len`` (traced i32 scalar, bucketed-prefill path): the sequence
    is end-padded to a jit bucket and only the first ``true_len`` positions
    are real.  Causal masking already keeps pad keys out of real queries'
    softmax rows; the only pad-sensitive output is the *local rolling
    cache*, which must hold the last ``window`` REAL positions — so it is
    built with a dynamic slice/roll at ``true_len`` instead of the static
    sequence end (bit-identical to the unpadded construction for both the
    ``s >= window`` and ``s < window`` branches)."""
    b, s, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hkv
    q = shd.constrain(proj(x, p["wq"], "bsd,dhk->bshk"), "heads")
    k = shd.constrain(proj(x, p["wk"], "bsd,dhk->bshk"), "heads")
    v = shd.constrain(proj(x, p["wv"], "bsd,dhk->bshk"), "heads")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos = jnp.arange(s)
    q = rope(q, pos[None, :], cfg.rope_theta)
    k = rope(k, pos[None, :], cfg.rope_theta)
    window = cfg.window_size if local else 0

    chunk = _chunk_of(s)
    n_chunks = s // chunk
    qc = q.reshape(b, n_chunks, chunk, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    starts = jnp.arange(n_chunks) * chunk
    scale = dh ** -0.5

    @jax.checkpoint
    def body(_, xs):
        # rematerialized per-chunk: the scan backward would otherwise stack
        # every chunk's [B,H,C,S] score matrix (= full S^2 memory)
        qch, start = xs                                   # [B,C,Hkv,G,dh]
        scores = jnp.einsum("bckgd,bskd->bkgcs", qch.astype(F32),
                            k.astype(F32)) * scale
        qpos = start + jnp.arange(chunk)
        m = _mask(qpos, pos, causal=cfg.causal, window=window)
        scores = jnp.where(m[None, None, None], scores, -1e30)
        if cfg.logit_softcap > 0:
            cap = cfg.logit_softcap
            scores = cap * jnp.tanh(scores / cap)
        w = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgcs,bskd->bckgd", w, v.astype(F32))
        return (), out.astype(x.dtype)

    _, oc = jax.lax.scan(body, (), (qc, starts))
    out = shd.constrain(
        oc.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dh), "heads")
    y = proj(out, p["wo"], "bshk,hkd->bsd")
    if local:
        w_sz = cfg.window_size
        if true_len is not None:
            # dynamic ring at the *true* end: slot j must hold the latest
            # real position p < true_len with p % w == j.  Left-padding k
            # with w zeros makes kp[t : t+w] == k[t-w : t] with exact
            # zeros where the index would be negative, which reproduces
            # the t < w zero-fill branch below for free.
            t = jnp.asarray(true_len, jnp.int32)

            def ring(arr):
                ap = jnp.pad(arr, ((0, 0), (w_sz, 0), (0, 0), (0, 0)))
                tail = jax.lax.dynamic_slice_in_dim(ap, t, w_sz, axis=1)
                return jnp.roll(tail, t % w_sz, axis=1)

            kcache, vcache = ring(k), ring(v)
        elif s >= w_sz:
            # rolling cache: slot j holds the latest position with pos%w == j
            tail_k = jax.lax.dynamic_slice_in_dim(k, s - w_sz, w_sz, axis=1)
            tail_v = jax.lax.dynamic_slice_in_dim(v, s - w_sz, w_sz, axis=1)
            shift = s % w_sz
            kcache = jnp.roll(tail_k, shift, axis=1)
            vcache = jnp.roll(tail_v, shift, axis=1)
        else:
            kcache = jnp.pad(k, ((0, 0), (0, w_sz - s), (0, 0), (0, 0)))
            vcache = jnp.pad(v, ((0, 0), (0, w_sz - s), (0, 0), (0, 0)))
        cache = {"k": kcache, "v": vcache}
    else:
        cache = {"k": k, "v": v}
    if cfg.kv_int8:
        qk, sk = _kv_quantize(cache["k"])
        qv, sv = _kv_quantize(cache["v"])
        cache = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    return y, cache


def attention_step(p: dict, x: jax.Array, cache: dict, pos: jax.Array,
                   cfg: ModelConfig, *, local: bool) -> tuple[jax.Array, dict]:
    """Single-token decode step.  x: [B, 1, D]; cache k/v: [B, Sc, Hkv, dh].

    ``pos`` may be a scalar or a per-sequence [B] vector (continuous
    batching: each slot advances independently).  Global layers write cache
    slot ``pos``; local layers write the rolling slot ``pos % window``."""
    b = x.shape[0]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hkv
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,))
    q = proj(x, p["wq"], "bsd,dhk->bshk")
    k = proj(x, p["wk"], "bsd,dhk->bshk")
    v = proj(x, p["wv"], "bsd,dhk->bshk")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    posb = pos[:, None]
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    sc = cache["k"].shape[1]
    slot = (pos % sc) if local else pos
    barange = jnp.arange(b)
    int8_kv = "k_scale" in cache
    if int8_kv:
        qk, sk = _kv_quantize(k[:, 0])
        qv, sv = _kv_quantize(v[:, 0])
        cache = {"k": cache["k"].at[barange, slot].set(qk),
                 "v": cache["v"].at[barange, slot].set(qv),
                 "k_scale": cache["k_scale"].at[barange, slot].set(sk),
                 "v_scale": cache["v_scale"].at[barange, slot].set(sv)}
        kc = _kv_dequantize(cache["k"], cache["k_scale"])
        vc = _kv_dequantize(cache["v"], cache["v_scale"])
    else:
        kc = cache["k"].at[barange, slot].set(k[:, 0].astype(cache["k"].dtype))
        vc = cache["v"].at[barange, slot].set(v[:, 0].astype(cache["v"].dtype))
    idx = jnp.arange(sc)[None, :]
    if local:
        # slot j currently holds absolute position p - ((p - j) mod Sc)
        abs_pos = pos[:, None] - jnp.mod(pos[:, None] - idx, sc)
        valid = (abs_pos >= 0) & (abs_pos <= pos[:, None])
    else:
        valid = idx <= pos[:, None]
    scores = jnp.einsum(
        "bkgd,bskd->bkgs",
        q.reshape(b, hkv, g, dh).astype(F32), kc.astype(F32)) * (dh ** -0.5)
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    if cfg.logit_softcap > 0:
        scores = cfg.logit_softcap * jnp.tanh(scores / cfg.logit_softcap)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vc.astype(F32))
    y = proj(out.reshape(b, h, dh).astype(x.dtype), p["wo"],
             "bhk,hkd->bd")[:, None, :]
    if int8_kv:
        return y, cache
    return y, {"k": kc, "v": vc}


# apack: hot-path-root(traced)
def paged_attention_step(p: dict, x: jax.Array, planes: dict, meta: dict,
                         pos: jax.Array, cfg: ModelConfig, *,
                         backend: str | None = None,
                         tp: tuple[str, int] | None = None
                         ) -> tuple[jax.Array, dict]:
    """Single-token decode step against the *paged* APack KV store.

    The device-resident page pool (``planes``, see
    ``model.DevicePoolPlanes``) replaces the dense cache: sealed/compressed
    pages are read by the fused gather-decode + attention kernel
    (``kernels/fused_page_attention.py``) which decodes each PACKED page
    tile into VMEM scratch and accumulates its QK^T / PV contribution with
    an online softmax — no dense cache is ever materialized.  The current
    token's K/V is quantized exactly like the dense int8 path
    (``_kv_quantize``), its self-attention term is merged into the
    kernel's unnormalized ``(acc, m, l)`` state here, and the quantized
    K/V is *returned* so the engine can append it to the pool on-device
    (``model.device_append``) — the decode hot path never touches host
    memory.

    ``meta`` carries the per-slot page tables: ``pid``/``tid``/``state``/
    ``t0`` i32[B, P] and ``qw`` i32[B, 2] (qpos, window — 0 for global
    layers, the ring width for rolling ones, decided by
    ``PagedKVCache.step_meta``); rolling layers mask evicted and
    partially-rolled-out pages in-kernel via the absolute-position
    window, so no ring buffer exists either.

    ``tp=(axis_name, size)`` runs the fused kernel tensor-parallel over
    kv heads inside a ``shard_map`` body: the dense planes arrive with
    only this shard's head block, the PACKED planes stay replicated
    (APack stream interleaving mixes heads, so a compressed page cannot
    be head-split — the kernel decodes the full page and slices its
    local heads at the ``h0`` jobmeta scalar), and the per-head-block
    ``(acc, m, l)`` partials are reassembled with a tiled ``all_gather``
    *before* any cross-head contraction — per-kv-head attention has no
    cross-head reductions, so the gathered state is bit-identical to the
    single-device kernel.  The projections run replicated: on the decode
    hot path the gather-decode kernel, not the matmuls, is the
    bandwidth-bound stage APack targets.

    Returns (y [B, 1, D], new-token cache dict {k, v, k_scale, v_scale}).
    """
    from repro.kernels.fused_page_attention import fused_page_attention
    b = x.shape[0]
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hkv
    pos = jnp.broadcast_to(jnp.asarray(pos), (b,))
    q = proj(x, p["wq"], "bsd,dhk->bshk", tp=tp)
    k = proj(x, p["wk"], "bsd,dhk->bshk", tp=tp)
    v = proj(x, p["wv"], "bsd,dhk->bshk", tp=tp)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    posb = pos[:, None]
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    qk, sk = _kv_quantize(k[:, 0])
    qv, sv = _kv_quantize(v[:, 0])
    kd = _kv_dequantize(qk, sk)                             # [B, Hkv, dh]
    vd = _kv_dequantize(qv, sv)
    ps_sz = planes["tok_k"].shape[1]
    n_streams = planes["sym_k"].shape[2]
    # PACKED decode always spans the *full* head dim (streams interleave
    # heads), even when the dense planes are head-sharded
    n_steps = (ps_sz * hkv * dh) // max(n_streams, 1)
    kmeta = jnp.stack([meta["state"], meta["t0"]], axis=-1)
    t = tp[1] if tp is not None else 1
    if t > 1:
        hkv_loc = hkv // t
        h0 = (jax.lax.axis_index(tp[0]) * hkv_loc).astype(jnp.int32)
        q_kern = jax.lax.dynamic_slice_in_dim(
            q[:, 0].reshape(b, hkv, g, dh), h0, hkv_loc, axis=1
        ).reshape(b, hkv_loc * g, dh)
    else:
        h0 = jnp.int32(0)
        q_kern = q[:, 0]
    jm = jnp.concatenate(
        [meta["qw"], jnp.broadcast_to(h0, (b,))[:, None]], axis=1)
    acc, m_run, l_run = fused_page_attention(
        q_kern.astype(F32), meta["pid"], meta["tid"], kmeta, jm,
        planes, n_steps=n_steps, num_heads=h, h_full=hkv,
        softcap=float(cfg.logit_softcap), backend=backend)
    if t > 1:
        # reassemble the full head axis in axis-index order (= head-block
        # order, since h0 = axis_index * hkv_loc) before the merge below
        acc = jax.lax.all_gather(acc, tp[0], axis=1, tiled=True)
        m_run = jax.lax.all_gather(m_run, tp[0], axis=1, tiled=True)
        l_run = jax.lax.all_gather(l_run, tp[0], axis=1, tiled=True)
    # merge the current token's self-attention term (position == qpos,
    # always in-window) into the unnormalized online-softmax state, then
    # normalize — the kernel never divides, so fully-masked page sets
    # (fresh slots) are safe.
    q3 = q[:, 0].reshape(b, hkv, g, dh).astype(F32)
    s_self = jnp.einsum("bkgd,bkd->bkg", q3, kd) * (dh ** -0.5)
    if cfg.logit_softcap > 0:
        s_self = cfg.logit_softcap * jnp.tanh(s_self / cfg.logit_softcap)
    accr = acc.reshape(b, hkv, g, dh)
    mr = m_run.reshape(b, hkv, g)
    lr = l_run.reshape(b, hkv, g)
    m_tot = jnp.maximum(mr, s_self)
    alpha = jnp.exp(mr - m_tot)
    w_self = jnp.exp(s_self - m_tot)
    l_tot = lr * alpha + w_self
    out = (accr * alpha[..., None] + w_self[..., None] * vd[:, :, None, :]) \
        / l_tot[..., None]
    y = proj(out.reshape(b, h, dh).astype(x.dtype), p["wo"],
             "bhk,hkd->bd", tp=tp)[:, None, :]
    return y, {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}


def init_attention_cache(cfg: ModelConfig, batch: int, seq_len: int, *,
                         local: bool, dtype=BF16) -> dict:
    sc = min(cfg.window_size, seq_len) if local else seq_len
    shape = (batch, sc, cfg.num_kv_heads, cfg.head_dim)
    if cfg.kv_int8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], F32),
                "v_scale": jnp.zeros(shape[:-1], F32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# --------------------------------------------------------------------- mlp
def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dt),
         "w_down": (jax.random.normal(ks[1], (f, d)) * f ** -0.5).astype(dt)}
    if cfg.mlp_variant in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[2], (d, f)) * d ** -0.5).astype(dt)
    return p


def mlp(p: dict, x: jax.Array, cfg: ModelConfig,
        tp: tuple[str, int] | None = None) -> jax.Array:
    up = shd.constrain(proj(x, p["w_up"], "...k,kn->...n", tp=tp),
                       "ffn_hidden")
    if cfg.mlp_variant == "swiglu":
        h = jax.nn.silu(proj(x, p["w_gate"], "...k,kn->...n", tp=tp)) * up
    elif cfg.mlp_variant == "geglu":
        h = jax.nn.gelu(proj(x, p["w_gate"], "...k,kn->...n", tp=tp)) * up
    elif cfg.mlp_variant == "gelu":
        h = jax.nn.gelu(up)
    elif cfg.mlp_variant == "relu2":
        h = jnp.square(jax.nn.relu(up))
    else:
        raise ValueError(cfg.mlp_variant)
    return proj(shd.constrain(h, "ffn_hidden"), p["w_down"], "...k,kn->...n",
                tp=tp)


# --------------------------------------------------------------------- moe
MOE_GROUP = 1024     # tokens per dispatch group (GShard-style)


def init_moe(cfg: ModelConfig, key) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(F32),
        "wi": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dt),
        "wg": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(dt),
        "wo": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dt),
    }
    if cfg.n_shared_experts:
        sub = dataclasses.replace(cfg, mlp_variant="swiglu")
        p["shared"] = init_mlp(sub, ks[4],
                               d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def moe(p: dict, x: jax.Array, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Token-choice top-k MoE with capacity dropping (GShard/Switch style).

    x: [B, S, D].  Tokens regroup into MOE_GROUP-sized dispatch groups; the
    one-hot dispatch einsum keeps every shape static (TPU-friendly), experts
    shard over the ``model`` mesh axis.  Returns (y, aux_losses)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    g = min(MOE_GROUP, t)
    while t % g:
        g -= 1
    xg = x.reshape(t // g, g, d)
    cap = int(np.ceil(g * k * cfg.capacity_factor / e))
    cap = max(4, min(cap, g))

    def one_group(xt):                                    # [G, D]
        logits = (xt.astype(F32) @ p["router"]).astype(F32)   # [G, E]
        probs = jax.nn.softmax(logits, axis=-1)
        w, sel = jax.lax.top_k(probs, k)                  # [G, k]
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        counts = jnp.zeros((e,), F32)
        combine = jnp.zeros((g, e, cap), F32)
        for i in range(k):
            oh = jax.nn.one_hot(sel[:, i], e, dtype=F32)          # [G, E]
            pos = counts[None, :] + jnp.cumsum(oh, axis=0) - oh   # [G, E]
            keep = oh * (pos < cap)
            combine = combine + (w[:, i:i + 1] * keep)[:, :, None] \
                * jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=F32)
            counts = counts + keep.sum(axis=0)
        dispatch = (combine > 0).astype(xt.dtype)         # [G, E, C]
        xin = jnp.einsum("gec,gd->ecd", dispatch, xt)
        hi = jnp.einsum("ecd,edf->ecf", xin, p["wi"].astype(xt.dtype))
        hg = jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(xt.dtype))
        h = jax.nn.silu(hg) * hi
        out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xt.dtype))
        y = jnp.einsum("gec,ecd->gd", combine.astype(xt.dtype), out)
        # aux: Switch load-balance + router z-loss
        frac_tokens = jnp.mean(jax.nn.one_hot(sel[:, 0], e, dtype=F32), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        lb = e * jnp.sum(frac_tokens * frac_probs)
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        return y, lb, z

    y, lb, z = jax.vmap(one_group)(xg)
    y = y.reshape(b, s, d)
    if cfg.n_shared_experts:
        sub = dataclasses.replace(cfg, mlp_variant="swiglu")
        y = y + mlp(p["shared"], x, sub)
    return y, {"load_balance": lb.mean(), "router_z": z.mean()}


# ----------------------------------------------------------------- RG-LRU
def init_recurrent(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    # Griffin recurrent block: two input branches, temporal conv, RG-LRU,
    # gated multiply, output projection.
    c = 0.8 + 0.1 * jax.random.uniform(ks[4], (w,))       # a init near 1
    return {
        "w_x": (jax.random.normal(ks[0], (d, w)) * s).astype(dt),
        "w_gate": (jax.random.normal(ks[1], (d, w)) * s).astype(dt),
        "w_out": (jax.random.normal(ks[2], (w, d)) * w ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[3], (4, w)) * 0.5).astype(dt),
        "a_param": jnp.log(jnp.exp(8.0 * c) - 1.0).astype(F32),  # softplus inv
        "w_input_gate": (jax.random.normal(ks[5], (w,)) * 0.1).astype(dt),
        "w_a_gate": (jax.random.normal(ks[6], (w,)) * 0.1).astype(dt),
    }


def _rglru_coeffs(p, xw):
    """Per-step gate computation.  xw: [..., W] branch input (post conv)."""
    r = jax.nn.sigmoid(xw.astype(F32) * p["w_a_gate"].astype(F32))
    i = jax.nn.sigmoid(xw.astype(F32) * p["w_input_gate"].astype(F32))
    log_a = -8.0 * r * jax.nn.softplus(p["a_param"])      # c=8 as in Griffin
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * i * xw.astype(F32)


def recurrent_full(p: dict, x: jax.Array, cfg: ModelConfig, *,
                   pad_mask=None, true_len=None) -> tuple[jax.Array, dict]:
    """Griffin recurrent block over a full sequence (associative scan).

    ``pad_mask`` ([S] bool, True = end-padding past ``true_len``): pad
    steps are made inert (a=1, input contribution 0) so the scan carries
    ``h_{true_len-1}`` unchanged to the end — the ``h[:, -1]`` cache then
    equals the unpadded final state, and the conv history is sliced at
    the true end instead of the padded one."""
    b, s, d = x.shape
    xw = shd.constrain(x @ p["w_x"].astype(x.dtype), "ffn_hidden")  # [B,S,W]
    gate = jax.nn.gelu(
        shd.constrain(x @ p["w_gate"].astype(x.dtype), "ffn_hidden"))
    # temporal conv width 4 (causal)
    xp = jnp.pad(xw, ((0, 0), (3, 0), (0, 0)))
    conv = sum(xp[:, i:i + s] * p["conv_w"][i].astype(x.dtype)
               for i in range(4))
    a, bx = _rglru_coeffs(p, conv)
    if pad_mask is not None:
        pad3 = pad_mask[None, :, None]                    # [1,S,1]
        a = jnp.where(pad3, 1.0, a)
        bx = jnp.where(pad3, 0.0, bx)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    af, bf = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h = bf                                                # h_t with h_0 = 0
    y = (h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype)
    if true_len is not None:
        # conv history of the 3 positions before true_len (zeros when
        # true_len < 3 — identical semantics to the static branches)
        conv_c = jax.lax.dynamic_slice_in_dim(
            xp, jnp.asarray(true_len, jnp.int32), 3, axis=1)
        cache = {"h": h[:, -1].astype(F32), "conv": conv_c.astype(F32)}
    else:
        cache = {"h": h[:, -1].astype(F32),
                 "conv": xw[:, -3:].astype(F32) if s >= 3 else
                 jnp.pad(xw, ((0, 0), (3 - s, 0), (0, 0))).astype(F32)}
    return y, cache


def recurrent_step(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig
                   ) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    xw = (x[:, 0] @ p["w_x"].astype(x.dtype))             # [B, W]
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"].astype(x.dtype))
    hist = jnp.concatenate([cache["conv"].astype(xw.dtype), xw[:, None]], axis=1)
    conv = sum(hist[:, i] * p["conv_w"][i].astype(x.dtype) for i in range(4))
    a, bx = _rglru_coeffs(p, conv)
    h = a * cache["h"] + bx
    y = ((h.astype(x.dtype) * gate) @ p["w_out"].astype(x.dtype))[:, None]
    return y, {"h": h, "conv": hist[:, 1:].astype(F32)}


def init_recurrent_cache(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), F32),
            "conv": jnp.zeros((batch, 3, w), F32)}


# ------------------------------------------------------------------ mLSTM
def init_mlstm(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    f = int(cfg.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = f // h
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "w_up": (jax.random.normal(ks[0], (d, f)) * s).astype(dt),
        "w_gate": (jax.random.normal(ks[1], (d, f)) * s).astype(dt),
        "w_down": (jax.random.normal(ks[2], (f, d)) * f ** -0.5).astype(dt),
        "wq": (jax.random.normal(ks[3], (f, h, dh)) * f ** -0.5).astype(dt),
        "wk": (jax.random.normal(ks[4], (f, h, dh)) * f ** -0.5).astype(dt),
        "wv": (jax.random.normal(ks[5], (f, h, dh)) * f ** -0.5).astype(dt),
        "w_if": (jax.random.normal(ks[6], (f, h, 2)) * f ** -0.5).astype(F32),
        "out_norm": jnp.zeros((f,), dt),
    }


def _mlstm_chunk(q, k, v, i_gate, f_gate, c0, n0, m0):
    """One chunk of the mLSTM chunkwise-parallel form.

    q,k,v: [B,C,H,dh]; i,f: [B,C,H] log-space gates; state c0 [B,H,dh,dh],
    n0 [B,H,dh], m0 [B,H].  Returns (out [B,C,H,dh], c1, n1, m1)."""
    bsz, c, h, dh = q.shape
    logf = jax.nn.log_sigmoid(f_gate)                      # [B,C,H]
    lf_cum = jnp.cumsum(logf, axis=1)                      # inclusive b_t
    # intra-chunk contribution weight of step s to step t (s <= t):
    # exp(b_t - b_s + i_s) — decay over f_{s+1..t} times input gate i_s.
    a = lf_cum[:, :, None, :] - lf_cum[:, None, :, :]      # [B,T,S,H]
    logd = a + i_gate[:, None, :, :]
    tmask = jnp.tril(jnp.ones((c, c), bool))
    logd = jnp.where(tmask[None, :, :, None], logd, -1e30)
    # inter-chunk state (convention: true_C = c * exp(m)) enters step t with
    # weight exp(b_t + m0).
    logstate = lf_cum + m0[:, None, :]                     # [B,C,H]
    m = jnp.maximum(jnp.max(logd, axis=2), logstate)       # [B,C,H]
    dmat = jnp.exp(logd - m[:, :, None, :])                # [B,T,S,H]
    sstate = jnp.exp(logstate - m)                         # [B,C,H]
    qf = q.astype(F32) * (dh ** -0.5)
    scores = jnp.einsum("bthd,bshd->btsh", qf, k.astype(F32)) * dmat
    num_intra = jnp.einsum("btsh,bshd->bthd", scores, v.astype(F32))
    num_inter = jnp.einsum("bthd,bhde->bthe", qf, c0) * sstate[..., None]
    den_inter = jnp.einsum("bthd,bhd->bth", qf, n0) * sstate
    num = num_intra + num_inter
    den = jnp.maximum(jnp.abs(jnp.einsum("btsh->bth", scores) + den_inter),
                      jnp.exp(-m))
    out = num / den[..., None]
    # chunk-final state
    lf_tot = lf_cum[:, -1]                                 # [B,H]
    m1 = jnp.maximum(lf_tot + m0, jnp.max(i_gate + (lf_tot[:, None] - lf_cum), axis=1))
    w_state = jnp.exp(lf_tot + m0 - m1)                    # [B,H]
    w_in = jnp.exp(i_gate + (lf_tot[:, None, :] - lf_cum) - m1[:, None, :])
    c1 = c0 * w_state[..., None, None] + jnp.einsum(
        "bshd,bshe,bsh->bhde", k.astype(F32), v.astype(F32), w_in)
    n1 = n0 * w_state[..., None] + jnp.einsum(
        "bshd,bsh->bhd", k.astype(F32), w_in)
    return out, c1, n1, m1


def mlstm_full(p: dict, x: jax.Array, cfg: ModelConfig, *,
               pad_mask=None) -> tuple[jax.Array, dict]:
    """``pad_mask`` ([S] bool, True = end-padding): pad steps get
    ``i = -1e30`` (zero input weight) and ``f = 1e30`` (``log_sigmoid``
    exactly 0.0 — no state decay), so the chunkwise scan carries the
    state at the true end through the padded tail unchanged."""
    b, s, d = x.shape
    f = int(cfg.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = f // h
    up = shd.constrain(x @ p["w_up"].astype(x.dtype), "ffn_hidden")  # [B,S,F]
    gate = jax.nn.silu(
        shd.constrain(x @ p["w_gate"].astype(x.dtype), "ffn_hidden"))
    q = jnp.einsum("bsf,fhd->bshd", up, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsf,fhd->bshd", up, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsf,fhd->bshd", up, p["wv"].astype(x.dtype))
    gates = jnp.einsum("bsf,fhg->bshg", up.astype(F32), p["w_if"])
    i_gate, f_gate = gates[..., 0], gates[..., 1] + 3.0    # forget bias
    if pad_mask is not None:
        padh = pad_mask[None, :, None]                     # [1,S,1]
        i_gate = jnp.where(padh, -1e30, i_gate)
        f_gate = jnp.where(padh, 1e30, f_gate)
    chunk = _chunk_of(s)
    nc = s // chunk

    def to_chunks(t):
        return t.reshape(b, nc, chunk, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

    @jax.checkpoint
    def body(carry, xs):
        c0, n0, m0 = carry
        qc, kc, vc, ic, fc = xs
        out, c1, n1, m1 = _mlstm_chunk(qc, kc, vc, ic, fc, c0, n0, m0)
        return (c1, n1, m1), out

    # empty-state stabilizer init must match init_mlstm_cache (-1e30), or
    # the exp(-m) denominator bound differs between train and decode paths
    init = (jnp.zeros((b, h, dh, dh), F32), jnp.zeros((b, h, dh), F32),
            jnp.full((b, h), -1e30, F32))
    (c1, n1, m1), outs = jax.lax.scan(
        body, init, (to_chunks(q), to_chunks(k), to_chunks(v),
                     to_chunks(i_gate), to_chunks(f_gate)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, f)
    out = rms_norm(out.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    y = (out * gate) @ p["w_down"].astype(x.dtype)
    return y, {"c": c1, "n": n1, "m": m1}


def mlstm_step(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig
               ) -> tuple[jax.Array, dict]:
    b = x.shape[0]
    d = cfg.d_model
    f = int(cfg.mlstm_proj_factor * d)
    h = cfg.num_heads
    dh = f // h
    up = x[:, 0] @ p["w_up"].astype(x.dtype)               # [B,F]
    gate = jax.nn.silu(x[:, 0] @ p["w_gate"].astype(x.dtype))
    q = jnp.einsum("bf,fhd->bhd", up, p["wq"].astype(x.dtype)).astype(F32)
    k = jnp.einsum("bf,fhd->bhd", up, p["wk"].astype(x.dtype)).astype(F32)
    v = jnp.einsum("bf,fhd->bhd", up, p["wv"].astype(x.dtype)).astype(F32)
    gts = jnp.einsum("bf,fhg->bhg", up.astype(F32), p["w_if"])
    i_g, f_g = gts[..., 0], gts[..., 1] + 3.0
    logf = jax.nn.log_sigmoid(f_g)
    c0, n0, m0 = cache["c"], cache["n"], cache["m"]
    m1 = jnp.maximum(logf + m0, i_g)
    wf = jnp.exp(logf + m0 - m1)
    wi = jnp.exp(i_g - m1)
    c1 = c0 * wf[..., None, None] + jnp.einsum("bhd,bhe->bhde", k, v) * wi[..., None, None]
    n1 = n0 * wf[..., None] + k * wi[..., None]
    qs = q * (dh ** -0.5)
    num = jnp.einsum("bhd,bhde->bhe", qs, c1)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n1)), jnp.exp(-m1))
    out = (num / den[..., None]).reshape(b, f)
    out = rms_norm(out.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    y = ((out * gate) @ p["w_down"].astype(x.dtype))[:, None]
    return y, {"c": c1, "n": n1, "m": m1}


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    f = int(cfg.mlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    dh = f // h
    return {"c": jnp.zeros((batch, h, dh, dh), F32),
            "n": jnp.zeros((batch, h, dh), F32),
            "m": jnp.full((batch, h), -1e30, F32)}


# ------------------------------------------------------------------ sLSTM
def init_slstm(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    f = int(cfg.slstm_proj_factor * d)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_in": (jax.random.normal(ks[0], (d, 4, d)) * s).astype(dt),
        # block-diagonal recurrence: per head [dh, dh] for each of 4 gates
        "r": (jax.random.normal(ks[1], (4, h, dh, dh)) * dh ** -0.5).astype(F32),
        "b": jnp.zeros((4, d), F32),
        "out_norm": jnp.zeros((d,), dt),
        "w_up": (jax.random.normal(ks[2], (d, 2, f)) * s).astype(dt),
        "w_down": (jax.random.normal(ks[3], (f, d)) * f ** -0.5).astype(dt),
    }


def _slstm_cell(zx, state, p, h_heads, pad=None):
    """One time step.  zx: [B, 4, D] pre-activations (input part).

    ``pad`` (scalar bool, bucketed-prefill path): a padding step is made
    a no-op — input gate forced to -1e30, forget decay to 0 (log-space),
    and the hidden output held at ``hprev`` — so the carried state at
    the end of a padded sequence equals the state at the true end."""
    c, n, m, hprev = state
    b, _, d = zx.shape
    hh = hprev.reshape(b, h_heads, -1)
    rec = jnp.einsum("ghde,bhd->gbhe", p["r"], hh).transpose(1, 0, 2, 3) \
        .reshape(b, 4, d)
    pre = zx.astype(F32) + rec + p["b"][None]
    zt = jnp.tanh(pre[:, 0])
    it = pre[:, 1]
    ft = pre[:, 2]
    ot = jax.nn.sigmoid(pre[:, 3])
    logf = jax.nn.log_sigmoid(ft)
    if pad is not None:
        it = jnp.where(pad, -1e30, it)
        logf = jnp.where(pad, 0.0, logf)
    m1 = jnp.maximum(logf + m, it)
    wi = jnp.exp(it - m1)
    wf = jnp.exp(logf + m - m1)
    c1 = wf * c + wi * zt
    n1 = wf * n + wi
    h1 = ot * (c1 / jnp.maximum(n1, 1e-6))
    if pad is not None:
        h1 = jnp.where(pad, hprev, h1)
    return (c1, n1, m1, h1), h1


def slstm_full(p: dict, x: jax.Array, cfg: ModelConfig, *,
               pad_mask=None) -> tuple[jax.Array, dict]:
    b, s, d = x.shape
    h = cfg.num_heads
    zx = jnp.einsum("bsd,dge->bsge", x, p["w_in"].astype(x.dtype))  # [B,S,4,D]
    chunk = _chunk_of(s)
    nc = s // chunk
    zc = zx.reshape(b, nc, chunk, 4, d).transpose(1, 2, 0, 3, 4)    # [nc,C,B,4,D]
    padc = (pad_mask.reshape(nc, chunk) if pad_mask is not None
            else jnp.zeros((nc, chunk), bool))

    @jax.checkpoint
    def chunk_body(state, xs):                                      # depth-1
        zchunk, pchunk = xs

        def step(st, xt):                                           # depth-2
            zt, pt = xt
            return _slstm_cell(zt, st, p, h, pad=pt)
        state, hs = jax.lax.scan(step, state, (zchunk, pchunk))
        return state, hs

    init = (jnp.zeros((b, d), F32), jnp.zeros((b, d), F32),
            jnp.full((b, d), -1e30, F32), jnp.zeros((b, d), F32))
    state, hs = jax.lax.scan(chunk_body, init, (zc, padc))          # [nc,C,B,D]
    hseq = hs.transpose(2, 0, 1, 3).reshape(b, s, d).astype(x.dtype)
    hseq = rms_norm(hseq, p["out_norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,dgf->bsgf", hseq, p["w_up"].astype(x.dtype))
    y = (jax.nn.gelu(up[:, :, 0]) * up[:, :, 1]) @ p["w_down"].astype(x.dtype)
    return y, {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}


def slstm_step(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig
               ) -> tuple[jax.Array, dict]:
    zx = jnp.einsum("bd,dge->bge", x[:, 0], p["w_in"].astype(x.dtype))
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    state, h1 = _slstm_cell(zx, state, p, cfg.num_heads)
    hs = rms_norm(h1.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    up = jnp.einsum("bd,dgf->bgf", hs, p["w_up"].astype(x.dtype))
    y = ((jax.nn.gelu(up[:, 0]) * up[:, 1]) @ p["w_down"].astype(x.dtype))[:, None]
    return y, {"c": state[0], "n": state[1], "m": state[2], "h": state[3]}


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), F32), "n": jnp.zeros((batch, d), F32),
            "m": jnp.full((batch, d), -1e30, F32),
            "h": jnp.zeros((batch, d), F32)}


# ------------------------------------------------------------ KV page pool
# Host-side model of the *off-chip* KV store for `kv_cache_dtype =
# "apack-int8"` serving: fixed-size token pages in a block pool with
# free-list allocation (the on-chip compute path still sees dense int8 —
# `models/model.py::PagedKVCache` materializes it every attention read).
#
# Page lifecycle: FREE -> HOT (per-token int8 + per-token-head scales,
# being appended) -> COLD (full; re-quantized to one scale per (page, head)
# — the scale amortization is itself a ~20% footprint cut over the dense
# int8 layout) -> PACKED (COLD payload APack-compressed with the layer's
# activation-mode table into fixed-capacity word-interleaved planes, ready
# for the Pallas gather-decode kernel).  Pages that fill before the layer's
# table is calibrated stay COLD.  Rolling-window (local-attention) layers
# additionally take the COLD/PACKED -> FREE edge through ``evict`` once
# every token in the page has rolled out of the attention window.
#
# Invariant violations raise ``ValueError``/``RuntimeError`` (never bare
# ``assert``): a double free or an overfull page is data corruption, and
# ``python -O`` strips asserts — the pool must stay loud under -O.

PAGE_FREE, PAGE_HOT, PAGE_COLD, PAGE_PACKED, PAGE_SPILLED = 0, 1, 2, 3, 4
PAGE_STATE_NAMES = {PAGE_FREE: "FREE", PAGE_HOT: "HOT",
                    PAGE_COLD: "COLD", PAGE_PACKED: "PACKED",
                    PAGE_SPILLED: "SPILLED"}

# Canonical page-lifecycle transition table — the single source of truth for
# the pool state machine.  Keys are the pool methods that move pages between
# states; values are the declared (src, dst) edges.  Two consumers:
#
#   * runtime: ``KVPagePool._require_transition`` validates every lifecycle
#     step against this table before the state write happens, so an illegal
#     edge raises instead of corrupting the pool;
#   * static:  ``repro.analysis.lifecycle`` parses this literal and verifies
#     every ``self.state[pid] = PAGE_*`` assignment site in the tree has a
#     dominating guard for a declared edge — CI fails on drift.
#
# SPILLED is deliberately absent: it is a *page-table* state owned by
# ``model.PagedKVCache`` (negative spill handles), never a pool-slot state —
# ``spill`` frees the slot and the payload parks in ``HostSpillTier``.
# ``evict``/``spill`` edges end at FREE because both funnel through ``free``
# for the actual write + scrub; their entries declare which sources may
# take that path (HOT pages are never evictable).  This dict must stay a
# pure literal: the analyzer reads it from the AST without importing jax.
PAGE_TRANSITIONS = {
    "alloc":  ((PAGE_FREE, PAGE_HOT),),
    "free":   ((PAGE_HOT, PAGE_FREE), (PAGE_COLD, PAGE_FREE),
               (PAGE_PACKED, PAGE_FREE)),
    "evict":  ((PAGE_COLD, PAGE_FREE), (PAGE_PACKED, PAGE_FREE)),
    "spill":  ((PAGE_HOT, PAGE_FREE), (PAGE_COLD, PAGE_FREE),
               (PAGE_PACKED, PAGE_FREE)),
    "adopt":  ((PAGE_HOT, PAGE_COLD), (PAGE_HOT, PAGE_PACKED)),
    "seal":   ((PAGE_HOT, PAGE_COLD),),
    "pack":   ((PAGE_COLD, PAGE_PACKED),),
    "repack": ((PAGE_PACKED, PAGE_PACKED),),
}


class PageIntegrityError(RuntimeError):
    """A KV page failed an integrity check (checksum mismatch on unspill or
    re-pack, a SPILLED page reached the decode path, or a poisoned table
    generation).  Carries enough structure for the engine to fail the
    *owning* request only — neighbors must never be poisoned."""

    def __init__(self, msg: str, *, rid: int | None = None,
                 layer: int | None = None, pid: int | None = None,
                 handle: int | None = None):
        super().__init__(msg)
        self.rid = rid
        self.layer = layer
        self.pid = pid
        self.handle = handle


class TransferDropped(RuntimeError):
    """An h2d/d2h transfer was dropped (fault injection / flaky link)."""

    def __init__(self, msg: str, *, direction: str = "?"):
        super().__init__(msg)
        self.direction = direction


@dataclasses.dataclass
class SpillRecord:
    """One page's payload parked in the host spill tier.

    ``state`` is the *pre-spill* pool state (HOT/COLD/PACKED) — it picks the
    payload layout on adopt; the page-table entry itself is SPILLED while
    the record lives here.  ``crc`` is stamped by :meth:`HostSpillTier.put`
    over the serialized payload and re-verified on every ``get``."""
    state: int
    fill: int
    layer: int
    gen: int                       # page_gen at spill time (table row id)
    payload: dict[str, np.ndarray]
    comp_bytes: int                # pool footprint at spill time
    raw_bytes: int                 # dense-int8 equivalent (spill ratio denom)
    crc: int = 0
    meta: dict = dataclasses.field(default_factory=dict)


def payload_crc(payload: dict[str, np.ndarray]) -> int:
    """CRC32 over a payload dict in sorted-key order (canonical framing:
    EBPC-style lossless streams are only robust with explicit integrity,
    PAPERS.md 1908.11645)."""
    c = 0
    for k in sorted(payload):
        c = zlib.crc32(payload[k].tobytes(), c)
    return c & 0xFFFFFFFF


class HostSpillTier:
    """Pinned-host-memory spill store for compressed KV pages.

    Records are append-only blobs keyed by an opaque handle; ``get``
    recomputes the CRC and *quarantines* a mismatching record (kept for
    forensics, never re-served) before raising ``PageIntegrityError``.
    On real hardware the payloads would sit in page-locked host buffers so
    readahead h2d can be async DMA; in this container they are host numpy
    copies with identical accounting."""

    def __init__(self):
        self._records: dict[int, SpillRecord] = {}
        self.quarantined: dict[int, SpillRecord] = {}
        self._next_handle = 0
        self.live_bytes = 0                 # compressed bytes currently parked
        self.put_count = 0
        self.get_count = 0
        self.integrity_failures = 0

    @property
    def live_count(self) -> int:
        return len(self._records)

    def live_gens(self) -> set[int]:
        """Table generations referenced by parked records.  Table-row
        compaction must treat these as live: an unspilled page decodes
        with the table generation it was packed under."""
        return {rec.gen for rec in self._records.values()}

    def put(self, rec: SpillRecord) -> int:
        rec.crc = payload_crc(rec.payload)
        handle = self._next_handle
        self._next_handle += 1
        self._records[handle] = rec
        self.live_bytes += rec.comp_bytes
        self.put_count += 1
        return handle

    def get(self, handle: int, *, verify: bool = True) -> SpillRecord:
        if handle not in self._records:
            raise KeyError(
                f"spill handle {handle} not live "
                f"(quarantined={handle in self.quarantined})")
        rec = self._records[handle]
        self.get_count += 1
        if verify and payload_crc(rec.payload) != rec.crc:
            self.quarantine(handle)
            raise PageIntegrityError(
                f"spilled page failed checksum on unspill (handle={handle}, "
                f"layer={rec.layer}, state="
                f"{PAGE_STATE_NAMES.get(rec.state, rec.state)}); "
                "record quarantined", handle=handle, layer=rec.layer)
        return rec

    def drop(self, handle: int) -> None:
        """Release a live record (owner retired or page unspilled).
        Quarantined records are kept — dropping evidence is how silent
        corruption spreads."""
        rec = self._records.pop(handle, None)
        if rec is not None:
            self.live_bytes -= rec.comp_bytes

    def quarantine(self, handle: int) -> None:
        rec = self._records.pop(handle, None)
        if rec is None:
            return
        self.live_bytes -= rec.comp_bytes
        self.quarantined[handle] = rec
        self.integrity_failures += 1


class KVPagePool:
    """Block pool of fixed-size KV token pages (storage + free list only;
    tables/calibration/decode policy live in ``model.PagedKVCache``).

    Kind axis: index 0 = K, 1 = V throughout.

    ``n_shards`` partitions the page-id space into contiguous per-shard
    ranges (shard ``s`` owns ``[s*pages_per_shard, (s+1)*pages_per_shard)``)
    with one free list per shard, so mesh-sharded admission reserves and
    allocates without ever serializing on a global free list.  The
    contiguous layout is what lets the device plane mirror shard its page
    axis with plain block `PartitionSpec`s — shard ``s``'s rows are
    exactly its page range."""

    def __init__(self, num_pages: int, page_size: int, kv_heads: int,
                 head_dim: int, elems_per_stream: int = 128,
                 n_shards: int = 1):
        from repro.kernels.ref import ofs_capacity_words, sym_capacity_words
        if n_shards < 1 or num_pages % n_shards:
            raise ValueError(
                f"num_pages={num_pages} must split evenly over "
                f"n_shards={n_shards} contiguous page ranges")
        self.n_shards = n_shards
        self.pages_per_shard = num_pages // n_shards
        self.num_pages = num_pages
        self.page_size = page_size
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        L = page_size * kv_heads * head_dim     # values per page per kind
        e = min(elems_per_stream, L)
        while L % e:                            # largest divisor <= target
            e -= 1
        self.elems_per_stream = e
        self.n_streams = L // e
        self.sym_words = sym_capacity_words(e)
        self.ofs_words = ofs_capacity_words(e, 8)
        p, ps, h, dh, s = num_pages, page_size, kv_heads, head_dim, self.n_streams
        # HOT storage: the per-token layout the model's int8 path emits
        self.tok_q = np.zeros((2, p, ps, h, dh), np.int8)
        self.tok_scale = np.zeros((2, p, ps, h), np.float32)
        # COLD storage: page-granular scales
        self.cold_q = np.zeros((2, p, ps, h, dh), np.int8)
        self.page_scale = np.zeros((2, p, h), np.float32)
        # PACKED storage: fixed-capacity APack planes, stackable for the
        # paged gather-decode kernel
        self.sym = np.zeros((2, p, self.sym_words, s), np.uint32)
        self.ofs = np.zeros((2, p, self.ofs_words, s), np.uint32)
        self.sym_bits = np.zeros((2, p, s), np.int32)
        self.ofs_bits = np.zeros((2, p, s), np.int32)
        self.stored = np.zeros((2, p, s), bool)
        self.fill = np.zeros(p, np.int32)
        self.state = np.full(p, PAGE_FREE, np.uint8)
        # per-shard stacks, each popping its lowest page id first (the
        # n_shards=1 layout is bit-compatible with the old single list)
        pps = self.pages_per_shard
        self.free_lists: list[list[int]] = [
            list(range((s + 1) * pps - 1, s * pps - 1, -1))
            for s in range(n_shards)]
        self.alloc_count = 0                    # lifetime allocs (reuse proof)
        self.high_water = 0                     # max pages in use at once
        self.evict_count = 0                    # rolling-window evictions
        self.spill_count = 0                    # pages spilled to host tier
        self.unspill_count = 0                  # pages adopted back in

    def _page_state(self, pid: int) -> str:
        st = int(self.state[pid])
        return (f"page {pid}: state={PAGE_STATE_NAMES.get(st, st)} "
                f"fill={int(self.fill[pid])}/{self.page_size}")

    def _require_transition(self, pid: int, edge: str, dst: int, *,
                            exc: type = ValueError,
                            detail: str | None = None) -> int:
        """Validate one lifecycle step against ``PAGE_TRANSITIONS`` and
        return the current (source) state.  Every state-mutating pool
        method funnels through here, so the declared table *is* the
        runtime guard — not a comment about it.  ``detail`` prefixes the
        error with the caller's diagnosis (kept stable for tests that
        match on it); the transition itself is always spelled out."""
        src = int(self.state[pid])
        if (src, dst) not in PAGE_TRANSITIONS[edge]:
            what = detail or f"illegal {edge}"
            raise exc(
                f"{what}: {PAGE_STATE_NAMES.get(src, src)}->"
                f"{PAGE_STATE_NAMES.get(dst, dst)} is not a declared "
                f"page transition ({self._page_state(pid)})")
        return src

    # ------------------------------------------------------------ free list
    @property
    def free_count(self) -> int:
        return sum(len(fl) for fl in self.free_lists)

    def free_count_shard(self, shard: int) -> int:
        return len(self.free_lists[shard])

    def shard_of(self, pid: int) -> int:
        """Owning shard of a page id (contiguous range partition)."""
        return pid // self.pages_per_shard

    def alloc(self, shard: int = 0) -> int | None:
        fl = self.free_lists[shard]
        if not fl:
            return None
        pid = fl.pop()
        # a non-FREE page on the free list is corruption — stay loud
        self._require_transition(pid, "alloc", PAGE_HOT, exc=RuntimeError,
                                 detail="alloc from corrupt free list")
        self.state[pid] = PAGE_HOT
        self.fill[pid] = 0
        self.alloc_count += 1
        self.high_water = max(self.high_water,
                              self.num_pages - self.free_count)
        return pid

    def free(self, pid: int) -> None:
        self._require_transition(pid, "free", PAGE_FREE,
                                 detail="double free of page")
        self.state[pid] = PAGE_FREE
        self.fill[pid] = 0
        # scrub so a stale read of a recycled page is loud, not subtle
        self.tok_q[:, pid] = 0
        self.tok_scale[:, pid] = 0
        self.cold_q[:, pid] = 0
        self.page_scale[:, pid] = 0
        self.sym[:, pid] = 0
        self.ofs[:, pid] = 0
        self.sym_bits[:, pid] = 0
        self.ofs_bits[:, pid] = 0
        self.stored[:, pid] = False
        self.free_lists[self.shard_of(pid)].append(pid)

    def evict(self, pid: int) -> None:
        """Rolling-window eviction hook: return a *sealed* page whose every
        token has rolled out of its layer's attention window.  HOT pages
        are never evictable — the newest tokens live there, and a policy
        bug that tries is corruption, not cleanup."""
        self._require_transition(
            pid, "evict", PAGE_FREE, exc=RuntimeError,
            detail="evict of live HOT (or already-FREE) page; rolling "
                   "eviction may only free sealed COLD/PACKED pages")
        self.free(pid)
        self.evict_count += 1

    # ------------------------------------------------------------- spill
    def spill(self, pid: int) -> tuple[int, int, dict, int]:
        """Copy a page's payload out for the host spill tier and free its
        pool slot.  Returns ``(state, fill, payload, comp_bytes)`` — the
        page-table entry transitions to SPILLED (tracked by the owner via a
        negative handle; the pool slot itself goes back on the free list).
        Only the arrays the state actually uses are captured: HOT pages the
        per-token planes, COLD the page-requantized payload, PACKED just the
        compressed planes + page scales (the headline case: spill traffic is
        APack-compressed)."""
        st = self._require_transition(pid, "spill", PAGE_FREE,
                                      detail="spill of FREE page")
        fill = int(self.fill[pid])
        if st == PAGE_HOT:
            payload = {"tok_q": self.tok_q[:, pid].copy(),
                       "tok_scale": self.tok_scale[:, pid].copy()}
        elif st == PAGE_COLD:
            payload = {"cold_q": self.cold_q[:, pid].copy(),
                       "page_scale": self.page_scale[:, pid].copy()}
        else:
            payload = {"sym": self.sym[:, pid].copy(),
                       "ofs": self.ofs[:, pid].copy(),
                       "sym_bits": self.sym_bits[:, pid].copy(),
                       "ofs_bits": self.ofs_bits[:, pid].copy(),
                       "stored": self.stored[:, pid].copy(),
                       "page_scale": self.page_scale[:, pid].copy()}
        comp = self.page_bytes(pid)
        self.free(pid)
        self.spill_count += 1
        return st, fill, payload, comp

    def adopt(self, st: int, fill: int, payload: dict,
              shard: int = 0) -> int:
        """Inverse of ``spill``: allocate a fresh slot (from ``shard``'s
        free list) and restore a spilled payload into it (FREE ->
        HOT/COLD/PACKED).  The pid is generally *different* from the one
        the page was spilled out of — owners must rewrite their page-table
        entry."""
        pid = self.alloc(shard)
        if pid is None:
            raise RuntimeError(
                "no free page to unspill into — admission must re-reserve "
                "before readahead")
        if st == PAGE_HOT:
            self.tok_q[:, pid] = payload["tok_q"]
            self.tok_scale[:, pid] = payload["tok_scale"]
            self.fill[pid] = fill
        elif st == PAGE_COLD:
            self._require_transition(pid, "adopt", PAGE_COLD)
            self.cold_q[:, pid] = payload["cold_q"]
            self.page_scale[:, pid] = payload["page_scale"]
            self.fill[pid] = fill
            self.state[pid] = PAGE_COLD
        elif st == PAGE_PACKED:
            self._require_transition(pid, "adopt", PAGE_PACKED)
            self.sym[:, pid] = payload["sym"]
            self.ofs[:, pid] = payload["ofs"]
            self.sym_bits[:, pid] = payload["sym_bits"]
            self.ofs_bits[:, pid] = payload["ofs_bits"]
            self.stored[:, pid] = payload["stored"]
            self.page_scale[:, pid] = payload["page_scale"]
            self.fill[pid] = fill
            self.state[pid] = PAGE_PACKED
        else:
            self.free(pid)
            raise ValueError(f"adopt of invalid spilled state {st}")
        self.unspill_count += 1
        return pid

    # ------------------------------------------------------------- writes
    def write_token(self, pid: int, kq: np.ndarray, vq: np.ndarray,
                    ks: np.ndarray, vs: np.ndarray) -> int:
        """Append one token's [H, dh] int8 K/V (+ [H] scales).  Returns the
        in-page offset written."""
        if self.state[pid] != PAGE_HOT:
            raise ValueError(
                f"write_token into non-HOT page ({self._page_state(pid)})")
        off = int(self.fill[pid])
        if off >= self.page_size:
            raise RuntimeError(
                f"write_token into overfull page ({self._page_state(pid)})")
        self.tok_q[0, pid, off] = kq
        self.tok_q[1, pid, off] = vq
        self.tok_scale[0, pid, off] = ks
        self.tok_scale[1, pid, off] = vs
        self.fill[pid] = off + 1
        return off

    def note_device_write(self, pid: int) -> int:
        """Metadata half of an *on-device* token append: the value was
        scatter-written into the device plane mirror
        (``model.device_append``), the host only advances the fill count.
        Same invariants as ``write_token`` — the host pool stays the
        source of truth for page lifecycle even when payloads live on
        device."""
        if self.state[pid] != PAGE_HOT:
            raise ValueError(
                f"device write into non-HOT page ({self._page_state(pid)})")
        off = int(self.fill[pid])
        if off >= self.page_size:
            raise RuntimeError(
                f"device write into overfull page ({self._page_state(pid)})")
        self.fill[pid] = off + 1
        return off

    def seal(self, pid: int, q2: np.ndarray, scale2: np.ndarray) -> None:
        """HOT -> COLD: store the page-requantized payload (``q2``
        [2, page_size, H, dh] int8, ``scale2`` [2, H] f32) and drop the
        per-token copy."""
        self._require_transition(pid, "seal", PAGE_COLD,
                                 detail="seal of non-full or non-HOT page")
        if self.fill[pid] != self.page_size:
            raise ValueError(
                f"seal of non-full or non-HOT page ({self._page_state(pid)})")
        self.cold_q[:, pid] = q2
        self.page_scale[:, pid] = scale2
        self.tok_q[:, pid] = 0
        self.tok_scale[:, pid] = 0
        self.state[pid] = PAGE_COLD

    def pack(self, pid: int, planes: tuple) -> None:
        """COLD -> PACKED: store both kinds' compressed planes
        (``planes`` = (sym[2,Ws,S], ofs[2,Wo,S], sym_bits[2,S],
        ofs_bits[2,S], stored[2,S])) and scrub the raw payload so any read
        that bypasses the decoder is visibly wrong."""
        self._require_transition(pid, "pack", PAGE_PACKED,
                                 detail="pack of non-COLD page")
        sym, ofs, sb, ob, st = planes
        self.sym[:, pid] = sym
        self.ofs[:, pid] = ofs
        self.sym_bits[:, pid] = sb
        self.ofs_bits[:, pid] = ob
        self.stored[:, pid] = st
        self.cold_q[:, pid] = 0
        self.state[pid] = PAGE_PACKED

    def repack(self, pid: int, planes: tuple) -> None:
        """PACKED -> PACKED: atomically swap a page's compressed planes for
        a re-encode under a *newer* table (table-refresh re-pack).  Same
        payload tuple as ``pack``.  The swap is whole-page: readers either
        see the complete old planes or the complete new ones — pages are
        immutable and independently coded, so decode stays lossless across
        a refresh as long as the reader's table id swaps with the planes
        (``model.PagedKVCache`` stamps ``page_gen`` in the same host-side
        critical section)."""
        self._require_transition(pid, "repack", PAGE_PACKED,
                                 detail="repack of non-PACKED page")
        sym, ofs, sb, ob, st = planes
        self.sym[:, pid] = sym
        self.ofs[:, pid] = ofs
        self.sym_bits[:, pid] = sb
        self.ofs_bits[:, pid] = ob
        self.stored[:, pid] = st

    # -------------------------------------------------------- accounting
    def dense_bytes(self, n_tokens: int) -> int:
        """What the dense int8 engine stores for ``n_tokens`` of one layer:
        int8 K+V plus per-token-head f32 scales."""
        h, dh = self.kv_heads, self.head_dim
        return 2 * (n_tokens * h * dh + n_tokens * h * 4)

    def page_bytes(self, pid: int) -> int:
        """Actual off-chip footprint of a page in its current state."""
        from repro.core.format import DIR_BITS_PER_STREAM
        h, dh = self.kv_heads, self.head_dim
        st = self.state[pid]
        if st == PAGE_HOT:
            return self.dense_bytes(int(self.fill[pid]))
        if st == PAGE_COLD:
            return 2 * (self.page_size * h * dh + h * 4)
        if st == PAGE_PACKED:
            payload = int(self.sym_bits[:, pid].sum()
                          + self.ofs_bits[:, pid].sum())
            directory = 2 * self.n_streams * DIR_BITS_PER_STREAM
            return (payload + directory + 7) // 8 + 2 * h * 4
        return 0

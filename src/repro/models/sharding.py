"""GSPMD sharding rules: param-path -> PartitionSpec.

Strategy (DESIGN.md §4): FSDP over the data(+pod) axes on one weight dim,
TP over ``model`` on the heads/ffn/vocab dim; GSPMD padding absorbs
non-divisible head counts (paligemma 8H, command-r 96H on a 16-way axis).
Activations: batch over data(+pod); heads/d_ff/vocab over model; optional
sequence-parallel residuals.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fsdp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on any dim whose size isn't divisible by its mesh-axes
    product (jit in_shardings demand exact divisibility; GSPMD pads only
    intermediates).  E.g. 8 kv-heads on a 16-way model axis -> replicated."""
    fitted = []
    for dim, entry in zip(shape, spec):
        fitted.append(entry if dim % _axes_size(mesh, entry) == 0 else None)
    return P(*fitted)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return fsdp_axes(mesh)


def _param_spec(path: str, leaf, fsdp) -> P:
    """Rules keyed on parameter path substrings (see models/model.py trees)."""
    nd = leaf.ndim
    f = fsdp

    def strip_stack(spec: P) -> P:
        # stacked (scanned) leaves carry a leading layer dim -> None
        return spec

    if "unembed" in path:          # must precede the "embed" substring test
        # Measured (kimi train GA4): P(None, "model") — the "obvious"
        # zero-forward-comms choice — replicates the unembed grads and
        # moments, costing +23 s memory-term and +25 GiB peak vs sharding
        # D over model and V over fsdp.  Keep the measured-better layout.
        return P("model", f)
    if "embed" in path:
        return P("model", f)
    if "norm" in path or "a_param" in path or "gate_vec" in path:
        return P(*([None] * nd))
    if "inner" in path:
        # attention
        if path.endswith("wq") or path.endswith("wk") or path.endswith("wv"):
            if nd >= 3:
                return P(*([None] * (nd - 3)), f, "model", None)
        if path.endswith("wo") and nd >= 3:
            return P(*([None] * (nd - 3)), "model", None, f)
        # rglru / mlstm projections
        if path.endswith("w_x") or path.endswith("w_gate") or path.endswith("w_up"):
            return P(*([None] * (nd - 2)), f, "model")
        if path.endswith("w_out") or path.endswith("w_down"):
            return P(*([None] * (nd - 2)), "model", f)
        if path.endswith("conv_w"):
            return P(*([None] * (nd - 1)), "model")
        if path.endswith("w_input_gate") or path.endswith("w_a_gate"):
            return P(*([None] * (nd - 1)), "model")
        if path.endswith("w_if"):
            return P(*([None] * (nd - 3)), "model", None, None)
        if path.endswith("w_in"):                      # slstm [D, 4, D]
            return P(*([None] * (nd - 3)), f, None, "model")
        if path.endswith("/r"):
            return P(*([None] * nd))
    if "ffn" in path:
        if path.endswith("router"):
            return P(*([None] * (nd - 2)), f, None)
        if path.endswith("wi") or path.endswith("wg"):   # [E, D, F]
            if _CTX.get("moe_ep"):
                # resident-expert EP: experts live whole on their shard
                # (E over dp axes, D/F over model) -> token all-to-all
                # replaces per-microbatch expert-weight all-gathers
                return P(*([None] * (nd - 3)), f, "model", None)
            return P(*([None] * (nd - 3)), "model", f, None)
        if path.endswith("wo") and nd >= 3:              # [E, F, D]
            if _CTX.get("moe_ep"):
                return P(*([None] * (nd - 3)), f, None, "model")
            return P(*([None] * (nd - 3)), "model", None, f)
        if path.endswith("w_up") or path.endswith("w_gate"):
            return P(*([None] * (nd - 2)), f, "model")
        if path.endswith("w_down"):
            return P(*([None] * (nd - 2)), "model", f)
        if path.endswith("w_in"):
            return P(*([None] * (nd - 3)), f, None, "model")
    # default: replicate
    return P(*([None] * nd))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(mesh: Mesh, params: Any) -> Any:
    """NamedSharding pytree matching an (abstract) param tree.

    Stacked leaves (leading layer dim from the scan) get their rule applied
    to the trailing dims — the rules above already index from the right."""
    f = fsdp_axes(mesh)

    def one(path, leaf):
        spec = _param_spec(_path_str(path), leaf, f)
        if len(spec) < leaf.ndim:           # pad leading dims (layer stack)
            spec = P(*([None] * (leaf.ndim - len(spec))), *spec)
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


def cache_shardings(mesh: Mesh, caches: Any) -> Any:
    """KV caches: batch over dp; kv-heads over model when divisible, else
    sequence over model (split-K / FlashDecoding-style decode attention —
    GSPMD inserts the psum over sequence shards)."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        p = _path_str(path)
        nd = leaf.ndim
        if p.endswith("/k") or p.endswith("/v"):
            # [(layers,) B, S, Hkv, dh]
            lead = [None] * (nd - 4)
            for cand in (P(*lead, dp, None, "model", None),
                         P(*lead, dp, "model", None, None),
                         P(*lead, dp, None, None, None)):
                if cand == fit_spec(cand, leaf.shape, mesh):
                    return NamedSharding(mesh, cand)
        if p.endswith("_scale"):
            # [(layers,) B, S, Hkv]
            lead = [None] * (nd - 3)
            for cand in (P(*lead, dp, None, "model"),
                         P(*lead, dp, "model", None),
                         P(*lead, dp, None, None)):
                if cand == fit_spec(cand, leaf.shape, mesh):
                    return NamedSharding(mesh, cand)
        if nd >= 2:
            spec = P(*([None] * (nd - 2)), dp, "model")
        else:
            spec = P(*([None] * nd))
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, caches)


def batch_shardings(mesh: Mesh, batch: Any) -> Any:
    dp = dp_axes(mesh)

    def one(path, leaf):
        if leaf.ndim >= 1:
            spec = P(dp, *([None] * (leaf.ndim - 1)))
        else:
            spec = P()
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, batch)


def activation_constraint(mesh: Mesh, h: jax.Array, *,
                          seq_shard: bool = False) -> jax.Array:
    """Residual-stream constraint between blocks: batch over dp and,
    optionally, sequence-parallel over model."""
    dp = dp_axes(mesh)
    spec = P(dp, "model", None) if seq_shard else P(dp, None, None)
    return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))


def logits_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(dp_axes(mesh), None, "model"))


# --------------------------------------------- paged pool plane partitioning
# Rules for the ``DevicePoolPlanes`` dict under a serving mesh
# (axes ("data", "model")).  Pages shard over "data" — each data shard owns
# a contiguous page range matching its per-shard free list, so append and
# gather stay shard-local.  Dense HOT/COLD payloads additionally shard
# their kv-head dim over "model" (tensor-parallel heads in the fused
# kernel).  PACKED planes (sym/ofs/stored) CANNOT head-shard: the APack
# stream layout interleaves heads across lanes, so every model shard keeps
# the full compressed words for its pages and the kernel decodes the full
# page then slices its local head block.  Table planes (vm/ol/cum) are
# small and fully replicated.
_PLANE_RULES: dict[str, tuple] = {
    "tok_k": ("data", None, "model", None),
    "tok_v": ("data", None, "model", None),
    "cold_k": ("data", None, "model", None),
    "cold_v": ("data", None, "model", None),
    "tok_sk": ("data", None, "model"),
    "tok_sv": ("data", None, "model"),
    "pscale_k": ("data", "model"),
    "pscale_v": ("data", "model"),
    "sym_k": ("data", None, None),
    "sym_v": ("data", None, None),
    "ofs_k": ("data", None, None),
    "ofs_v": ("data", None, None),
    "stored_k": ("data", None),
    "stored_v": ("data", None),
    "vm": (None, None),
    "ol": (None, None),
    "cum": (None, None),
}


def plane_pspec(name: str) -> P:
    """``shard_map`` in/out PartitionSpec for one pool plane by name."""
    try:
        return P(*_PLANE_RULES[name])
    except KeyError:
        raise KeyError(f"no plane partition rule for {name!r}") from None


def plane_pspecs(planes: dict | None = None) -> dict:
    """PartitionSpec dict matching a ``DevicePoolPlanes.planes`` dict
    (or the full rule set when called without one — the planes dict key
    set is fixed per pool layout, so spec builders that run before any
    pool exists can use the rules directly)."""
    return {k: plane_pspec(k) for k in (_PLANE_RULES if planes is None
                                        else planes)}


# Packed-WEIGHT plane rules (modules.PackedWeight / CompressedLinear leaf
# order).  The weight stream layout is kt-major — stream index
# (kt*nn + j)*TILE_N + c — so a contiguous shard of the stream (last)
# axis is a contiguous K-tile range: sharding sym/ofs/stored over
# "model" K-splits the matmul and ``modules.packed_proj`` reassembles
# the row-parallel partials with a psum.  Dequant scale is per OUTPUT
# column and the matmul is linear in it, so it replicates exactly;
# table planes (v_min/ol/cum) are tiny and replicate.  Weight planes
# never shard over "data": every decode job reads every weight.
PACKED_LEAF_KINDS = ("sym", "ofs", "stored", "v_min", "ol", "cum", "scale")
_PACKED_SPLIT_KINDS = frozenset({"sym", "ofs", "stored"})


def packed_leaf_pspecs(leaves, *, splittable: bool) -> list[P]:
    """PartitionSpecs for one ``CompressedLinear``'s leaves, in flatten
    order (``PACKED_LEAF_KINDS``; a stacked layer axis, if present, just
    adds a leading replicated dim).  ``splittable=False`` (an
    indivisible K-tile count, or no model axis) degrades every leaf to
    replicated — same fall-back policy as ``fit_spec``."""
    specs = []
    for kind, leaf in zip(PACKED_LEAF_KINDS, leaves):
        if splittable and kind in _PACKED_SPLIT_KINDS:
            specs.append(P(*([None] * (leaf.ndim - 1)), "model"))
        else:
            specs.append(P())
    return specs


def plane_shardings(mesh: Mesh, planes: dict) -> dict:
    """NamedSharding dict for placing the pool planes on a serving mesh.

    ``fit_spec`` drops any axis that doesn't divide (e.g. kv-heads on an
    oversized model axis -> replicated heads; the kernel TP path is gated
    on divisibility separately)."""
    return {k: NamedSharding(mesh, fit_spec(plane_pspec(k), v.shape, mesh))
            for k, v in planes.items()}


# ------------------------------------------------------- model-code context
# GSPMD propagation alone loses the batch sharding through scan carries
# (measured: full-global-batch fp32 logits per device).  Model code calls
# ``constrain(x, kind)``, a no-op unless the launcher installed a mesh.
_CTX: dict = {"mesh": None, "seq_shard": False, "moe_ep": False}


def set_mesh_context(mesh: Mesh | None, *, seq_shard: bool = False,
                     moe_ep: bool = False) -> None:
    _CTX["mesh"] = mesh
    _CTX["seq_shard"] = seq_shard
    _CTX["moe_ep"] = moe_ep


class mesh_context:
    def __init__(self, mesh: Mesh, *, seq_shard: bool = False,
                 moe_ep: bool = False):
        self.mesh, self.seq_shard, self.moe_ep = mesh, seq_shard, moe_ep

    def __enter__(self):
        self.prev = dict(_CTX)
        set_mesh_context(self.mesh, seq_shard=self.seq_shard,
                         moe_ep=self.moe_ep)

    def __exit__(self, *exc):
        _CTX.update(self.prev)


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """kind: 'residual' [B,S,D] | 'logits' [B,S,V] | 'batch_only'."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    dp = dp_axes(mesh)
    if kind == "residual":
        spec = P(dp, "model", None) if _CTX["seq_shard"] else P(dp, None, None)
    elif kind == "logits":
        spec = P(dp, None, "model")
    elif kind == "heads":          # [B, S, H, dh] — TP over heads
        spec = P(dp, None, "model", None)
    elif kind == "ffn_hidden":     # [B, S, F] — TP over the hidden dim
        spec = P(dp, None, "model")
    elif kind == "experts":        # [E, C, D] / [E, C, F] — EP over experts
        ax = dp if _CTX.get("moe_ep") else "model"
        spec = P(ax, *([None] * (x.ndim - 1)))
    elif kind == "kv_cache":       # [B, S, Hkv, dh]
        if x.shape[2] % _axes_size(mesh, "model") == 0:
            spec = P(dp, None, "model", None)
        else:                      # kv heads indivisible -> shard sequence
            spec = P(dp, "model", None, None)
    else:
        spec = P(dp, *([None] * (x.ndim - 1)))
    # Intermediates may shard unevenly (GSPMD pads) — crucial for e.g.
    # 24 heads on a 16-way model axis (measured: fit-dropping the head
    # sharding replicated the whole attention computation 16x).  Only the
    # batch dim is fit-checked: padding batch=1 across 32 DP shards would
    # waste, not help.
    if x.shape[0] % _axes_size(mesh, spec[0]) != 0:
        spec = P(None, *spec[1:])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

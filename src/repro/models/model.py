"""Family assembly: embeddings -> scanned block stack -> head.

All families share one forward skeleton; the per-layer ``block_pattern``
cycle selects block kinds (attention global/local, RG-LRU recurrent, mLSTM,
sLSTM).  Layers are stacked and driven by ``lax.scan`` over pattern cycles so
the HLO is O(one cycle) regardless of depth — required for fast 512-device
dry-run compiles and for the roofline's while-body accounting.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import modules as m
from . import sharding as shd
from .config import ModelConfig

F32 = jnp.float32


# ------------------------------------------------------------------- init
def _init_block(cfg: ModelConfig, kind: str, key) -> dict:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if kind in ("global", "local"):
        p["inner"] = m.init_attention(cfg, ks[0])
    elif kind == "recurrent":
        p["inner"] = m.init_recurrent(cfg, ks[0])
    elif kind == "mlstm":
        p["inner"] = m.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["inner"] = m.init_slstm(cfg, ks[0])
    else:
        raise ValueError(kind)
    if kind in ("global", "local", "recurrent"):
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        if cfg.num_experts > 0:
            p["ffn"] = m.init_moe(cfg, ks[1])
        elif cfg.d_ff > 0:
            p["ffn"] = m.init_mlp(cfg, ks[1])
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
        ).astype(dt)
    # unscanned leading layers (kimi's dense-FFN first layer, griffin's
    # leading recurrent pair); prefix blocks always use the dense MLP
    if cfg.prefix_pattern:
        dense_cfg = dataclasses.replace(cfg, num_experts=0)
        params["prefix"] = [
            _init_block(dense_cfg, kind, k)
            for kind, k in zip(cfg.prefix_pattern,
                               jax.random.split(keys[2],
                                                len(cfg.prefix_pattern)))]
    # scanned stack: one stacked tree per position in the cycle
    n = _n_cycles(cfg)
    stacked = []
    for i, kind in enumerate(cfg.cycle):
        ks = jax.random.split(keys[3 + (i % 5)], n)
        stacked.append(jax.vmap(lambda k, kind=kind: _init_block(cfg, kind, k))(ks))
    params["blocks"] = tuple(stacked)
    return params


def _n_cycles(cfg: ModelConfig) -> int:
    return cfg.n_cycles


def exact_param_count(cfg: ModelConfig) -> int:
    """Parameter count from the abstract init tree (no allocation).

    ``cfg.param_count()`` is analytic and exact for attention families but
    approximates xLSTM internals; the roofline uses this exact version."""
    import numpy as np
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


# ------------------------------------------------------------------ block
def _ffn(cfg: ModelConfig, p: dict, h: jax.Array):
    if cfg.num_experts > 0 and "router" in p["ffn"]:
        return m.moe(p["ffn"], h, cfg)
    return m.mlp(p["ffn"], h, cfg), {}


def block_full(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               collect_cache: bool = True):
    """Full-sequence (train / prefill) block.  Returns (h, cache, aux)."""
    aux: dict = {}
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_full(p["inner"], hn, cfg,
                                        local=(kind == "local"))
    elif kind == "recurrent":
        inner, cache = m.recurrent_full(p["inner"], hn, cfg)
    elif kind == "mlstm":
        inner, cache = m.mlstm_full(p["inner"], hn, cfg)
    elif kind == "slstm":
        inner, cache = m.slstm_full(p["inner"], hn, cfg)
    else:
        raise ValueError(kind)
    if not collect_cache:
        cache = ()        # keep the train scan free of stacked cache ys
    if "ffn" in p:
        if cfg.parallel_block:
            f, aux = _ffn(cfg, p, hn)
            h = h + inner + f
        else:
            h = h + inner
            f, aux = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps))
            h = h + f
    else:
        h = h + inner
    return h, cache, aux


def block_step(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               cache, pos):
    """Single-token decode block.  Returns (h, new_cache)."""
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_step(p["inner"], hn, cache, pos, cfg,
                                        local=(kind == "local"))
    elif kind == "recurrent":
        inner, cache = m.recurrent_step(p["inner"], hn, cache, cfg)
    elif kind == "mlstm":
        inner, cache = m.mlstm_step(p["inner"], hn, cache, cfg)
    elif kind == "slstm":
        inner, cache = m.slstm_step(p["inner"], hn, cache, cfg)
    else:
        raise ValueError(kind)
    if "ffn" in p:
        if cfg.parallel_block:
            f, _ = _ffn(cfg, p, hn)
            h = h + inner + f
        else:
            h = h + inner
            f, _ = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps))
            h = h + f
    else:
        h = h + inner
    return h, cache


# ---------------------------------------------------------------- forward
def embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """tokens (+ frontend embeddings) -> [B, S, D] hidden states.

    Modality frontends are stubs per the assignment: ``patch_embeds`` /
    ``frame_embeds`` arrive precomputed."""
    parts = []
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"])
    if cfg.frontend == "audio":
        h = batch["frame_embeds"]
        return h.astype(jnp.bfloat16)
    tok = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        tok = tok * jnp.asarray(cfg.d_model ** 0.5, tok.dtype)  # gemma scale
    parts.append(tok)
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate([p.astype(jnp.bfloat16) for p in parts], axis=1)


def _scan_blocks(cfg: ModelConfig, params: dict, h: jax.Array, *,
                 remat: bool = True, collect_cache: bool = True):
    """Scan the stacked cycle over the sequence hiddens (full mode)."""
    def cycle_fn(carry, p_cycle):
        h, lb, rz = carry
        # barrier: stops XLA from hoisting the body's bf16->f32 convert out
        # of the loop, which would store the stacked per-layer residuals in
        # fp32 (measured 2x memory on the backward stack)
        h = jax.lax.optimization_barrier(h)
        h = shd.constrain(h, "residual")
        caches = []
        for i, kind in enumerate(cfg.cycle):
            h, cache, aux = block_full(cfg, kind, p_cycle[i], h,
                                       collect_cache)
            h = shd.constrain(h, "residual")
            caches.append(cache)
            lb = lb + aux.get("load_balance", 0.0)
            rz = rz + aux.get("router_z", 0.0)
        return (h, lb, rz), tuple(caches)

    fn = jax.checkpoint(cycle_fn,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else cycle_fn
    (h, lb, rz), caches = jax.lax.scan(fn, (h, 0.0, 0.0), params["blocks"])
    return h, caches, {"load_balance": lb, "router_z": rz}


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = True, collect_cache: bool = False,
            last_only: bool = False):
    """Full forward.  Returns (logits, caches, aux).  ``collect_cache``
    is for prefill only — training must not stack per-layer caches.
    ``last_only`` computes the LM head for the final position only
    (prefill: the all-position full-vocab logits would otherwise
    materialize tens of GB per device)."""
    h = shd.constrain(embed_inputs(cfg, params, batch), "residual")
    prefix_caches = []
    for kind, p in zip(cfg.prefix_pattern, params.get("prefix", [])):
        h, cache, _ = block_full(cfg, kind, p, h, collect_cache)
        h = shd.constrain(h, "residual")
        prefix_caches.append(cache)
    h, caches, aux = _scan_blocks(cfg, params, h, remat=remat,
                                  collect_cache=collect_cache)
    if last_only:
        h = h[:, -1:]
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": prefix_caches, "blocks": caches}, aux


def _head(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"].astype(h.dtype))
    else:
        logits = h @ params["unembed"].astype(h.dtype)
    return shd.constrain(logits.astype(F32), "logits")


def loss_fn(cfg: ModelConfig, logits: jax.Array, batch: dict,
            aux: dict | None = None) -> jax.Array:
    """Next-token CE (causal LM) or per-frame CE (encoder), fp32, masked."""
    labels = batch.get("labels")
    if cfg.is_encoder:
        targets, mask = labels, jnp.ones(labels.shape, F32)
    else:
        tok = batch["tokens"]
        targets = tok[:, 1:]
        mask = batch.get("loss_mask", jnp.ones_like(tok, F32))[:, 1:].astype(F32)
        n_img = logits.shape[1] - tok.shape[1]
        if n_img > 0:                       # vlm: image prefix predicts nothing
            logits = logits[:, n_img:]
        logits = logits[:, :-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: keeps the vocab dim
    # sharded (a sharded-dim gather would force a full fp32 logits
    # all-gather — tens of GB/device at 152k-256k vocabs)
    ll = jnp.sum(logits * jax.nn.one_hot(targets, logits.shape[-1],
                                         dtype=logits.dtype), axis=-1)
    nll = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    z_loss = 1e-4 * jnp.sum((lse * mask) ** 2) / jnp.maximum(jnp.sum(mask), 1.0)
    total = nll + z_loss
    if aux:
        total = total + 0.01 * aux.get("load_balance", 0.0) \
            + 0.001 * aux.get("router_z", 0.0)
    return total


# ------------------------------------------------------------------ cache
def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      dtype=jnp.bfloat16):
    if kind in ("global", "local"):
        return m.init_attention_cache(cfg, batch, seq_len,
                                      local=(kind == "local"), dtype=dtype)
    if kind == "recurrent":
        return m.init_recurrent_cache(cfg, batch)
    if kind == "mlstm":
        return m.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return m.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree: per cycle position, leaves stacked [n_cycles,...]."""
    n = _n_cycles(cfg)
    stacked = []
    for kind in cfg.cycle:
        one = _init_block_cache(cfg, kind, batch, seq_len, dtype)
        stacked.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one))
    prefix = [_init_block_cache(cfg, kind, batch, seq_len, dtype)
              for kind in cfg.prefix_pattern]
    return {"prefix": prefix, "blocks": tuple(stacked)}


def decode_step(cfg: ModelConfig, params: dict, caches: dict,
                tokens: jax.Array, pos: jax.Array):
    """One decode step.  tokens: [B, 1] -> (logits [B, 1, V], new caches)."""
    h = params["embed"][tokens].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params.get("prefix", []),
                          caches["prefix"]):
        h, c = block_step(cfg, kind, p, h, c, pos)
        new_prefix.append(c)

    def cycle_fn(h, xs):
        p_cycle, c_cycle = xs
        new_c = []
        for i, kind in enumerate(cfg.cycle):
            h, c = block_step(cfg, kind, p_cycle[i], h, c_cycle[i], pos)
            new_c.append(c)
        return h, tuple(new_c)

    h, new_caches = jax.lax.scan(cycle_fn, h,
                                 (params["blocks"], caches["blocks"]))
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": new_prefix, "blocks": new_caches}


def extend_caches(cfg: ModelConfig, caches: dict, max_len: int) -> dict:
    """Pad prefill caches (global-attention k/v of length S) to decode
    capacity ``max_len``.  Rolling/local and recurrent caches are already
    fixed-size."""
    def pad(kind, cache):
        if kind == "global":
            s = cache["k"].shape[-3]
            if s < max_len:
                def pad_one(name, v):
                    # seq axis: ndim-3 for k/v, ndim-2 for per-head scales
                    ax = v.ndim - (2 if name.endswith("_scale") else 3)
                    widths = [(0, 0)] * v.ndim
                    widths[ax] = (0, max_len - s)
                    return jnp.pad(v, widths)
                return {k: pad_one(k, v) for k, v in cache.items()}
        return cache

    blocks = tuple(pad(kind, c)
                   for kind, c in zip(cfg.cycle, caches["blocks"]))
    prefix = [pad(kind, c)
              for kind, c in zip(cfg.prefix_pattern, caches["prefix"])]
    return {"prefix": prefix, "blocks": blocks}


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            max_len: int | None = None):
    """Process a prompt, returning (last-position logits, decode caches)."""
    logits, caches, _ = forward(cfg, params, batch, remat=False,
                                collect_cache=True, last_only=True)
    if max_len is not None:
        caches = extend_caches(cfg, caches, max_len)
    return logits, caches

"""Family assembly: embeddings -> scanned block stack -> head.

All families share one forward skeleton; the per-layer ``block_pattern``
cycle selects block kinds (attention global/local, RG-LRU recurrent, mLSTM,
sLSTM).  Layers are stacked and driven by ``lax.scan`` over pattern cycles so
the HLO is O(one cycle) regardless of depth — required for fast 512-device
dry-run compiles and for the roofline's while-body accounting.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import modules as m
from . import sharding as shd
from .config import ModelConfig

F32 = jnp.float32


@jax.custom_vjp
def _residual_barrier(h: jax.Array) -> jax.Array:
    """``optimization_barrier`` with a defined gradient (identity).

    ``lax.optimization_barrier`` has no differentiation rule, so the bare
    primitive breaks every ``jax.grad`` trace through the train scan.  The
    custom_vjp hides it from autodiff while keeping the barrier in both the
    forward and backward HLO (the backward residual stack has the same
    bf16->f32 hoisting hazard the forward one does)."""
    return jax.lax.optimization_barrier(h)


def _residual_barrier_fwd(h):
    return jax.lax.optimization_barrier(h), None


def _residual_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


# ------------------------------------------------------------------- init
def _init_block(cfg: ModelConfig, kind: str, key) -> dict:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if kind in ("global", "local"):
        p["inner"] = m.init_attention(cfg, ks[0])
    elif kind == "recurrent":
        p["inner"] = m.init_recurrent(cfg, ks[0])
    elif kind == "mlstm":
        p["inner"] = m.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["inner"] = m.init_slstm(cfg, ks[0])
    else:
        raise ValueError(kind)
    if kind in ("global", "local", "recurrent"):
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        if cfg.num_experts > 0:
            p["ffn"] = m.init_moe(cfg, ks[1])
        elif cfg.d_ff > 0:
            p["ffn"] = m.init_mlp(cfg, ks[1])
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
        ).astype(dt)
    # unscanned leading layers (kimi's dense-FFN first layer, griffin's
    # leading recurrent pair); prefix blocks always use the dense MLP
    if cfg.prefix_pattern:
        dense_cfg = dataclasses.replace(cfg, num_experts=0)
        params["prefix"] = [
            _init_block(dense_cfg, kind, k)
            for kind, k in zip(cfg.prefix_pattern,
                               jax.random.split(keys[2],
                                                len(cfg.prefix_pattern)))]
    # scanned stack: one stacked tree per position in the cycle
    n = _n_cycles(cfg)
    stacked = []
    for i, kind in enumerate(cfg.cycle):
        ks = jax.random.split(keys[3 + (i % 5)], n)
        stacked.append(jax.vmap(lambda k, kind=kind: _init_block(cfg, kind, k))(ks))
    params["blocks"] = tuple(stacked)
    return params


def _n_cycles(cfg: ModelConfig) -> int:
    return cfg.n_cycles


def exact_param_count(cfg: ModelConfig) -> int:
    """Parameter count from the abstract init tree (no allocation).

    ``cfg.param_count()`` is analytic and exact for attention families but
    approximates xLSTM internals; the roofline uses this exact version."""
    import numpy as np
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


# ------------------------------------------------------------------ block
def _ffn(cfg: ModelConfig, p: dict, h: jax.Array):
    if cfg.num_experts > 0 and "router" in p["ffn"]:
        return m.moe(p["ffn"], h, cfg)
    return m.mlp(p["ffn"], h, cfg), {}


def block_full(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               collect_cache: bool = True):
    """Full-sequence (train / prefill) block.  Returns (h, cache, aux)."""
    aux: dict = {}
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_full(p["inner"], hn, cfg,
                                        local=(kind == "local"))
    elif kind == "recurrent":
        inner, cache = m.recurrent_full(p["inner"], hn, cfg)
    elif kind == "mlstm":
        inner, cache = m.mlstm_full(p["inner"], hn, cfg)
    elif kind == "slstm":
        inner, cache = m.slstm_full(p["inner"], hn, cfg)
    else:
        raise ValueError(kind)
    if not collect_cache:
        cache = ()        # keep the train scan free of stacked cache ys
    if "ffn" in p:
        if cfg.parallel_block:
            f, aux = _ffn(cfg, p, hn)
            h = h + inner + f
        else:
            h = h + inner
            f, aux = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps))
            h = h + f
    else:
        h = h + inner
    return h, cache, aux


def block_step(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               cache, pos):
    """Single-token decode block.  Returns (h, new_cache)."""
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_step(p["inner"], hn, cache, pos, cfg,
                                        local=(kind == "local"))
    elif kind == "recurrent":
        inner, cache = m.recurrent_step(p["inner"], hn, cache, cfg)
    elif kind == "mlstm":
        inner, cache = m.mlstm_step(p["inner"], hn, cache, cfg)
    elif kind == "slstm":
        inner, cache = m.slstm_step(p["inner"], hn, cache, cfg)
    else:
        raise ValueError(kind)
    if "ffn" in p:
        if cfg.parallel_block:
            f, _ = _ffn(cfg, p, hn)
            h = h + inner + f
        else:
            h = h + inner
            f, _ = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps))
            h = h + f
    else:
        h = h + inner
    return h, cache


# ---------------------------------------------------------------- forward
def embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """tokens (+ frontend embeddings) -> [B, S, D] hidden states.

    Modality frontends are stubs per the assignment: ``patch_embeds`` /
    ``frame_embeds`` arrive precomputed."""
    parts = []
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"])
    if cfg.frontend == "audio":
        h = batch["frame_embeds"]
        return h.astype(jnp.bfloat16)
    tok = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        tok = tok * jnp.asarray(cfg.d_model ** 0.5, tok.dtype)  # gemma scale
    parts.append(tok)
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate([p.astype(jnp.bfloat16) for p in parts], axis=1)


def _scan_blocks(cfg: ModelConfig, params: dict, h: jax.Array, *,
                 remat: bool = True, collect_cache: bool = True):
    """Scan the stacked cycle over the sequence hiddens (full mode)."""
    def cycle_fn(carry, p_cycle):
        h, lb, rz = carry
        # barrier: stops XLA from hoisting the body's bf16->f32 convert out
        # of the loop, which would store the stacked per-layer residuals in
        # fp32 (measured 2x memory on the backward stack)
        h = _residual_barrier(h)
        h = shd.constrain(h, "residual")
        caches = []
        for i, kind in enumerate(cfg.cycle):
            h, cache, aux = block_full(cfg, kind, p_cycle[i], h,
                                       collect_cache)
            h = shd.constrain(h, "residual")
            caches.append(cache)
            lb = lb + aux.get("load_balance", 0.0)
            rz = rz + aux.get("router_z", 0.0)
        return (h, lb, rz), tuple(caches)

    fn = jax.checkpoint(cycle_fn,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else cycle_fn
    (h, lb, rz), caches = jax.lax.scan(fn, (h, 0.0, 0.0), params["blocks"])
    return h, caches, {"load_balance": lb, "router_z": rz}


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = True, collect_cache: bool = False,
            last_only: bool = False):
    """Full forward.  Returns (logits, caches, aux).  ``collect_cache``
    is for prefill only — training must not stack per-layer caches.
    ``last_only`` computes the LM head for the final position only
    (prefill: the all-position full-vocab logits would otherwise
    materialize tens of GB per device)."""
    h = shd.constrain(embed_inputs(cfg, params, batch), "residual")
    prefix_caches = []
    for kind, p in zip(cfg.prefix_pattern, params.get("prefix", [])):
        h, cache, _ = block_full(cfg, kind, p, h, collect_cache)
        h = shd.constrain(h, "residual")
        prefix_caches.append(cache)
    h, caches, aux = _scan_blocks(cfg, params, h, remat=remat,
                                  collect_cache=collect_cache)
    if last_only:
        h = h[:, -1:]
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": prefix_caches, "blocks": caches}, aux


def _head(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"].astype(h.dtype))
    else:
        logits = h @ params["unembed"].astype(h.dtype)
    return shd.constrain(logits.astype(F32), "logits")


def loss_fn(cfg: ModelConfig, logits: jax.Array, batch: dict,
            aux: dict | None = None) -> jax.Array:
    """Next-token CE (causal LM) or per-frame CE (encoder), fp32, masked."""
    labels = batch.get("labels")
    if cfg.is_encoder:
        targets, mask = labels, jnp.ones(labels.shape, F32)
    else:
        tok = batch["tokens"]
        targets = tok[:, 1:]
        mask = batch.get("loss_mask", jnp.ones_like(tok, F32))[:, 1:].astype(F32)
        n_img = logits.shape[1] - tok.shape[1]
        if n_img > 0:                       # vlm: image prefix predicts nothing
            logits = logits[:, n_img:]
        logits = logits[:, :-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: keeps the vocab dim
    # sharded (a sharded-dim gather would force a full fp32 logits
    # all-gather — tens of GB/device at 152k-256k vocabs)
    ll = jnp.sum(logits * jax.nn.one_hot(targets, logits.shape[-1],
                                         dtype=logits.dtype), axis=-1)
    nll = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    z_loss = 1e-4 * jnp.sum((lse * mask) ** 2) / jnp.maximum(jnp.sum(mask), 1.0)
    total = nll + z_loss
    if aux:
        total = total + 0.01 * aux.get("load_balance", 0.0) \
            + 0.001 * aux.get("router_z", 0.0)
    return total


# ------------------------------------------------------------------ cache
def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      dtype=jnp.bfloat16):
    if kind in ("global", "local"):
        return m.init_attention_cache(cfg, batch, seq_len,
                                      local=(kind == "local"), dtype=dtype)
    if kind == "recurrent":
        return m.init_recurrent_cache(cfg, batch)
    if kind == "mlstm":
        return m.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return m.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree: per cycle position, leaves stacked [n_cycles,...]."""
    n = _n_cycles(cfg)
    stacked = []
    for kind in cfg.cycle:
        one = _init_block_cache(cfg, kind, batch, seq_len, dtype)
        stacked.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one))
    prefix = [_init_block_cache(cfg, kind, batch, seq_len, dtype)
              for kind in cfg.prefix_pattern]
    return {"prefix": prefix, "blocks": tuple(stacked)}


def decode_step(cfg: ModelConfig, params: dict, caches: dict,
                tokens: jax.Array, pos: jax.Array):
    """One decode step.  tokens: [B, 1] -> (logits [B, 1, V], new caches)."""
    h = params["embed"][tokens].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params.get("prefix", []),
                          caches["prefix"]):
        h, c = block_step(cfg, kind, p, h, c, pos)
        new_prefix.append(c)

    def cycle_fn(h, xs):
        p_cycle, c_cycle = xs
        new_c = []
        for i, kind in enumerate(cfg.cycle):
            h, c = block_step(cfg, kind, p_cycle[i], h, c_cycle[i], pos)
            new_c.append(c)
        return h, tuple(new_c)

    h, new_caches = jax.lax.scan(cycle_fn, h,
                                 (params["blocks"], caches["blocks"]))
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": new_prefix, "blocks": new_caches}


def extend_caches(cfg: ModelConfig, caches: dict, max_len: int) -> dict:
    """Pad prefill caches (global-attention k/v of length S) to decode
    capacity ``max_len``.  Rolling/local and recurrent caches are already
    fixed-size."""
    def pad(kind, cache):
        if kind == "global":
            s = cache["k"].shape[-3]
            if s < max_len:
                def pad_one(name, v):
                    # seq axis: ndim-3 for k/v, ndim-2 for per-head scales
                    ax = v.ndim - (2 if name.endswith("_scale") else 3)
                    widths = [(0, 0)] * v.ndim
                    widths[ax] = (0, max_len - s)
                    return jnp.pad(v, widths)
                return {k: pad_one(k, v) for k, v in cache.items()}
        return cache

    blocks = tuple(pad(kind, c)
                   for kind, c in zip(cfg.cycle, caches["blocks"]))
    prefix = [pad(kind, c)
              for kind, c in zip(cfg.prefix_pattern, caches["prefix"])]
    return {"prefix": prefix, "blocks": blocks}


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            max_len: int | None = None):
    """Process a prompt, returning (last-position logits, decode caches)."""
    logits, caches, _ = forward(cfg, params, batch, remat=False,
                                collect_cache=True, last_only=True)
    if max_len is not None:
        caches = extend_caches(cfg, caches, max_len)
    return logits, caches


# ------------------------------------------------------- paged APack KV
ATTN_KINDS = ("global", "local")
STATE_KINDS = ("recurrent", "mlstm", "slstm")


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    """Network-layer kind list: prefix layers first, then the scanned
    stack in layer order ``n_prefix + j * n_cycle + c``."""
    return list(cfg.prefix_pattern) + [
        cfg.cycle[c] for j in range(cfg.n_cycles)
        for c in range(len(cfg.cycle))]


class PagedKVCache:
    """Paged, APack-compressed KV cache for ``kv_cache_dtype="apack-int8"``.

    Supports heterogeneous stacks — any mix of ``global`` / ``local``
    attention and ``recurrent`` / ``mlstm`` / ``slstm`` fixed-state layers,
    scanned or prefix.  Three stream kinds:

    * **global** attention layers: the off-chip store is a
      ``modules.KVPagePool`` shared by every layer; each request owns a
      per-layer list of page ids (the page table).  Token ``t`` of a
      sequence lives at page ``t // page_size`` offset ``t % page_size`` —
      the same absolute layout as the dense cache, so ``materialize`` can
      rebuild the exact int8 cache pytree ``decode_step`` consumes.
    * **local** (rolling-window) attention layers: same page layout, plus
      page-granular eviction — once every token in the oldest page has
      rolled out of the attention window the page returns to the free list
      (``pool.evict``).  A rolling layer therefore holds at most
      ``window_pages`` pages regardless of sequence length, and
      ``materialize`` rebuilds the rolling *ring* layout (slot
      ``pos % ring``) ``attention_step`` expects.
    * **recurrent/mLSTM/sLSTM state** layers: fixed-size f32 states stay
      dense on the hot path (stored per request, stitched into the
      materialized pytree every step) and are APack-compressed losslessly
      with weight-mode tables only at snapshot boundaries
      (``snapshot_state`` / ``restore_state`` — the engine
      checkpoint/preemption path).

    Compression policy (paper §VI activations): each attention layer ×
    {K, V} gets its own activation-mode table, calibrated *online* from
    the histogram of the first ``calib_pages`` sealed pages of that layer
    — the probability slack for empty ranges guarantees any later,
    unprofiled value stays encodable (lossless).  Pages sealed before
    calibration completes stay COLD (uncompressed int8, page-granular
    scales) and are retro-packed the moment the table exists.  Reads of
    PACKED pages go through the Pallas gather-decode kernel
    (``kernels/paged_decode.py``), batched across *all* layers per K/V
    kind via the per-page table-id prefetch vector — compressed words are
    the only thing that crosses the "off-chip" boundary, which is where
    the traffic saving in ``self.traffic`` comes from.
    """

    def __init__(self, cfg: ModelConfig, num_pages: int, *,
                 page_size: int = 16, calib_pages: int = 4,
                 elems_per_stream: int = 128, backend: str | None = None):
        self.cfg = cfg
        self.page_size = page_size
        self.calib_pages = calib_pages
        self.backend = backend
        self.n_prefix = len(cfg.prefix_pattern)
        self.n_cycle = len(cfg.cycle)
        self.n_stack = cfg.n_cycles
        self.layer_kinds = _layer_kinds(cfg)
        self.n_layers = len(self.layer_kinds)
        self.attn_layers = [i for i, k in enumerate(self.layer_kinds)
                            if k in ATTN_KINDS]
        self.local_layers = [i for i, k in enumerate(self.layer_kinds)
                             if k == "local"]
        self.state_layers = [i for i, k in enumerate(self.layer_kinds)
                             if k in STATE_KINDS]
        self.window = cfg.window_size
        self.pool = m.KVPagePool(num_pages, page_size, cfg.num_kv_heads,
                                 cfg.head_dim, elems_per_stream)
        # per (layer, kind=K/V): activation-mode table + calibration state
        self.tables: list[list] = [[None, None] for _ in range(self.n_layers)]
        self.hists = np.zeros((self.n_layers, 2, 256), np.int64)
        self.hist_pages = np.zeros((self.n_layers, 2), np.int32)
        self._cold: list[set[int]] = [set() for _ in range(self.n_layers)]
        self._table_stack = None          # lazy [2*n_layers, ...] np stack
        self._state_templates: dict[str, dict] = {}
        self.page_tables: dict[int, list[list[int]]] = {}
        self.page_base: dict[int, list[int]] = {}   # evicted-page count
        self.states: dict[int, dict[int, dict[str, np.ndarray]]] = {}
        self.seq_len: dict[int, int] = {}
        self.traffic = {"kv_raw_bytes": 0, "kv_read_bytes": 0,
                        "kv_table_bytes": 0, "kv_pages_packed": 0,
                        "kv_raw_bytes_global": 0, "kv_read_bytes_global": 0,
                        "kv_raw_bytes_local": 0, "kv_read_bytes_local": 0,
                        "state_raw_bytes": 0, "state_snapshot_bytes": 0,
                        "state_snapshots": 0}

    # ------------------------------------------------------------ sizing
    def pages_per_seq(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    @property
    def window_pages(self) -> int:
        """Max live pages of a rolling layer: the window can straddle one
        more page boundary than ``ceil(window / page_size)`` covers."""
        return -(-self.window // self.page_size) + 1

    def pages_needed(self, n_tokens: int) -> int:
        """Pool pages a request storing ``n_tokens`` occupies, summed over
        layers with per-kind reservation: global layers hold the full
        sequence, rolling layers at most ``window_pages``, recurrent-kind
        layers none (their state is not paged)."""
        return self.pages_for_config(self.cfg, n_tokens, self.page_size)

    @staticmethod
    def pages_for_config(cfg: ModelConfig, n_tokens: int,
                         page_size: int) -> int:
        """Worst-case per-request page count (shared with the engine's
        pool sizing, so the default pool can be computed pre-construction)."""
        full = -(-n_tokens // page_size)
        rolling = min(full, -(-cfg.window_size // page_size) + 1)
        total = 0
        for kind in _layer_kinds(cfg):
            if kind == "global":
                total += full
            elif kind == "local":
                total += rolling
        return total

    @property
    def free_pages(self) -> int:
        return self.pool.free_count

    def kv_ratio(self) -> float | None:
        """Cumulative compressed-vs-raw KV read traffic (< 1.0 is a win).

        ``None`` before any read has moved a byte: reporting 1.0 there
        would claim break-even for an engine that has not served anything
        (and would hide table overhead already accrued)."""
        raw = self.traffic["kv_raw_bytes"]
        if raw == 0:
            return None
        return (self.traffic["kv_read_bytes"]
                + self.traffic["kv_table_bytes"]) / raw

    def stream_stats(self) -> dict:
        """Per-stream accounting: global KV reads, rolling/local KV reads,
        recurrent-state snapshot bytes.  Stream ratios are payload-only
        (table overhead is global, counted once in ``kv_ratio``)."""
        out = {}
        for kind in ("global", "local"):
            raw = self.traffic[f"kv_raw_bytes_{kind}"]
            read = self.traffic[f"kv_read_bytes_{kind}"]
            out[kind] = {"raw_bytes": raw, "read_bytes": read,
                         "ratio": (read / raw) if raw else None}
        raw = self.traffic["state_raw_bytes"]
        comp = self.traffic["state_snapshot_bytes"]
        out["state"] = {"raw_bytes": raw, "snapshot_bytes": comp,
                        "snapshots": self.traffic["state_snapshots"],
                        "ratio": (comp / raw) if raw else None}
        return out

    # ----------------------------------------------------------- requests
    def add_request(self, rid: int) -> None:
        if rid in self.page_tables:
            raise ValueError(f"duplicate request id {rid}")
        self.page_tables[rid] = [[] for _ in range(self.n_layers)]
        self.page_base[rid] = [0] * self.n_layers
        self.states[rid] = {}
        self.seq_len[rid] = 0

    def release(self, rid: int) -> None:
        for layer, pids in enumerate(self.page_tables.pop(rid)):
            for pid in pids:
                self._cold[layer].discard(pid)
                self.pool.free(pid)
        del self.page_base[rid]
        del self.states[rid]
        del self.seq_len[rid]

    # ------------------------------------------------------------ appends
    def _append_layer_token(self, rid: int, layer: int, kq, vq, ks, vs,
                            t: int) -> None:
        pids = self.page_tables[rid][layer]
        if t % self.page_size == 0:
            if t // self.page_size != self.page_base[rid][layer] + len(pids):
                raise RuntimeError(
                    f"page-table desync for rid={rid} layer={layer}: token "
                    f"{t} vs base={self.page_base[rid][layer]} "
                    f"live={len(pids)}")
            pid = self.pool.alloc()
            if pid is None:
                raise RuntimeError(
                    "page pool exhausted mid-flight (admission must reserve)")
            pids.append(pid)
        pid = pids[-1]
        self.pool.write_token(pid, kq, vq, ks, vs)
        if int(self.pool.fill[pid]) == self.page_size:
            self._seal(layer, pid)

    def append_token(self, rid: int, kq: np.ndarray, vq: np.ndarray,
                     ks: np.ndarray, vs: np.ndarray) -> None:
        """Append one token's KV for every attention layer.  kq/vq:
        [n_layers, H, dh] int8; ks/vs: [n_layers, H] f32 (the model's
        per-token scales).  Rows of recurrent-kind layers are ignored —
        their state is not per-token (see ``append_step_tokens``)."""
        t = self.seq_len[rid]
        for layer in self.attn_layers:
            self._append_layer_token(rid, layer, kq[layer], vq[layer],
                                     ks[layer], vs[layer], t)
        self.seq_len[rid] = t + 1
        self.evict_rolled(rid)

    def evict_rolled(self, rid: int) -> None:
        """Rolling-window eviction: free every local-layer page whose
        tokens have *all* left the attention window.  Page ``p`` holds
        tokens ``[p*ps, (p+1)*ps)``; with the next decode position at
        ``qpos = seq_len`` the attention mask keeps ``kpos > qpos -
        window``, so the page is dead once ``(p+1)*ps - 1 <= qpos -
        window``.  Only the oldest live page can die, and it is always
        sealed (COLD/PACKED) because pages seal the moment they fill."""
        qpos = self.seq_len[rid]
        ps = self.page_size
        for layer in self.local_layers:
            pids = self.page_tables[rid][layer]
            base = self.page_base[rid][layer]
            while pids and (base + 1) * ps - 1 <= qpos - self.window:
                pid = pids.pop(0)
                self._cold[layer].discard(pid)
                self.pool.evict(pid)
                base += 1
            self.page_base[rid][layer] = base

    # --------------------------------------------------- cache plumbing
    def _layer_cache(self, caches: dict, layer: int):
        """(leaf-dict, stack-index) of one network layer in a cache pytree
        — prefix leaves are [B, ...], scanned leaves [n_stack, B, ...]."""
        if layer < self.n_prefix:
            return caches["prefix"][layer], None
        off = layer - self.n_prefix
        return caches["blocks"][off % self.n_cycle], off // self.n_cycle

    def _state_template(self, kind: str) -> dict[str, np.ndarray]:
        """Init-value state leaves (batch dim stripped) for empty slots."""
        if kind not in self._state_templates:
            one = _init_block_cache(self.cfg, kind, 1, 1)
            self._state_templates[kind] = {
                f: np.asarray(jax.device_get(x))[0] for f, x in one.items()}
        return self._state_templates[kind]

    def _ring(self, max_len: int) -> int:
        """Rolling-layer dense-cache width (matches init_attention_cache)."""
        return min(self.window, max_len)

    def append_step_tokens(self, caches: dict, slot_rids: list,
                           positions) -> None:
        """Extract what a decode step wrote for every active slot of a
        dense cache pytree: the token at ``positions[slot]`` (ring slot
        ``pos % ring`` for rolling layers) for attention layers, the whole
        updated fixed-size state for recurrent-kind layers."""
        b = len(slot_rids)
        positions = np.asarray(positions, np.int32)
        barange = jnp.arange(b)
        fetched: dict[int, dict[str, np.ndarray]] = {}
        done_groups = set()
        for layer in range(self.n_layers):
            kind = self.layer_kinds[layer]
            leaf, j = self._layer_cache(caches, layer)
            group = ("p", layer) if j is None else ("c",
                                                    (layer - self.n_prefix)
                                                    % self.n_cycle)
            if group in done_groups:
                continue
            done_groups.add(group)
            if kind in ATTN_KINDS:
                sc = leaf["k"].shape[-3]
                slot_idx = jnp.asarray(
                    positions % sc if kind == "local" else positions)
                vals = {}
                for f in ("k", "v", "k_scale", "v_scale"):
                    x = leaf[f]
                    if j is None:
                        vals[f] = np.asarray(
                            jax.device_get(x[barange, slot_idx]))[None]
                    else:
                        vals[f] = np.asarray(
                            jax.device_get(x[:, barange, slot_idx]))
            else:
                vals = {f: (np.asarray(jax.device_get(x))[None] if j is None
                            else np.asarray(jax.device_get(x)))
                        for f, x in leaf.items()}
            # vals leaves are [n_stack(or 1), B, ...]; distribute to layers
            if j is None:
                fetched[layer] = {f: v[0] for f, v in vals.items()}
            else:
                c = (layer - self.n_prefix) % self.n_cycle
                for jj in range(self.n_stack):
                    fetched[self.n_prefix + jj * self.n_cycle + c] = {
                        f: v[jj] for f, v in vals.items()}
        h, dh = self.pool.kv_heads, self.pool.head_dim
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            kq = np.zeros((self.n_layers, h, dh), np.int8)
            vq = np.zeros((self.n_layers, h, dh), np.int8)
            ks = np.zeros((self.n_layers, h), np.float32)
            vs = np.zeros((self.n_layers, h), np.float32)
            for layer in self.attn_layers:
                kq[layer] = fetched[layer]["k"][slot]
                vq[layer] = fetched[layer]["v"][slot]
                ks[layer] = fetched[layer]["k_scale"][slot]
                vs[layer] = fetched[layer]["v_scale"][slot]
            self.append_token(rid, kq, vq, ks, vs)
            for layer in self.state_layers:
                self.states[rid][layer] = {
                    f: v[slot].copy() for f, v in fetched[layer].items()}

    def ingest_prefill(self, rid: int, caches: dict, s: int) -> None:
        """Chop a (batch-1) prefill cache into pages, token order.

        Global layers ingest every position.  Rolling layers only have
        the last ``min(s, window)`` positions in the prefill cache (the
        model emits the rolling ring, not the full sequence) — exactly
        the live window: fully-dead leading pages are skipped outright
        (``page_base`` starts past them) and in-page positions older than
        the window ingest as zeros (dead by construction, never
        materialized).  Recurrent-kind layers store their final state."""
        ps = self.page_size
        for layer in self.attn_layers:
            kind = self.layer_kinds[layer]
            leaf, j = self._layer_cache(caches, layer)

            def one(f, leaf=leaf, j=j):
                x = leaf[f] if j is None else leaf[f][j]
                return np.asarray(jax.device_get(x))[0]

            k, v = one("k"), one("v")                  # [S or window, H, dh]
            ksc, vsc = one("k_scale"), one("v_scale")
            if kind == "local":
                w = k.shape[0]                         # ring width == window
                start = (max(0, s - w) // ps) * ps
                self.page_base[rid][layer] = start // ps
            else:
                w, start = None, 0
            for t in range(start, s):
                if kind == "local":
                    if t < s - w:
                        kq, vq = np.zeros_like(k[0]), np.zeros_like(v[0])
                        kss, vss = np.zeros_like(ksc[0]), np.zeros_like(vsc[0])
                    else:
                        kq, vq = k[t % w], v[t % w]
                        kss, vss = ksc[t % w], vsc[t % w]
                else:
                    kq, vq, kss, vss = k[t], v[t], ksc[t], vsc[t]
                self._append_layer_token(rid, layer, kq, vq, kss, vss, t)
        for layer in self.state_layers:
            leaf, j = self._layer_cache(caches, layer)
            self.states[rid][layer] = {
                f: np.asarray(jax.device_get(x if j is None else x[j]))[0]
                for f, x in leaf.items()}
        self.seq_len[rid] = s
        self.evict_rolled(rid)

    # ------------------------------------------------- seal/calibrate/pack
    def _seal(self, layer: int, pid: int) -> None:
        """Full HOT page -> COLD: re-quantize to one scale per (page, head)
        — scale amortization — then calibrate or pack."""
        from repro.core import quant, tables as ctables
        from repro.core.tables import TABLE_OVERHEAD_BITS
        pool = self.pool
        q2 = np.zeros((2, self.page_size, pool.kv_heads, pool.head_dim),
                      np.int8)
        scale2 = np.zeros((2, pool.kv_heads), np.float32)
        for kind in (0, 1):
            f = (pool.tok_q[kind, pid].astype(np.float32)
                 * pool.tok_scale[kind, pid][..., None])
            sc = np.maximum(np.abs(f).max(axis=(0, 2)), 1e-8) / 127.0
            q2[kind] = np.clip(np.round(f / sc[None, :, None]),
                               -127, 127).astype(np.int8)
            scale2[kind] = sc
        pool.seal(pid, q2, scale2)
        self._cold[layer].add(pid)
        if self.tables[layer][0] is not None:
            self._pack(layer, pid)
            return
        for kind in (0, 1):
            u = quant.to_unsigned(q2[kind]).reshape(-1)
            self.hists[layer, kind] += np.bincount(u, minlength=256)
            self.hist_pages[layer, kind] += 1
        if int(self.hist_pages[layer, 0]) >= self.calib_pages:
            for kind in (0, 1):
                self.tables[layer][kind] = ctables.find_table(
                    self.hists[layer, kind], bits=8, is_activation=True)
            self._table_stack = None
            self.traffic["kv_table_bytes"] += 2 * TABLE_OVERHEAD_BITS // 8
            for cold_pid in sorted(self._cold[layer]):
                self._pack(layer, cold_pid)

    def _pack(self, layer: int, pid: int) -> None:
        """COLD -> PACKED: APack-encode both kinds with the layer's
        activation tables into the pool's fixed-capacity planes."""
        from repro.core import quant
        from repro.kernels import ref as _codec
        pool = self.pool
        outs = []
        for kind in (0, 1):
            vals = quant.to_unsigned(pool.cold_q[kind, pid]).reshape(
                pool.n_streams, pool.elems_per_stream)
            ta = _codec.TableArrays.from_table(self.tables[layer][kind])
            planes = _codec.encode(jnp.asarray(vals.astype(np.int32)), ta,
                                   pool.elems_per_stream, 8)
            outs.append(tuple(np.asarray(p) for p in planes))
        pool.pack(pid, tuple(np.stack([o[i] for o in outs])
                             for i in range(5)))
        self._cold[layer].discard(pid)
        self.traffic["kv_pages_packed"] += 1

    def _tables_stacked(self):
        """np table arrays stacked ``[2 * n_layers, ...]``, row
        ``2*layer + kind`` — the per-page table-id space of the batched
        gather-decode call.  Rebuilt lazily on calibration (tables are
        immutable once created); uncalibrated rows stay zero and are never
        referenced (PACKED requires a table)."""
        if self._table_stack is None:
            vm = np.zeros((2 * self.n_layers, 17), np.int32)
            ol = np.zeros((2 * self.n_layers, 16), np.int32)
            cm = np.zeros((2 * self.n_layers, 17), np.int32)
            for layer in range(self.n_layers):
                for kind in (0, 1):
                    t = self.tables[layer][kind]
                    if t is not None:
                        a, b, c = t.as_arrays()
                        row = 2 * layer + kind
                        vm[row], ol[row], cm[row] = a, b, c
            self._table_stack = (vm, ol, cm)
        return self._table_stack

    # ------------------------------------------------- state snapshots
    def snapshot_state(self, rid: int) -> dict:
        """Engine checkpoint/preemption path: APack-compress the request's
        fixed-size recurrent/mLSTM/sLSTM states.  Bit-exact lossless — f32
        byte planes through the coder with *weight-mode* tables (the full
        state is profiled at snapshot time, so the §VI activation slack is
        unnecessary; same heuristic choice as ``compress_params`` for
        weights).  Attention KV needs no snapshotting: it already lives
        compressed in the page pool."""
        from repro.core import byteplane
        manifest: list[tuple[int, str, tuple[int, ...]]] = []
        parts: list[np.ndarray] = []
        for layer in self.state_layers:
            st = self.states[rid].get(layer)
            if st is None:
                raise RuntimeError(
                    f"request {rid} has no state for layer {layer} "
                    "(prefill not ingested?)")
            for f in sorted(st):
                arr = np.ascontiguousarray(st[f], np.float32)
                manifest.append((layer, f, arr.shape))
                parts.append(arr.reshape(-1))
        if not parts:
            return {"manifest": [], "planes": None}
        # one stream per snapshot, not one per (field, plane): the 298-byte
        # table overhead amortizes over the whole state, and every byte
        # that will ever be encoded is in the histogram (weight mode)
        flat = np.concatenate(parts)
        planes = byteplane.compress_float(flat, table_mode="weight")
        self.traffic["state_raw_bytes"] += flat.nbytes
        self.traffic["state_snapshot_bytes"] += planes.total_bits // 8
        self.traffic["state_snapshots"] += 1
        return {"manifest": manifest, "planes": planes}

    def restore_state(self, rid: int, snap: dict) -> None:
        """Decompress a ``snapshot_state`` blob back into the request's
        live state store (bit-exact: resumed decode == uninterrupted)."""
        from repro.core import byteplane
        if snap["planes"] is None:
            return
        flat = byteplane.decompress_float(snap["planes"])
        off = 0
        for layer, f, shape in snap["manifest"]:
            n = int(np.prod(shape))
            self.states[rid].setdefault(layer, {})[f] = \
                flat[off:off + n].reshape(shape).copy()
            off += n

    # -------------------------------------------------------- materialize
    def materialize(self, slot_rids: list, max_len: int) -> dict:
        """Rebuild the dense cache pytree for the active batch.

        Attention layers: HOT/COLD pages copy straight from the pool;
        PACKED pages decode in ONE batched Pallas gather-decode call per
        K/V kind (page-index + table-id vectors padded to a jit bucket),
        spanning every layer.  Global layers land at absolute positions,
        rolling layers in the ring slot ``pos % ring`` with dead positions
        skipped.  Recurrent-kind layers stitch the stored per-request
        states (init template for empty slots).  Also accrues the
        per-stream raw-vs-actual read-traffic counters."""
        from repro.core import quant
        from repro.kernels.paged_decode import gather_bucket, gather_decode
        pool = self.pool
        b = len(slot_rids)
        h, dh, ps = pool.kv_heads, pool.head_dim, self.page_size

        def span(kind):
            return max_len if kind == "global" else self._ring(max_len)

        kvq = {layer: np.zeros((2, b, span(self.layer_kinds[layer]), h, dh),
                               np.int8) for layer in self.attn_layers}
        kvs = {layer: np.zeros((2, b, span(self.layer_kinds[layer]), h),
                               np.float32) for layer in self.attn_layers}

        def place(layer, kind01, slot, t0, n_tok, q, sc, qpos):
            """q: [n_tok, H, dh], sc: [n_tok, H] -> dense-cache layout."""
            kind = self.layer_kinds[layer]
            if kind == "global":
                n_tok = min(n_tok, max_len - t0)
                kvq[layer][kind01, slot, t0:t0 + n_tok] = q[:n_tok]
                kvs[layer][kind01, slot, t0:t0 + n_tok] = sc[:n_tok]
            else:
                ring = kvq[layer].shape[2]
                a = np.arange(t0, t0 + n_tok)
                live = a >= qpos - ring
                if live.any():
                    kvq[layer][kind01, slot, a[live] % ring] = q[live]
                    kvs[layer][kind01, slot, a[live] % ring] = sc[live]

        jobs: list[tuple] = []           # (layer, pid, slot, t0, qpos)
        raw = {"global": 0, "local": 0}
        read = {"global": 0, "local": 0}
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            qpos = self.seq_len[rid]
            for layer in self.attn_layers:
                kind = self.layer_kinds[layer]
                base = self.page_base[rid][layer]
                for k_, pid in enumerate(self.page_tables[rid][layer]):
                    t0 = (base + k_) * ps
                    state = pool.state[pid]
                    n_tok = (int(pool.fill[pid]) if state == m.PAGE_HOT
                             else ps)
                    if kind == "local":
                        n_live = int(np.sum(np.arange(t0, t0 + n_tok)
                                            >= qpos - self._ring(max_len)))
                    else:
                        n_live = n_tok
                    raw[kind] += pool.dense_bytes(n_live)
                    read[kind] += pool.page_bytes(pid)
                    if state == m.PAGE_HOT:
                        for kind01 in (0, 1):
                            place(layer, kind01, slot, t0, n_tok,
                                  pool.tok_q[kind01, pid, :n_tok],
                                  pool.tok_scale[kind01, pid, :n_tok], qpos)
                    elif state == m.PAGE_COLD:
                        for kind01 in (0, 1):
                            place(layer, kind01, slot, t0, ps,
                                  pool.cold_q[kind01, pid],
                                  np.broadcast_to(
                                      pool.page_scale[kind01, pid][None],
                                      (ps, h)), qpos)
                    else:
                        jobs.append((layer, pid, slot, t0, qpos))
        if jobs:
            vm, ol, cm = self._tables_stacked()
            idx = np.asarray([pid for _, pid, _, _, _ in jobs], np.int32)
            g = gather_bucket(len(idx))
            pad = (0, g - len(idx))
            idx_p = jnp.asarray(np.pad(idx, pad, mode="edge"))
            for kind01 in (0, 1):
                tid = np.asarray([2 * layer + kind01
                                  for layer, *_ in jobs], np.int32)
                out = gather_decode(
                    jnp.asarray(pool.sym[kind01]),
                    jnp.asarray(pool.ofs[kind01]),
                    jnp.asarray(pool.stored[kind01]), idx_p,
                    jnp.asarray(vm), jnp.asarray(ol), jnp.asarray(cm),
                    n_steps=pool.elems_per_stream, backend=self.backend,
                    table_idx=jnp.asarray(np.pad(tid, pad, mode="edge")))
                vals = np.asarray(out)[:len(jobs)].astype(np.uint8)
                q = quant.from_unsigned(vals).reshape(len(jobs), ps, h, dh)
                for i, (layer, pid, slot, t0, qpos) in enumerate(jobs):
                    place(layer, kind01, slot, t0, ps, q[i],
                          np.broadcast_to(pool.page_scale[kind01, pid][None],
                                          (ps, h)), qpos)
        for kind in ("global", "local"):
            self.traffic[f"kv_raw_bytes_{kind}"] += raw[kind]
            self.traffic[f"kv_read_bytes_{kind}"] += read[kind]
        self.traffic["kv_raw_bytes"] += raw["global"] + raw["local"]
        self.traffic["kv_read_bytes"] += read["global"] + read["local"]

        def attn_leaves(layer):
            return {"k": kvq[layer][0], "v": kvq[layer][1],
                    "k_scale": kvs[layer][0], "v_scale": kvs[layer][1]}

        def state_leaves(layer):
            tmpl = self._state_template(self.layer_kinds[layer])
            out = {}
            for f, t0_ in tmpl.items():
                rows = []
                for rid in slot_rids:
                    st = self.states[rid].get(layer) if rid is not None \
                        else None
                    rows.append(st[f] if st is not None else t0_)
                out[f] = np.stack(rows)
            return out

        prefix = []
        for i in range(self.n_prefix):
            leaves = (attn_leaves(i) if self.layer_kinds[i] in ATTN_KINDS
                      else state_leaves(i))
            prefix.append({f: jnp.asarray(x) for f, x in leaves.items()})
        blocks = []
        for c in range(self.n_cycle):
            layers = [self.n_prefix + j * self.n_cycle + c
                      for j in range(self.n_stack)]
            if self.cfg.cycle[c] in ATTN_KINDS:
                per = [attn_leaves(l) for l in layers]
            else:
                per = [state_leaves(l) for l in layers]
            blocks.append({f: jnp.asarray(np.stack([p[f] for p in per]))
                           for f in per[0]})
        return {"prefix": prefix, "blocks": tuple(blocks)}

"""Family assembly: embeddings -> scanned block stack -> head.

All families share one forward skeleton; the per-layer ``block_pattern``
cycle selects block kinds (attention global/local, RG-LRU recurrent, mLSTM,
sLSTM).  Layers are stacked and driven by ``lax.scan`` over pattern cycles so
the HLO is O(one cycle) regardless of depth — required for fast 512-device
dry-run compiles and for the roofline's while-body accounting.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import modules as m
from . import sharding as shd
from .config import ModelConfig

F32 = jnp.float32


@jax.custom_vjp
def _residual_barrier(h: jax.Array) -> jax.Array:
    """``optimization_barrier`` with a defined gradient (identity).

    ``lax.optimization_barrier`` has no differentiation rule, so the bare
    primitive breaks every ``jax.grad`` trace through the train scan.  The
    custom_vjp hides it from autodiff while keeping the barrier in both the
    forward and backward HLO (the backward residual stack has the same
    bf16->f32 hoisting hazard the forward one does)."""
    return jax.lax.optimization_barrier(h)


def _residual_barrier_fwd(h):
    return jax.lax.optimization_barrier(h), None


def _residual_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


# ------------------------------------------------------------------- init
def _init_block(cfg: ModelConfig, kind: str, key) -> dict:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if kind in ("global", "local"):
        p["inner"] = m.init_attention(cfg, ks[0])
    elif kind == "recurrent":
        p["inner"] = m.init_recurrent(cfg, ks[0])
    elif kind == "mlstm":
        p["inner"] = m.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["inner"] = m.init_slstm(cfg, ks[0])
    else:
        raise ValueError(kind)
    if kind in ("global", "local", "recurrent"):
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        if cfg.num_experts > 0:
            p["ffn"] = m.init_moe(cfg, ks[1])
        elif cfg.d_ff > 0:
            p["ffn"] = m.init_mlp(cfg, ks[1])
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
        ).astype(dt)
    # unscanned leading layers (kimi's dense-FFN first layer, griffin's
    # leading recurrent pair); prefix blocks always use the dense MLP
    if cfg.prefix_pattern:
        dense_cfg = dataclasses.replace(cfg, num_experts=0)
        params["prefix"] = [
            _init_block(dense_cfg, kind, k)
            for kind, k in zip(cfg.prefix_pattern,
                               jax.random.split(keys[2],
                                                len(cfg.prefix_pattern)))]
    # scanned stack: one stacked tree per position in the cycle
    n = _n_cycles(cfg)
    stacked = []
    for i, kind in enumerate(cfg.cycle):
        ks = jax.random.split(keys[3 + (i % 5)], n)
        stacked.append(jax.vmap(lambda k, kind=kind: _init_block(cfg, kind, k))(ks))
    params["blocks"] = tuple(stacked)
    return params


def _n_cycles(cfg: ModelConfig) -> int:
    return cfg.n_cycles


def exact_param_count(cfg: ModelConfig) -> int:
    """Parameter count from the abstract init tree (no allocation).

    ``cfg.param_count()`` is analytic and exact for attention families but
    approximates xLSTM internals; the roofline uses this exact version."""
    import numpy as np
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


# ------------------------------------------------------------------ block
def _ffn(cfg: ModelConfig, p: dict, h: jax.Array):
    if cfg.num_experts > 0 and "router" in p["ffn"]:
        return m.moe(p["ffn"], h, cfg)
    return m.mlp(p["ffn"], h, cfg), {}


def block_full(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               collect_cache: bool = True):
    """Full-sequence (train / prefill) block.  Returns (h, cache, aux)."""
    aux: dict = {}
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_full(p["inner"], hn, cfg,
                                        local=(kind == "local"))
    elif kind == "recurrent":
        inner, cache = m.recurrent_full(p["inner"], hn, cfg)
    elif kind == "mlstm":
        inner, cache = m.mlstm_full(p["inner"], hn, cfg)
    elif kind == "slstm":
        inner, cache = m.slstm_full(p["inner"], hn, cfg)
    else:
        raise ValueError(kind)
    if not collect_cache:
        cache = ()        # keep the train scan free of stacked cache ys
    if "ffn" in p:
        if cfg.parallel_block:
            f, aux = _ffn(cfg, p, hn)
            h = h + inner + f
        else:
            h = h + inner
            f, aux = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps))
            h = h + f
    else:
        h = h + inner
    return h, cache, aux


def block_step(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               cache, pos):
    """Single-token decode block.  Returns (h, new_cache)."""
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_step(p["inner"], hn, cache, pos, cfg,
                                        local=(kind == "local"))
    elif kind == "recurrent":
        inner, cache = m.recurrent_step(p["inner"], hn, cache, cfg)
    elif kind == "mlstm":
        inner, cache = m.mlstm_step(p["inner"], hn, cache, cfg)
    elif kind == "slstm":
        inner, cache = m.slstm_step(p["inner"], hn, cache, cfg)
    else:
        raise ValueError(kind)
    if "ffn" in p:
        if cfg.parallel_block:
            f, _ = _ffn(cfg, p, hn)
            h = h + inner + f
        else:
            h = h + inner
            f, _ = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps))
            h = h + f
    else:
        h = h + inner
    return h, cache


# ---------------------------------------------------------------- forward
def embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """tokens (+ frontend embeddings) -> [B, S, D] hidden states.

    Modality frontends are stubs per the assignment: ``patch_embeds`` /
    ``frame_embeds`` arrive precomputed."""
    parts = []
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"])
    if cfg.frontend == "audio":
        h = batch["frame_embeds"]
        return h.astype(jnp.bfloat16)
    tok = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        tok = tok * jnp.asarray(cfg.d_model ** 0.5, tok.dtype)  # gemma scale
    parts.append(tok)
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate([p.astype(jnp.bfloat16) for p in parts], axis=1)


def _scan_blocks(cfg: ModelConfig, params: dict, h: jax.Array, *,
                 remat: bool = True, collect_cache: bool = True):
    """Scan the stacked cycle over the sequence hiddens (full mode)."""
    def cycle_fn(carry, p_cycle):
        h, lb, rz = carry
        # barrier: stops XLA from hoisting the body's bf16->f32 convert out
        # of the loop, which would store the stacked per-layer residuals in
        # fp32 (measured 2x memory on the backward stack)
        h = _residual_barrier(h)
        h = shd.constrain(h, "residual")
        caches = []
        for i, kind in enumerate(cfg.cycle):
            h, cache, aux = block_full(cfg, kind, p_cycle[i], h,
                                       collect_cache)
            h = shd.constrain(h, "residual")
            caches.append(cache)
            lb = lb + aux.get("load_balance", 0.0)
            rz = rz + aux.get("router_z", 0.0)
        return (h, lb, rz), tuple(caches)

    fn = jax.checkpoint(cycle_fn,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else cycle_fn
    (h, lb, rz), caches = jax.lax.scan(fn, (h, 0.0, 0.0), params["blocks"])
    return h, caches, {"load_balance": lb, "router_z": rz}


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = True, collect_cache: bool = False,
            last_only: bool = False):
    """Full forward.  Returns (logits, caches, aux).  ``collect_cache``
    is for prefill only — training must not stack per-layer caches.
    ``last_only`` computes the LM head for the final position only
    (prefill: the all-position full-vocab logits would otherwise
    materialize tens of GB per device)."""
    h = shd.constrain(embed_inputs(cfg, params, batch), "residual")
    prefix_caches = []
    for kind, p in zip(cfg.prefix_pattern, params.get("prefix", [])):
        h, cache, _ = block_full(cfg, kind, p, h, collect_cache)
        h = shd.constrain(h, "residual")
        prefix_caches.append(cache)
    h, caches, aux = _scan_blocks(cfg, params, h, remat=remat,
                                  collect_cache=collect_cache)
    if last_only:
        h = h[:, -1:]
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": prefix_caches, "blocks": caches}, aux


def _head(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"].astype(h.dtype))
    else:
        logits = h @ params["unembed"].astype(h.dtype)
    return shd.constrain(logits.astype(F32), "logits")


def loss_fn(cfg: ModelConfig, logits: jax.Array, batch: dict,
            aux: dict | None = None) -> jax.Array:
    """Next-token CE (causal LM) or per-frame CE (encoder), fp32, masked."""
    labels = batch.get("labels")
    if cfg.is_encoder:
        targets, mask = labels, jnp.ones(labels.shape, F32)
    else:
        tok = batch["tokens"]
        targets = tok[:, 1:]
        mask = batch.get("loss_mask", jnp.ones_like(tok, F32))[:, 1:].astype(F32)
        n_img = logits.shape[1] - tok.shape[1]
        if n_img > 0:                       # vlm: image prefix predicts nothing
            logits = logits[:, n_img:]
        logits = logits[:, :-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: keeps the vocab dim
    # sharded (a sharded-dim gather would force a full fp32 logits
    # all-gather — tens of GB/device at 152k-256k vocabs)
    ll = jnp.sum(logits * jax.nn.one_hot(targets, logits.shape[-1],
                                         dtype=logits.dtype), axis=-1)
    nll = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    z_loss = 1e-4 * jnp.sum((lse * mask) ** 2) / jnp.maximum(jnp.sum(mask), 1.0)
    total = nll + z_loss
    if aux:
        total = total + 0.01 * aux.get("load_balance", 0.0) \
            + 0.001 * aux.get("router_z", 0.0)
    return total


# ------------------------------------------------------------------ cache
def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      dtype=jnp.bfloat16):
    if kind in ("global", "local"):
        return m.init_attention_cache(cfg, batch, seq_len,
                                      local=(kind == "local"), dtype=dtype)
    if kind == "recurrent":
        return m.init_recurrent_cache(cfg, batch)
    if kind == "mlstm":
        return m.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return m.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree: per cycle position, leaves stacked [n_cycles,...]."""
    n = _n_cycles(cfg)
    stacked = []
    for kind in cfg.cycle:
        one = _init_block_cache(cfg, kind, batch, seq_len, dtype)
        stacked.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one))
    prefix = [_init_block_cache(cfg, kind, batch, seq_len, dtype)
              for kind in cfg.prefix_pattern]
    return {"prefix": prefix, "blocks": tuple(stacked)}


def decode_step(cfg: ModelConfig, params: dict, caches: dict,
                tokens: jax.Array, pos: jax.Array):
    """One decode step.  tokens: [B, 1] -> (logits [B, 1, V], new caches)."""
    h = params["embed"][tokens].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params.get("prefix", []),
                          caches["prefix"]):
        h, c = block_step(cfg, kind, p, h, c, pos)
        new_prefix.append(c)

    def cycle_fn(h, xs):
        p_cycle, c_cycle = xs
        new_c = []
        for i, kind in enumerate(cfg.cycle):
            h, c = block_step(cfg, kind, p_cycle[i], h, c_cycle[i], pos)
            new_c.append(c)
        return h, tuple(new_c)

    h, new_caches = jax.lax.scan(cycle_fn, h,
                                 (params["blocks"], caches["blocks"]))
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": new_prefix, "blocks": new_caches}


def extend_caches(cfg: ModelConfig, caches: dict, max_len: int) -> dict:
    """Pad prefill caches (global-attention k/v of length S) to decode
    capacity ``max_len``.  Rolling/local and recurrent caches are already
    fixed-size."""
    def pad(kind, cache):
        if kind == "global":
            s = cache["k"].shape[-3]
            if s < max_len:
                def pad_one(name, v):
                    # seq axis: ndim-3 for k/v, ndim-2 for per-head scales
                    ax = v.ndim - (2 if name.endswith("_scale") else 3)
                    widths = [(0, 0)] * v.ndim
                    widths[ax] = (0, max_len - s)
                    return jnp.pad(v, widths)
                return {k: pad_one(k, v) for k, v in cache.items()}
        return cache

    blocks = tuple(pad(kind, c)
                   for kind, c in zip(cfg.cycle, caches["blocks"]))
    prefix = [pad(kind, c)
              for kind, c in zip(cfg.prefix_pattern, caches["prefix"])]
    return {"prefix": prefix, "blocks": blocks}


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            max_len: int | None = None):
    """Process a prompt, returning (last-position logits, decode caches)."""
    logits, caches, _ = forward(cfg, params, batch, remat=False,
                                collect_cache=True, last_only=True)
    if max_len is not None:
        caches = extend_caches(cfg, caches, max_len)
    return logits, caches


# ------------------------------------------------------- paged APack KV
class PagedKVCache:
    """Paged, APack-compressed KV cache for ``kv_cache_dtype="apack-int8"``.

    The off-chip store is a ``modules.KVPagePool`` shared by every
    attention layer; each request owns a per-layer list of page ids (the
    page table).  Token ``t`` of a sequence lives at page ``t // page_size``
    offset ``t % page_size`` — the same absolute layout as the dense cache,
    so ``materialize`` can rebuild the exact int8 cache pytree
    ``decode_step`` consumes.

    Compression policy (paper §VI activations): each layer × {K, V} gets
    its own activation-mode table, calibrated *online* from the histogram
    of the first ``calib_pages`` sealed pages of that layer — the
    probability slack for empty ranges guarantees any later, unprofiled
    value stays encodable (lossless).  Pages sealed before calibration
    completes stay COLD (uncompressed int8, page-granular scales) and are
    retro-packed the moment the table exists.  Reads of PACKED pages go
    through the Pallas gather-decode kernel (``kernels/paged_decode.py``)
    — compressed words are the only thing that crosses the "off-chip"
    boundary, which is where the traffic saving in ``self.traffic``
    comes from.
    """

    def __init__(self, cfg: ModelConfig, num_pages: int, *,
                 page_size: int = 16, calib_pages: int = 4,
                 elems_per_stream: int = 128, backend: str | None = None):
        kinds = set(cfg.cycle)
        if kinds != {"global"} or cfg.prefix_pattern:
            raise NotImplementedError(
                "paged apack-int8 KV supports prefix-free all-global-"
                f"attention stacks; {cfg.name} has cycle={sorted(kinds)} "
                f"prefix={cfg.prefix_pattern} (local/rolling and recurrent "
                "states are fixed-size and stay dense; unscanned prefix "
                "layers would need their own page tables)")
        self.cfg = cfg
        self.page_size = page_size
        self.calib_pages = calib_pages
        self.backend = backend
        self.n_cycle = len(cfg.cycle)
        self.n_stack = cfg.n_cycles
        self.n_layers = self.n_cycle * self.n_stack
        self.pool = m.KVPagePool(num_pages, page_size, cfg.num_kv_heads,
                                 cfg.head_dim, elems_per_stream)
        # per (layer, kind=K/V): activation-mode table + calibration state
        self.tables: list[list] = [[None, None] for _ in range(self.n_layers)]
        self.hists = np.zeros((self.n_layers, 2, 256), np.int64)
        self.hist_pages = np.zeros((self.n_layers, 2), np.int32)
        self._cold: list[set[int]] = [set() for _ in range(self.n_layers)]
        self.page_tables: dict[int, list[list[int]]] = {}
        self.seq_len: dict[int, int] = {}
        self.traffic = {"kv_raw_bytes": 0, "kv_read_bytes": 0,
                        "kv_table_bytes": 0, "kv_pages_packed": 0}

    # ------------------------------------------------------------ sizing
    def pages_per_seq(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def pages_needed(self, n_tokens: int) -> int:
        """Pool pages a request storing ``n_tokens`` occupies (all layers)."""
        return self.n_layers * self.pages_per_seq(n_tokens)

    @property
    def free_pages(self) -> int:
        return self.pool.free_count

    def kv_ratio(self) -> float:
        """Cumulative compressed-vs-raw KV read traffic (< 1.0 is a win)."""
        raw = self.traffic["kv_raw_bytes"]
        read = self.traffic["kv_read_bytes"] + self.traffic["kv_table_bytes"]
        return read / raw if raw else 1.0

    # ----------------------------------------------------------- requests
    def add_request(self, rid: int) -> None:
        assert rid not in self.page_tables
        self.page_tables[rid] = [[] for _ in range(self.n_layers)]
        self.seq_len[rid] = 0

    def release(self, rid: int) -> None:
        for layer, pids in enumerate(self.page_tables.pop(rid)):
            for pid in pids:
                self._cold[layer].discard(pid)
                self.pool.free(pid)
        del self.seq_len[rid]

    def append_token(self, rid: int, kq: np.ndarray, vq: np.ndarray,
                     ks: np.ndarray, vs: np.ndarray) -> None:
        """Append one token's KV for every layer.  kq/vq: [n_layers, H, dh]
        int8; ks/vs: [n_layers, H] f32 (the model's per-token scales)."""
        t = self.seq_len[rid]
        new_page = t % self.page_size == 0
        for layer in range(self.n_layers):
            pids = self.page_tables[rid][layer]
            if new_page:
                pid = self.pool.alloc()
                assert pid is not None, \
                    "page pool exhausted mid-flight (admission must reserve)"
                pids.append(pid)
            pid = pids[-1]
            self.pool.write_token(pid, kq[layer], vq[layer],
                                  ks[layer], vs[layer])
            if int(self.pool.fill[pid]) == self.page_size:
                self._seal(layer, pid)
        self.seq_len[rid] = t + 1

    def _unstack(self, caches: dict, positions=None) -> dict[str, np.ndarray]:
        """Fetch a dense int8 cache's leaves into network-layer order:
        field -> [n_layers, B, (S,) ...] with layer = j*n_cycle + c.  This
        is the single home of the stacked-cycle cache layout.  With
        ``positions`` ([B] ints) the sequence axis is sliced to each
        slot's position *on device* before the host fetch — one token per
        slot instead of the whole [B, S] cache."""
        out = {}
        for f in ("k", "v", "k_scale", "v_scale"):
            per_c = []
            for c in range(self.n_cycle):
                leaf = caches["blocks"][c][f]
                if positions is not None:
                    b = leaf.shape[1]
                    leaf = leaf[:, jnp.arange(b),
                                jnp.asarray(np.asarray(positions, np.int32))]
                per_c.append(np.asarray(jax.device_get(leaf)))
            out[f] = np.stack([per_c[c][j]
                               for j in range(self.n_stack)
                               for c in range(self.n_cycle)])
        return out

    def append_step_tokens(self, caches: dict, slot_rids: list,
                           positions) -> None:
        """Extract the token a decode step wrote at ``positions[slot]`` for
        every active slot of a dense cache pytree and append it to the
        paged store (the dense view is then discarded)."""
        arrs = self._unstack(caches, positions=positions)
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            self.append_token(rid, arrs["k"][:, slot], arrs["v"][:, slot],
                              arrs["k_scale"][:, slot],
                              arrs["v_scale"][:, slot])

    def ingest_prefill(self, rid: int, caches: dict, s: int) -> None:
        """Chop a (batch-1) prefill cache into pages, token order."""
        arrs = self._unstack(caches)
        for t in range(s):
            self.append_token(rid, arrs["k"][:, 0, t], arrs["v"][:, 0, t],
                              arrs["k_scale"][:, 0, t],
                              arrs["v_scale"][:, 0, t])

    # ------------------------------------------------- seal/calibrate/pack
    def _seal(self, layer: int, pid: int) -> None:
        """Full HOT page -> COLD: re-quantize to one scale per (page, head)
        — scale amortization — then calibrate or pack."""
        from repro.core import quant, tables as ctables
        from repro.core.tables import TABLE_OVERHEAD_BITS
        pool = self.pool
        q2 = np.zeros((2, self.page_size, pool.kv_heads, pool.head_dim),
                      np.int8)
        scale2 = np.zeros((2, pool.kv_heads), np.float32)
        for kind in (0, 1):
            f = (pool.tok_q[kind, pid].astype(np.float32)
                 * pool.tok_scale[kind, pid][..., None])
            sc = np.maximum(np.abs(f).max(axis=(0, 2)), 1e-8) / 127.0
            q2[kind] = np.clip(np.round(f / sc[None, :, None]),
                               -127, 127).astype(np.int8)
            scale2[kind] = sc
        pool.seal(pid, q2, scale2)
        self._cold[layer].add(pid)
        if self.tables[layer][0] is not None:
            self._pack(layer, pid)
            return
        for kind in (0, 1):
            u = quant.to_unsigned(q2[kind]).reshape(-1)
            self.hists[layer, kind] += np.bincount(u, minlength=256)
            self.hist_pages[layer, kind] += 1
        if int(self.hist_pages[layer, 0]) >= self.calib_pages:
            for kind in (0, 1):
                self.tables[layer][kind] = ctables.find_table(
                    self.hists[layer, kind], bits=8, is_activation=True)
            self.traffic["kv_table_bytes"] += 2 * TABLE_OVERHEAD_BITS // 8
            for cold_pid in sorted(self._cold[layer]):
                self._pack(layer, cold_pid)

    def _pack(self, layer: int, pid: int) -> None:
        """COLD -> PACKED: APack-encode both kinds with the layer's
        activation tables into the pool's fixed-capacity planes."""
        from repro.core import quant
        from repro.kernels import ref as _codec
        pool = self.pool
        outs = []
        for kind in (0, 1):
            vals = quant.to_unsigned(pool.cold_q[kind, pid]).reshape(
                pool.n_streams, pool.elems_per_stream)
            ta = _codec.TableArrays.from_table(self.tables[layer][kind])
            planes = _codec.encode(jnp.asarray(vals.astype(np.int32)), ta,
                                   pool.elems_per_stream, 8)
            outs.append(tuple(np.asarray(p) for p in planes))
        pool.pack(pid, tuple(np.stack([o[i] for o in outs])
                             for i in range(5)))
        self._cold[layer].discard(pid)
        self.traffic["kv_pages_packed"] += 1

    # -------------------------------------------------------- materialize
    def materialize(self, slot_rids: list, max_len: int) -> dict:
        """Rebuild the dense int8 cache pytree for the active batch.

        HOT/COLD pages copy straight from the pool; PACKED pages are
        decoded in batched per-(layer, kind) Pallas gather-decode calls
        (page-index vectors padded to a jit bucket).  Also accrues the
        raw-vs-actual read-traffic counters."""
        from repro.core import quant
        from repro.kernels.paged_decode import gather_bucket, gather_decode
        pool = self.pool
        b = len(slot_rids)
        h, dh, ps = pool.kv_heads, pool.head_dim, self.page_size
        kvq = np.zeros((2, self.n_cycle, self.n_stack, b, max_len, h, dh),
                       np.int8)
        kvs = np.zeros((2, self.n_cycle, self.n_stack, b, max_len, h),
                       np.float32)
        jobs: dict[int, list] = {}
        raw = read = 0
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            for layer, pids in enumerate(self.page_tables[rid]):
                c, j = layer % self.n_cycle, layer // self.n_cycle
                for pno, pid in enumerate(pids):
                    t0 = pno * ps
                    state = pool.state[pid]
                    n_tok = (int(pool.fill[pid]) if state == m.PAGE_HOT
                             else ps)
                    raw += pool.dense_bytes(n_tok)
                    read += pool.page_bytes(pid)
                    if state == m.PAGE_HOT:
                        kvq[:, c, j, slot, t0:t0 + n_tok] = \
                            pool.tok_q[:, pid, :n_tok]
                        kvs[:, c, j, slot, t0:t0 + n_tok] = \
                            pool.tok_scale[:, pid, :n_tok]
                    elif state == m.PAGE_COLD:
                        kvq[:, c, j, slot, t0:t0 + ps] = pool.cold_q[:, pid]
                        kvs[:, c, j, slot, t0:t0 + ps] = \
                            pool.page_scale[:, pid][:, None, :]
                    else:
                        jobs.setdefault(layer, []).append((pid, slot, t0))
        if jobs:
            # one pool upload per step, shared by every (layer, kind) call
            # (device-resident planes are a ROADMAP item)
            sym_dev = [jnp.asarray(pool.sym[kind]) for kind in (0, 1)]
            ofs_dev = [jnp.asarray(pool.ofs[kind]) for kind in (0, 1)]
            st_dev = [jnp.asarray(pool.stored[kind]) for kind in (0, 1)]
        for layer, items in jobs.items():
            c, j = layer % self.n_cycle, layer // self.n_cycle
            idx = np.asarray([pid for pid, _, _ in items], np.int32)
            g = gather_bucket(len(idx))
            idx_p = np.pad(idx, (0, g - len(idx)), mode="edge")
            for kind in (0, 1):
                v_min, ol, cum = self.tables[layer][kind].as_arrays()
                out = gather_decode(
                    sym_dev[kind], ofs_dev[kind], st_dev[kind],
                    jnp.asarray(idx_p),
                    jnp.asarray(v_min), jnp.asarray(ol), jnp.asarray(cum),
                    n_steps=pool.elems_per_stream, backend=self.backend)
                vals = np.asarray(out)[:len(items)].astype(np.uint8)
                q = quant.from_unsigned(vals).reshape(len(items), ps, h, dh)
                for i, (pid, slot, t0) in enumerate(items):
                    kvq[kind, c, j, slot, t0:t0 + ps] = q[i]
                    kvs[kind, c, j, slot, t0:t0 + ps] = \
                        pool.page_scale[kind, pid][None, :]
        self.traffic["kv_raw_bytes"] += raw
        self.traffic["kv_read_bytes"] += read
        blocks = tuple(
            {"k": jnp.asarray(kvq[0, c]), "v": jnp.asarray(kvq[1, c]),
             "k_scale": jnp.asarray(kvs[0, c]),
             "v_scale": jnp.asarray(kvs[1, c])}
            for c in range(self.n_cycle))
        return {"prefix": [], "blocks": blocks}

"""Family assembly: embeddings -> scanned block stack -> head.

All families share one forward skeleton; the per-layer ``block_pattern``
cycle selects block kinds (attention global/local, RG-LRU recurrent, mLSTM,
sLSTM).  Layers are stacked and driven by ``lax.scan`` over pattern cycles so
the HLO is O(one cycle) regardless of depth — required for fast 512-device
dry-run compiles and for the roofline's while-body accounting.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_decode import table_row

from . import modules as m
from . import sharding as shd
from .config import ModelConfig

F32 = jnp.float32


@jax.custom_vjp
def _residual_barrier(h: jax.Array) -> jax.Array:
    """``optimization_barrier`` with a defined gradient (identity).

    ``lax.optimization_barrier`` has no differentiation rule, so the bare
    primitive breaks every ``jax.grad`` trace through the train scan.  The
    custom_vjp hides it from autodiff while keeping the barrier in both the
    forward and backward HLO (the backward residual stack has the same
    bf16->f32 hoisting hazard the forward one does)."""
    return jax.lax.optimization_barrier(h)


def _residual_barrier_fwd(h):
    return jax.lax.optimization_barrier(h), None


def _residual_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_residual_barrier.defvjp(_residual_barrier_fwd, _residual_barrier_bwd)


# ------------------------------------------------------------------- init
def _init_block(cfg: ModelConfig, kind: str, key) -> dict:
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p: dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if kind in ("global", "local"):
        p["inner"] = m.init_attention(cfg, ks[0])
    elif kind == "recurrent":
        p["inner"] = m.init_recurrent(cfg, ks[0])
    elif kind == "mlstm":
        p["inner"] = m.init_mlstm(cfg, ks[0])
    elif kind == "slstm":
        p["inner"] = m.init_slstm(cfg, ks[0])
    else:
        raise ValueError(kind)
    if kind in ("global", "local", "recurrent"):
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        if cfg.num_experts > 0:
            p["ffn"] = m.init_moe(cfg, ks[1])
        elif cfg.d_ff > 0:
            p["ffn"] = m.init_mlp(cfg, ks[1])
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size)) * cfg.d_model ** -0.5
        ).astype(dt)
    # unscanned leading layers (kimi's dense-FFN first layer, griffin's
    # leading recurrent pair); prefix blocks always use the dense MLP
    if cfg.prefix_pattern:
        dense_cfg = dataclasses.replace(cfg, num_experts=0)
        params["prefix"] = [
            _init_block(dense_cfg, kind, k)
            for kind, k in zip(cfg.prefix_pattern,
                               jax.random.split(keys[2],
                                                len(cfg.prefix_pattern)))]
    # scanned stack: one stacked tree per position in the cycle
    n = _n_cycles(cfg)
    stacked = []
    for i, kind in enumerate(cfg.cycle):
        ks = jax.random.split(keys[3 + (i % 5)], n)
        stacked.append(jax.vmap(lambda k, kind=kind: _init_block(cfg, kind, k))(ks))
    params["blocks"] = tuple(stacked)
    return params


def _n_cycles(cfg: ModelConfig) -> int:
    return cfg.n_cycles


def exact_param_count(cfg: ModelConfig) -> int:
    """Parameter count from the abstract init tree (no allocation).

    ``cfg.param_count()`` is analytic and exact for attention families but
    approximates xLSTM internals; the roofline uses this exact version."""
    import numpy as np
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


# ------------------------------------------------------------------ block
def _ffn(cfg: ModelConfig, p: dict, h: jax.Array,
         tp: tuple[str, int] | None = None):
    if cfg.num_experts > 0 and "router" in p["ffn"]:
        return m.moe(p["ffn"], h, cfg)
    return m.mlp(p["ffn"], h, cfg, tp=tp), {}


def block_full(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               collect_cache: bool = True, *, pad_mask=None, true_len=None):
    """Full-sequence (train / prefill) block.  Returns (h, cache, aux).

    ``pad_mask``/``true_len`` (both set, or neither): the bucketed-prefill
    path — the sequence is end-padded to a jit bucket and every stateful
    construction (local rolling ring, recurrent/mLSTM/sLSTM carried
    state) must ignore positions past ``true_len``.  Attention math needs
    no masking beyond causality (pad keys sit *after* every real query)."""
    aux: dict = {}
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_full(p["inner"], hn, cfg,
                                        local=(kind == "local"),
                                        true_len=true_len)
    elif kind == "recurrent":
        inner, cache = m.recurrent_full(p["inner"], hn, cfg,
                                        pad_mask=pad_mask,
                                        true_len=true_len)
    elif kind == "mlstm":
        inner, cache = m.mlstm_full(p["inner"], hn, cfg, pad_mask=pad_mask)
    elif kind == "slstm":
        inner, cache = m.slstm_full(p["inner"], hn, cfg, pad_mask=pad_mask)
    else:
        raise ValueError(kind)
    if not collect_cache:
        cache = ()        # keep the train scan free of stacked cache ys
    if "ffn" in p:
        if cfg.parallel_block:
            f, aux = _ffn(cfg, p, hn)
            h = h + inner + f
        else:
            h = h + inner
            f, aux = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps))
            h = h + f
    else:
        h = h + inner
    return h, cache, aux


def _join_block(cfg: ModelConfig, p: dict, h: jax.Array, hn: jax.Array,
                inner: jax.Array,
                tp: tuple[str, int] | None = None) -> jax.Array:
    """Residual + FFN tail shared by the dense and paged decode blocks."""
    if "ffn" in p:
        if cfg.parallel_block:
            f, _ = _ffn(cfg, p, hn, tp=tp)
            return h + inner + f
        h = h + inner
        f, _ = _ffn(cfg, p, m.rms_norm(h, p["norm2"], cfg.norm_eps), tp=tp)
        return h + f
    return h + inner


def block_step(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
               cache, pos):
    """Single-token decode block.  Returns (h, new_cache)."""
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    if kind in ("global", "local"):
        inner, cache = m.attention_step(p["inner"], hn, cache, pos, cfg,
                                        local=(kind == "local"))
    elif kind == "recurrent":
        inner, cache = m.recurrent_step(p["inner"], hn, cache, cfg)
    elif kind == "mlstm":
        inner, cache = m.mlstm_step(p["inner"], hn, cache, cfg)
    elif kind == "slstm":
        inner, cache = m.slstm_step(p["inner"], hn, cache, cfg)
    else:
        raise ValueError(kind)
    return _join_block(cfg, p, h, hn, inner), cache


def block_step_paged(cfg: ModelConfig, kind: str, p: dict, h: jax.Array,
                     planes: dict, meta, cache, pos,
                     backend: str | None = None,
                     tp: tuple[str, int] | None = None):
    """Decode block against the device-resident paged KV store.

    Attention kinds read pages through the fused gather-decode kernel and
    return the new token's quantized K/V (for the on-device append);
    recurrent-kind blocks are unchanged — their fixed-size state rides in
    ``cache`` (the device state store) exactly like the dense path.
    ``tp=(axis_name, size)`` runs the fused kernel tensor-parallel over
    kv-head blocks inside a ``shard_map`` body (see
    ``modules.paged_attention_step``)."""
    if kind not in ATTN_KINDS:
        return block_step(cfg, kind, p, h, cache, pos)
    hn = m.rms_norm(h, p["norm1"], cfg.norm_eps)
    inner, new_kv = m.paged_attention_step(p["inner"], hn, planes, meta,
                                           pos, cfg, backend=backend, tp=tp)
    return _join_block(cfg, p, h, hn, inner, tp=tp), new_kv


# ---------------------------------------------------------------- forward
def embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    """tokens (+ frontend embeddings) -> [B, S, D] hidden states.

    Modality frontends are stubs per the assignment: ``patch_embeds`` /
    ``frame_embeds`` arrive precomputed."""
    parts = []
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        parts.append(batch["patch_embeds"])
    if cfg.frontend == "audio":
        h = batch["frame_embeds"]
        return h.astype(jnp.bfloat16)
    tok = params["embed"][batch["tokens"]].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        tok = tok * jnp.asarray(cfg.d_model ** 0.5, tok.dtype)  # gemma scale
    parts.append(tok)
    if len(parts) == 1:
        return parts[0]
    return jnp.concatenate([p.astype(jnp.bfloat16) for p in parts], axis=1)


def _scan_blocks(cfg: ModelConfig, params: dict, h: jax.Array, *,
                 remat: bool = True, collect_cache: bool = True,
                 pad_mask=None, true_len=None):
    """Scan the stacked cycle over the sequence hiddens (full mode)."""
    def cycle_fn(carry, p_cycle):
        h, lb, rz = carry
        # barrier: stops XLA from hoisting the body's bf16->f32 convert out
        # of the loop, which would store the stacked per-layer residuals in
        # fp32 (measured 2x memory on the backward stack)
        h = _residual_barrier(h)
        h = shd.constrain(h, "residual")
        caches = []
        for i, kind in enumerate(cfg.cycle):
            h, cache, aux = block_full(cfg, kind, p_cycle[i], h,
                                       collect_cache, pad_mask=pad_mask,
                                       true_len=true_len)
            h = shd.constrain(h, "residual")
            caches.append(cache)
            lb = lb + aux.get("load_balance", 0.0)
            rz = rz + aux.get("router_z", 0.0)
        return (h, lb, rz), tuple(caches)

    fn = jax.checkpoint(cycle_fn,
                        policy=jax.checkpoint_policies.nothing_saveable) \
        if remat else cycle_fn
    (h, lb, rz), caches = jax.lax.scan(fn, (h, 0.0, 0.0), params["blocks"])
    return h, caches, {"load_balance": lb, "router_z": rz}


def forward(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: bool = True, collect_cache: bool = False,
            last_only: bool = False, true_len=None):
    """Full forward.  Returns (logits, caches, aux).  ``collect_cache``
    is for prefill only — training must not stack per-layer caches.
    ``last_only`` computes the LM head for the final position only
    (prefill: the all-position full-vocab logits would otherwise
    materialize tens of GB per device).

    ``true_len`` (traced i32 scalar, bucketed prefill): tokens are
    end-padded to a power-of-two jit bucket so varied-length traffic
    reuses compiles; only the first ``true_len`` positions are real.
    Stateful layers freeze past the true end (see ``block_full``) and
    ``last_only`` slices the logits at ``true_len - 1`` — the masked
    last-token logits — instead of the padded sequence end."""
    h = shd.constrain(embed_inputs(cfg, params, batch), "residual")
    pad_mask = None
    if true_len is not None:
        true_len = jnp.asarray(true_len, jnp.int32)
        pad_mask = jnp.arange(h.shape[1]) >= true_len      # [S] bool
    prefix_caches = []
    for kind, p in zip(cfg.prefix_pattern, params.get("prefix", [])):
        h, cache, _ = block_full(cfg, kind, p, h, collect_cache,
                                 pad_mask=pad_mask, true_len=true_len)
        h = shd.constrain(h, "residual")
        prefix_caches.append(cache)
    h, caches, aux = _scan_blocks(cfg, params, h, remat=remat,
                                  collect_cache=collect_cache,
                                  pad_mask=pad_mask, true_len=true_len)
    if last_only:
        h = (h[:, -1:] if true_len is None else
             jax.lax.dynamic_slice_in_dim(h, true_len - 1, 1, axis=1))
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": prefix_caches, "blocks": caches}, aux


def _head(cfg: ModelConfig, params: dict, h: jax.Array,
          tp: tuple[str, int] | None = None) -> jax.Array:
    if cfg.tie_embeddings:
        # tied embeddings stay dense (the same tensor serves the token
        # lookup in ``embed_inputs``), so the head einsum is always dense
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"].astype(h.dtype))
    else:
        logits = m.proj(h, params["unembed"], "bsd,dv->bsv", tp=tp)
    return shd.constrain(logits.astype(F32), "logits")


def loss_fn(cfg: ModelConfig, logits: jax.Array, batch: dict,
            aux: dict | None = None) -> jax.Array:
    """Next-token CE (causal LM) or per-frame CE (encoder), fp32, masked."""
    labels = batch.get("labels")
    if cfg.is_encoder:
        targets, mask = labels, jnp.ones(labels.shape, F32)
    else:
        tok = batch["tokens"]
        targets = tok[:, 1:]
        mask = batch.get("loss_mask", jnp.ones_like(tok, F32))[:, 1:].astype(F32)
        n_img = logits.shape[1] - tok.shape[1]
        if n_img > 0:                       # vlm: image prefix predicts nothing
            logits = logits[:, n_img:]
        logits = logits[:, :-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction instead of take_along_axis: keeps the vocab dim
    # sharded (a sharded-dim gather would force a full fp32 logits
    # all-gather — tens of GB/device at 152k-256k vocabs)
    ll = jnp.sum(logits * jax.nn.one_hot(targets, logits.shape[-1],
                                         dtype=logits.dtype), axis=-1)
    nll = jnp.sum((lse - ll) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    z_loss = 1e-4 * jnp.sum((lse * mask) ** 2) / jnp.maximum(jnp.sum(mask), 1.0)
    total = nll + z_loss
    if aux:
        total = total + 0.01 * aux.get("load_balance", 0.0) \
            + 0.001 * aux.get("router_z", 0.0)
    return total


# ------------------------------------------------------------------ cache
def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                      dtype=jnp.bfloat16):
    if kind in ("global", "local"):
        return m.init_attention_cache(cfg, batch, seq_len,
                                      local=(kind == "local"), dtype=dtype)
    if kind == "recurrent":
        return m.init_recurrent_cache(cfg, batch)
    if kind == "mlstm":
        return m.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return m.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode cache pytree: per cycle position, leaves stacked [n_cycles,...]."""
    n = _n_cycles(cfg)
    stacked = []
    for kind in cfg.cycle:
        one = _init_block_cache(cfg, kind, batch, seq_len, dtype)
        stacked.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one))
    prefix = [_init_block_cache(cfg, kind, batch, seq_len, dtype)
              for kind in cfg.prefix_pattern]
    return {"prefix": prefix, "blocks": tuple(stacked)}


def decode_step(cfg: ModelConfig, params: dict, caches: dict,
                tokens: jax.Array, pos: jax.Array):
    """One decode step.  tokens: [B, 1] -> (logits [B, 1, V], new caches)."""
    h = params["embed"][tokens].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    new_prefix = []
    for kind, p, c in zip(cfg.prefix_pattern, params.get("prefix", []),
                          caches["prefix"]):
        h, c = block_step(cfg, kind, p, h, c, pos)
        new_prefix.append(c)

    def cycle_fn(h, xs):
        p_cycle, c_cycle = xs
        new_c = []
        for i, kind in enumerate(cfg.cycle):
            h, c = block_step(cfg, kind, p_cycle[i], h, c_cycle[i], pos)
            new_c.append(c)
        return h, tuple(new_c)

    h, new_caches = jax.lax.scan(cycle_fn, h,
                                 (params["blocks"], caches["blocks"]))
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h)
    return logits, {"prefix": new_prefix, "blocks": new_caches}


# apack: hot-path-root(traced)
def decode_step_paged(cfg: ModelConfig, params: dict, planes: dict,
                      states: dict, meta: dict, tokens: jax.Array,
                      pos: jax.Array, backend: str | None = None,
                      tp: tuple[str, int] | None = None):
    """One decode step with the KV cache *device-resident in page form*.

    The dense-cache pytree of ``decode_step`` is replaced by:

    * ``planes`` — the ``DevicePoolPlanes`` dict (pool payload + stacked
      activation tables), shared by every attention layer;
    * ``states`` — the device state store (``init_state_store``): dense
      fixed-size recurrent/mLSTM/sLSTM states, ``{}`` at attention
      positions;
    * ``meta``  — per-step page-table metadata (``PagedKVCache.step_meta``):
      tiny i32 arrays, the only per-step host->device upload.

    Attention layers read pages through the fused gather-decode+attention
    kernel and *return* the new token's quantized K/V instead of writing a
    dense cache; the engine scatters those into the pool planes on-device
    (``device_append``).  Returns (logits, new_cache) where new_cache
    holds kv dicts at attention positions and updated states elsewhere.
    """
    h = params["embed"][tokens].astype(jnp.bfloat16)
    if cfg.frontend == "vision":
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    new_prefix = []
    for kind, p, mt, st in zip(cfg.prefix_pattern, params.get("prefix", []),
                               meta["prefix"], states["prefix"]):
        h, new = block_step_paged(cfg, kind, p, h, planes, mt, st, pos,
                                  backend, tp)
        new_prefix.append(new)

    def cycle_fn(h, xs):
        p_cycle, m_cycle, s_cycle = xs
        news = []
        for i, kind in enumerate(cfg.cycle):
            h, new = block_step_paged(cfg, kind, p_cycle[i], h, planes,
                                      m_cycle[i], s_cycle[i], pos, backend,
                                      tp)
            news.append(new)
        return h, tuple(news)

    h, new_blocks = jax.lax.scan(
        cycle_fn, h, (params["blocks"], meta["blocks"], states["blocks"]))
    h = m.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, h, tp=tp)
    return logits, {"prefix": new_prefix, "blocks": new_blocks}


def init_state_store(cfg: ModelConfig, batch: int) -> dict:
    """Device-resident store for recurrent-kind layer states (the paged
    decode path keeps them on device between steps — no per-step
    ``device_get``/re-upload).  Attention positions hold ``{}``: their
    state lives in the page pool."""
    n = cfg.n_cycles
    prefix = [({} if kind in ATTN_KINDS
               else _init_block_cache(cfg, kind, batch, 1))
              for kind in cfg.prefix_pattern]
    blocks = []
    for kind in cfg.cycle:
        if kind in ATTN_KINDS:
            blocks.append({})
        else:
            one = _init_block_cache(cfg, kind, batch, 1)
            blocks.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n, *x.shape)), one))
    return {"prefix": prefix, "blocks": tuple(blocks)}


def states_from_step(cfg: ModelConfig, new_cache: dict) -> dict:
    """Project ``decode_step_paged``'s output onto the state-store shape:
    keep the updated recurrent-kind states (still on device), drop the
    attention entries (their K/V went to the pool via the append)."""
    prefix = [({} if kind in ATTN_KINDS else c)
              for kind, c in zip(cfg.prefix_pattern, new_cache["prefix"])]
    blocks = tuple(({} if kind in ATTN_KINDS else c)
                   for kind, c in zip(cfg.cycle, new_cache["blocks"]))
    return {"prefix": prefix, "blocks": blocks}


def device_append(cfg: ModelConfig, planes: dict, new_cache: dict,
                  targets: dict,
                  tp: tuple[str, int] | None = None) -> dict:
    """On-device page append: scatter every attention layer's new-token
    K/V (from ``decode_step_paged``) into the HOT token planes at the
    (page, offset) slots claimed by ``PagedKVCache.claim_append_targets``.

    Pure jnp under jit — one dynamic-slice scatter per plane per step, no
    host round-trip.  Inactive slots carry the out-of-range page sentinel
    and are dropped by ``mode="drop"``.

    ``tp=(axis_name, size)`` (inside a ``shard_map`` body): the token
    planes hold only this model shard's kv-head block, while the model
    computed the full-head K/V on every model shard — slice the local
    block at ``axis_index * h_local`` before scattering."""
    rows = {"k": [], "v": [], "k_scale": [], "v_scale": []}
    pids, offs = [], []

    def add(entry, tg):
        pid, off = tg
        for f in rows:
            x = entry[f]                 # [B, ...] or [n_stack, B, ...]
            tail = 2 if f in ("k", "v") else 1    # [H, dh] vs [H]
            rows[f].append(x.reshape(-1, *x.shape[x.ndim - tail:]))
        pids.append(jnp.asarray(pid).reshape(-1))
        offs.append(jnp.asarray(off).reshape(-1))

    for kind, entry, tg in zip(cfg.prefix_pattern, new_cache["prefix"],
                               targets["prefix"]):
        if kind in ATTN_KINDS:
            add(entry, tg)
    for c, kind in enumerate(cfg.cycle):
        if kind in ATTN_KINDS:
            add(new_cache["blocks"][c], targets["blocks"][c])
    if not pids:
        return planes
    pid = jnp.concatenate(pids).astype(jnp.int32)
    off = jnp.concatenate(offs).astype(jnp.int32)
    vals = {f: jnp.concatenate(rows[f]) for f in rows}
    if tp is not None and tp[1] > 1:
        h_loc = planes["tok_k"].shape[2]
        h0 = (jax.lax.axis_index(tp[0]) * h_loc).astype(jnp.int32)
        for f in vals:
            vals[f] = jax.lax.dynamic_slice_in_dim(vals[f], h0, h_loc,
                                                   axis=1)
    out = dict(planes)
    out["tok_k"] = planes["tok_k"].at[pid, off].set(vals["k"], mode="drop")
    out["tok_v"] = planes["tok_v"].at[pid, off].set(vals["v"], mode="drop")
    out["tok_sk"] = planes["tok_sk"].at[pid, off].set(vals["k_scale"],
                                                      mode="drop")
    out["tok_sv"] = planes["tok_sv"].at[pid, off].set(vals["v_scale"],
                                                      mode="drop")
    return out


# --------------------------------------------------------- packed weights
def _pack_quantize(arr: np.ndarray, n_contract: int):
    """Quantize a dense >=2-D tensor with the shared serving convention
    (``quant.quantize_symmetric(..., axis=-1)`` on the ORIGINAL shape —
    identical to ``serve.compress_params``), then fold to the 2-D
    [K, N_flat] matmul view.  The per-last-axis scale is constant along
    every contracted (leading) axis, so tiling it across the flattened
    output axes is exact for the matmul dequantization."""
    from repro.core import quant
    shape = arr.shape
    q, qp = quant.quantize_symmetric(jnp.asarray(arr, jnp.float32), axis=-1)
    k = int(np.prod(shape[:n_contract]))
    nf = int(np.prod(shape[n_contract:]))
    q2 = np.asarray(q).reshape(k, nf)
    sc = np.broadcast_to(np.asarray(qp.scale, np.float32),
                         shape).reshape(k, nf)[0]
    return q2, np.ascontiguousarray(sc)


def pack_weights(cfg: ModelConfig, params: dict, *,
                 min_size: int | None = None,
                 tile_k: int | None = None) -> tuple[dict, dict]:
    """Convert the param tree's large projection/FFN matrices to
    device-resident APack planes (``modules.PackedWeight``), making the
    compressed form the *live* weight store for serving.

    Packed sites: attention wq/wk/wv (contract d) and wo (contract
    h, dh), non-MoE FFN w_up/w_gate/w_down, and the untied lm head.
    Dense by design: the embedding (it serves the token *lookup*), MoE
    expert stacks and recurrent/mLSTM/sLSTM internals (their einsum
    structure doesn't reduce to the [K, N] projection the fused kernel
    serves), and anything under ``min_size`` elements (table + scale
    overhead would beat the savings).

    Scanned stacks are packed per layer (per-layer weight-mode tables
    track per-layer statistics) and re-stacked with a leading layer axis
    (``stack_compressed``) so ``lax.scan`` drives them unchanged.

    Returns ``(packed_params, stats)`` — stats carries the byte
    accounting the engine's ``weight_stats`` reports (dense/native,
    int8, payload, slotted, scale streams)."""
    from repro.kernels import decompress_matmul as dm
    if min_size is None:
        min_size = dm.DEFAULT_WEIGHT_MIN_SIZE

    stats = {"packed_tensors": 0, "native_bytes": 0, "int8_bytes": 0,
             "payload_bytes": 0, "slotted_bytes": 0, "scale_bytes": 0}

    def _account(cws, arr):
        stats["packed_tensors"] += 1
        stats["native_bytes"] += arr.size * arr.dtype.itemsize
        stats["int8_bytes"] += arr.size
        for cw in cws:
            stats["payload_bytes"] += -(-cw.payload_bits // 8)
            stats["slotted_bytes"] += (cw.sym_plane.size * 4
                                       + cw.ofs_plane.size * 4
                                       + cw.stored.size * 4)
            stats["scale_bytes"] += cw.scale.size * 4

    def _tile_k(k: int) -> int:
        return tile_k or min(dm.DEFAULT_TILE_K, k)

    def _pack_tensor(w, n_contract):
        arr = np.asarray(jax.device_get(w))
        q2, sc = _pack_quantize(arr, n_contract)
        cw = dm.compress_quantized(q2, sc, _tile_k(q2.shape[0]))
        _account([cw], arr)
        return m.PackedWeight(cw, tuple(arr.shape), n_contract,
                              str(arr.dtype))

    def _pack_stacked(w, n_contract):
        arr = np.asarray(jax.device_get(w))           # [L, ...]
        cws = []
        for l in range(arr.shape[0]):
            q2, sc = _pack_quantize(arr[l], n_contract)
            cws.append(dm.compress_quantized(q2, sc, _tile_k(q2.shape[0])))
        _account(cws, arr)
        return m.PackedWeight(dm.stack_compressed(cws), tuple(arr.shape[1:]),
                              n_contract, str(arr.dtype))

    def _elig(w, stacked):
        per_layer = int(np.prod(w.shape[1:] if stacked else w.shape))
        return per_layer >= min_size

    def _pack_block(blk, kind, stacked):
        pack = _pack_stacked if stacked else _pack_tensor
        out = dict(blk)
        if kind in ATTN_KINDS:
            inner = dict(blk["inner"])
            for name, nc in (("wq", 1), ("wk", 1), ("wv", 1), ("wo", 2)):
                if _elig(inner[name], stacked):
                    inner[name] = pack(inner[name], nc)
            out["inner"] = inner
        if "ffn" in blk and "router" not in blk["ffn"]:
            ffn = dict(blk["ffn"])
            for name in ("w_up", "w_gate", "w_down"):
                if name in ffn and _elig(ffn[name], stacked):
                    ffn[name] = pack(ffn[name], 1)
            out["ffn"] = ffn
        return out

    out = dict(params)
    if "unembed" in params and _elig(params["unembed"], False):
        out["unembed"] = _pack_tensor(params["unembed"], 1)
    if "prefix" in params:
        out["prefix"] = [_pack_block(b, kind, False)
                         for kind, b in zip(cfg.prefix_pattern,
                                            params["prefix"])]
    out["blocks"] = tuple(_pack_block(b, kind, True)
                          for kind, b in zip(cfg.cycle, params["blocks"]))
    return out, stats


def packed_param_specs(params: dict, n_model: int):
    """Param-tree PartitionSpecs for the mesh step: dense leaves
    replicate (``P()``, the pre-packing behavior), PACKED plane leaves
    K-split over "model" when the layout divides (``sharding.
    packed_leaf_pspecs``) — the stream axis is kt-major, so a contiguous
    stream shard is a contiguous K-tile range and ``modules.packed_proj``
    reassembles the row-parallel partials with a ``psum``."""
    from jax.sharding import PartitionSpec as P

    def one(x):
        if not isinstance(x, m.PackedWeight):
            return P()
        cw = x.cw
        nk = cw.k_pad // cw.tile_k
        splittable = (n_model > 1 and cw.k == cw.k_pad
                      and nk % n_model == 0)
        leaves, treedef = jax.tree_util.tree_flatten(x)
        return jax.tree_util.tree_unflatten(
            treedef, shd.packed_leaf_pspecs(leaves, splittable=splittable))

    flat, treedef = jax.tree_util.tree_flatten(
        params, is_leaf=lambda x: isinstance(x, m.PackedWeight))
    return jax.tree_util.tree_unflatten(treedef, [one(x) for x in flat])


# ------------------------------------------------ mesh-sharded decode step
def mesh_axis_sizes(mesh) -> tuple[int, int]:
    """(n_data, n_model) of a serving mesh; absent axes count as 1."""
    shape = dict(mesh.shape)
    return int(shape.get("data", 1)), int(shape.get("model", 1))


def _localize_meta(cfg: ModelConfig, meta: dict, p_loc, d0):
    """Global page ids -> this data shard's local plane indices.

    Shard ``s`` owns the contiguous page range ``[s*p_loc, (s+1)*p_loc)``
    (matching the pool's per-shard free lists), and the engine binds every
    request to exactly one shard — so an *active* slot of this shard only
    references owned pages.  Masked entries (state == FREE, or rows of
    slots bound to other shards) may carry any global id; ``clip`` keeps
    them in-range and the state mask makes their value irrelevant."""
    def one(md):
        if not md:
            return md
        out = dict(md)
        out["pid"] = jnp.clip(md["pid"] - d0, 0, p_loc - 1)
        return out

    return {"prefix": [one(md) for md in meta["prefix"]],
            "blocks": tuple(one(md) for md in meta["blocks"])}


def _localize_targets(cfg: ModelConfig, targets: dict, p_loc, d0):
    """Append targets -> local plane indices; anything this shard does not
    own (idle-slot sentinels, other shards' pages) maps to the local
    out-of-range sentinel ``p_loc`` and is dropped by the scatter's
    ``mode="drop"`` — each shard appends only into its own page range."""
    def one(tg):
        if tg is None:
            return None
        pid, off = tg
        lp = pid - d0
        lp = jnp.where((lp >= 0) & (lp < p_loc), lp, p_loc)
        return (lp.astype(jnp.int32), off)

    return {"prefix": [one(tg) for tg in targets["prefix"]],
            "blocks": tuple(one(tg) for tg in targets["blocks"])}


def _paged_tree_specs(cfg: ModelConfig, prefix_spec, block_spec,
                      empty):
    """Spec pytree matching the state/meta/target tree shapes: attention
    positions get the batch-sharded spec, recurrent-kind positions the
    empty node their argument carries (``{}`` for states/meta, ``None``
    for targets).  Prefix leaves are [B, ...], scanned block leaves
    [n_stack, B, ...] — hence the two specs."""
    prefix = [(prefix_spec if kind in ATTN_KINDS else empty)
              for kind in cfg.prefix_pattern]
    blocks = tuple((block_spec if kind in ATTN_KINDS else empty)
                   for kind in cfg.cycle)
    return {"prefix": prefix, "blocks": blocks}


def _state_specs(cfg: ModelConfig, P):
    """State-store specs: batch-sharded over "data" at every
    recurrent-kind position, ``{}`` at attention positions (their state
    lives in the page pool)."""
    prefix = [({} if kind in ATTN_KINDS else P("data"))
              for kind in cfg.prefix_pattern]
    blocks = tuple(({} if kind in ATTN_KINDS else P(None, "data"))
                   for kind in cfg.cycle)
    return {"prefix": prefix, "blocks": blocks}


def build_sharded_step(cfg: ModelConfig, mesh, *, backend: str | None = None,
                       params: dict | None = None):
    """The mesh-sharded fused decode step: ONE ``jit(shard_map(...))``
    combining ``decode_step_paged`` + ``device_append`` +
    ``states_from_step`` per step.

    Partitioning (DESIGN.md §11): decode jobs data-parallel over "data"
    (batch rows, state store, step meta, append targets and the page
    planes all shard with their jobs — each data shard owns a contiguous
    page range matching its free list), kv-heads tensor-parallel over
    "model" for the fused gather-decode-attention kernel.  PACKED planes
    replicate over "model" (the APack stream layout interleaves heads);
    each model shard decodes the full page and slices its local head
    block, then an ``all_gather`` over "model" reassembles head-major
    accumulators before the output projection — greedy tokens stay
    bit-identical to the single-device engine because per-kv-head
    attention has no cross-head reduction and the gather restores exact
    head order.

    Returns ``step(params, planes, states, meta, tokens, pos, targets)
    -> (logits, toks, planes', states')`` where ``toks`` is the greedy
    argmax over the final-position logits, computed *inside* the device
    program: the engine's per-step host pull shrinks from a
    ``[batch, vocab]`` logits gather (plus an eager cross-shard argmax
    dispatch) to ``batch`` int32s.  Targets must be claimed *before*
    the call (host metadata is independent of the decode output), which
    is what lets the whole step stay a single device program with zero
    ``device_get`` per shard.

    ``params``: pass the (possibly APack-packed) param tree to derive
    per-leaf weight specs — packed plane leaves K-split over "model"
    where the layout divides (see ``packed_param_specs``); ``None``
    keeps the legacy fully-replicated ``P()``."""
    from jax.sharding import PartitionSpec as P
    n_data, n_model = mesh_axis_sizes(mesh)
    if n_model > 1 and cfg.num_kv_heads % n_model:
        raise ValueError(
            f"num_kv_heads={cfg.num_kv_heads} must divide over the "
            f"{n_model}-way model axis for tensor-parallel paged decode")
    tp = ("model", n_model) if n_model > 1 else None

    def _body(params, planes, states, meta, tokens, pos, targets):
        p_loc = planes["tok_k"].shape[0]
        d0 = (jax.lax.axis_index("data") * p_loc).astype(jnp.int32)
        logits, new_cache = decode_step_paged(
            cfg, params, planes, states,
            _localize_meta(cfg, meta, p_loc, d0), tokens, pos,
            backend=backend, tp=tp)
        planes2 = device_append(
            cfg, planes, new_cache,
            _localize_targets(cfg, targets, p_loc, d0), tp=tp)
        toks = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return logits, toks, planes2, states_from_step(cfg, new_cache)

    plane_specs = shd.plane_pspecs()
    state_specs = _state_specs(cfg, P)
    meta_specs = _paged_tree_specs(cfg, P("data"), P(None, "data"), {})
    target_specs = _paged_tree_specs(cfg, P("data"), P(None, "data"), None)
    param_specs = (P() if params is None
                   else packed_param_specs(params, n_model))
    from jax.experimental.shard_map import shard_map
    stepped = shard_map(
        _body, mesh=mesh,
        in_specs=(param_specs, plane_specs, state_specs, meta_specs,
                  P("data"), P("data"), target_specs),
        out_specs=(P("data"), P("data"), plane_specs, state_specs),
        check_rep=False)
    return jax.jit(stepped)


def extend_caches(cfg: ModelConfig, caches: dict, max_len: int) -> dict:
    """Pad prefill caches (global-attention k/v of length S) to decode
    capacity ``max_len``.  Rolling/local and recurrent caches are already
    fixed-size."""
    def pad(kind, cache):
        if kind == "global":
            s = cache["k"].shape[-3]
            if s < max_len:
                def pad_one(name, v):
                    # seq axis: ndim-3 for k/v, ndim-2 for per-head scales
                    ax = v.ndim - (2 if name.endswith("_scale") else 3)
                    widths = [(0, 0)] * v.ndim
                    widths[ax] = (0, max_len - s)
                    return jnp.pad(v, widths)
                return {k: pad_one(k, v) for k, v in cache.items()}
        return cache

    blocks = tuple(pad(kind, c)
                   for kind, c in zip(cfg.cycle, caches["blocks"]))
    prefix = [pad(kind, c)
              for kind, c in zip(cfg.prefix_pattern, caches["prefix"])]
    return {"prefix": prefix, "blocks": blocks}


def prefill(cfg: ModelConfig, params: dict, batch: dict,
            max_len: int | None = None):
    """Process a prompt, returning (last-position logits, decode caches)."""
    logits, caches, _ = forward(cfg, params, batch, remat=False,
                                collect_cache=True, last_only=True)
    if max_len is not None:
        caches = extend_caches(cfg, caches, max_len)
    return logits, caches


# ------------------------------------------------------- paged APack KV
ATTN_KINDS = ("global", "local")
STATE_KINDS = ("recurrent", "mlstm", "slstm")


def _layer_kinds(cfg: ModelConfig) -> list[str]:
    """Network-layer kind list: prefix layers first, then the scanned
    stack in layer order ``n_prefix + j * n_cycle + c``."""
    return list(cfg.prefix_pattern) + [
        cfg.cycle[c] for j in range(cfg.n_cycles)
        for c in range(len(cfg.cycle))]


class DevicePoolPlanes:
    """Device-resident mirror of the ``KVPagePool`` storage planes.

    Kind-split (``_k`` / ``_v`` arrays instead of a leading kind axis) so
    the fused kernel's BlockSpecs index pages directly.  The decode hot
    path reads these and the on-device append writes them; the host pool
    stays the metadata + seal/pack source of truth, synced per *page
    event* (seal, pack, calibration, prefill ingest) rather than per step
    — that sync is the only payload that ever crosses host<->device in
    steady-state decode."""

    def __init__(self, pool: m.KVPagePool, n_tables: int, mesh=None):
        p, ps = pool.num_pages, pool.page_size
        h, dh, s = pool.kv_heads, pool.head_dim, pool.n_streams
        self.n_tables = n_tables
        self.mesh = mesh
        z = jnp.zeros
        self.planes: dict[str, jax.Array] = {
            "tok_k": z((p, ps, h, dh), jnp.int8),
            "tok_v": z((p, ps, h, dh), jnp.int8),
            "tok_sk": z((p, ps, h), F32),
            "tok_sv": z((p, ps, h), F32),
            "cold_k": z((p, ps, h, dh), jnp.int8),
            "cold_v": z((p, ps, h, dh), jnp.int8),
            "pscale_k": z((p, h), F32),
            "pscale_v": z((p, h), F32),
            "sym_k": z((p, pool.sym_words, s), jnp.uint32),
            "sym_v": z((p, pool.sym_words, s), jnp.uint32),
            "ofs_k": z((p, pool.ofs_words, s), jnp.uint32),
            "ofs_v": z((p, pool.ofs_words, s), jnp.uint32),
            "stored_k": z((p, s), jnp.int32),
            "stored_v": z((p, s), jnp.int32),
            "vm": z((n_tables, 17), jnp.int32),
            "ol": z((n_tables, 16), jnp.int32),
            "cum": z((n_tables, 17), jnp.int32),
        }
        self.repin()

    def repin(self) -> None:
        """Re-place every plane under the mesh partitioning rules
        (``sharding.plane_pspecs``): pages shard over "data" (matching the
        per-shard free lists), dense payload heads over "model", PACKED
        streams and tables replicated over "model".  Called at
        construction and after host-sync *events* — eager ``.at[].set``
        scatters may leave an event-updated plane with a degraded layout,
        and repinning there keeps the steady-state step free of implicit
        reshards.  No-op without a mesh."""
        if self.mesh is None:
            return
        sh = shd.plane_shardings(self.mesh, self.planes)
        # only re-place planes whose layout actually degraded: an event
        # flush typically touches one state's planes, and device_put on
        # the 17 untouched ones is pure per-event dispatch overhead
        self.planes = {
            k: v if v.sharding.is_equivalent_to(sh[k], v.ndim)
            else jax.device_put(v, sh[k])
            for k, v in self.planes.items()}

    def ensure_table_capacity(self, n_rows: int) -> bool:
        """Grow the device table planes to hold ``n_rows`` rows (doubling,
        so a long-running refresh schedule causes O(log generations) plane
        reallocations / decode-jit recompiles, each at a refresh boundary
        — never in the steady-state loop).  Returns True if reallocated;
        the caller must then re-upload every table row."""
        if n_rows <= self.n_tables:
            return False
        cap = self.n_tables
        while cap < n_rows:
            cap *= 2
        self.n_tables = cap
        z = jnp.zeros
        self.planes["vm"] = z((cap, 17), jnp.int32)
        self.planes["ol"] = z((cap, 16), jnp.int32)
        self.planes["cum"] = z((cap, 17), jnp.int32)
        return True


class PagedKVCache:
    """Paged, APack-compressed KV cache for ``kv_cache_dtype="apack-int8"``.

    Supports heterogeneous stacks — any mix of ``global`` / ``local``
    attention and ``recurrent`` / ``mlstm`` / ``slstm`` fixed-state layers,
    scanned or prefix.  Three stream kinds:

    * **global** attention layers: the off-chip store is a
      ``modules.KVPagePool`` shared by every layer; each request owns a
      per-layer list of page ids (the page table).  Token ``t`` of a
      sequence lives at page ``t // page_size`` offset ``t % page_size`` —
      the same absolute layout as the dense cache, so ``materialize`` can
      rebuild the exact int8 cache pytree ``decode_step`` consumes.
    * **local** (rolling-window) attention layers: same page layout, plus
      page-granular eviction — once every token in the oldest page has
      rolled out of the attention window the page returns to the free list
      (``pool.evict``).  A rolling layer therefore holds at most
      ``window_pages`` pages regardless of sequence length, and
      ``materialize`` rebuilds the rolling *ring* layout (slot
      ``pos % ring``) ``attention_step`` expects.
    * **recurrent/mLSTM/sLSTM state** layers: fixed-size f32 states stay
      dense on the hot path (stored per request, stitched into the
      materialized pytree every step) and are APack-compressed losslessly
      with weight-mode tables only at snapshot boundaries
      (``snapshot_state`` / ``restore_state`` — the engine
      checkpoint/preemption path).

    Compression policy (paper §VI activations): each attention layer ×
    {K, V} gets its own activation-mode table, calibrated *online* from
    the histogram of the first ``calib_pages`` sealed pages of that layer
    — the probability slack for empty ranges guarantees any later,
    unprofiled value stays encodable (lossless).  Pages sealed before
    calibration completes stay COLD (uncompressed int8, page-granular
    scales) and are retro-packed the moment the table exists.  Reads of
    PACKED pages go through the Pallas gather-decode kernel
    (``kernels/paged_decode.py``), batched across *all* layers per K/V
    kind via the per-page table-id prefetch vector — compressed words are
    the only thing that crosses the "off-chip" boundary, which is where
    the traffic saving in ``self.traffic`` comes from.
    """

    def __init__(self, cfg: ModelConfig, num_pages: int, *,
                 page_size: int = 16, calib_pages: int = 4,
                 elems_per_stream: int = 128, backend: str | None = None,
                 refresh_every_pages: int | None = None,
                 refresh_threshold: float = 0.15,
                 refresh_min_pages: int = 4,
                 verify_on_repack: bool = False,
                 transfer_retries: int = 2,
                 n_shards: int = 1):
        self.cfg = cfg
        self.page_size = page_size
        self.calib_pages = calib_pages
        self.backend = backend
        # table-refresh policy (drift-adaptive serving): refresh a layer's
        # tables when the drift sketch's expected coded size regresses
        # ``refresh_threshold`` past the calibration-time expectation, or
        # unconditionally every ``refresh_every_pages`` sealed pages; both
        # triggers arm only after ``refresh_min_pages`` pages of sketch.
        # Triggers are only *checked* when maybe_refresh()/refresh_step()
        # is called (the engine's kv_refresh knob) — sketches always
        # accumulate, so enabling refresh mid-serve needs no warmup.
        self.refresh_every_pages = refresh_every_pages
        self.refresh_threshold = refresh_threshold
        self.refresh_min_pages = refresh_min_pages
        self.n_prefix = len(cfg.prefix_pattern)
        self.n_cycle = len(cfg.cycle)
        self.n_stack = cfg.n_cycles
        self.layer_kinds = _layer_kinds(cfg)
        self.n_layers = len(self.layer_kinds)
        self.attn_layers = [i for i, k in enumerate(self.layer_kinds)
                            if k in ATTN_KINDS]
        self.local_layers = [i for i, k in enumerate(self.layer_kinds)
                             if k == "local"]
        self.state_layers = [i for i, k in enumerate(self.layer_kinds)
                             if k in STATE_KINDS]
        self.window = cfg.window_size
        self.pool = m.KVPagePool(num_pages, page_size, cfg.num_kv_heads,
                                 cfg.head_dim, elems_per_stream,
                                 n_shards=n_shards)
        # mesh-sharded serving: every request is bound to one page shard
        # (= one "data" mesh slice) at admission; its pages allocate from
        # that shard's free list only, so admission and the on-device
        # append never serialize on a global lock and every page a data
        # shard's kernel reads lives in its own contiguous page range.
        self.n_shards = n_shards
        self.request_shard: dict[int, int] = {}
        # per (layer, kind=K/V): activation-mode table + calibration state
        self.tables: list[list] = [[None, None] for _ in range(self.n_layers)]
        self.hists = np.zeros((self.n_layers, 2, 256), np.int64)
        self.hist_pages = np.zeros((self.n_layers, 2), np.int32)
        self._cold: list[set[int]] = [set() for _ in range(self.n_layers)]
        self._packed: list[set[int]] = [set() for _ in range(self.n_layers)]
        self._table_stack = None   # lazy [(G+1)*2*n_layers, ...] np stack
        # generation-versioned table pool: ``self.tables`` is always the
        # *current* generation; each refresh snapshots the previous set so
        # pages packed under older tables keep decoding bit-exactly while
        # the budgeted re-pack migrates them.  Table row addressing is
        # ``paged_decode.table_row(gen, layer, kind, n_layers)``.
        self.generation = 0
        self._gen_snapshots: list[list[list]] = []   # per past gen: [L][2]
        # generation -> row-block *slot* in the stacked table pool.  Rows
        # are addressed through this indirection so ``compact_table_rows``
        # can reclaim the 2*n_layers block of a generation that no longer
        # owns any PACKED page (resident or spilled) — the stacked pool
        # stops growing monotonically with refresh count.  Generation 0 is
        # always live (HOT/COLD pages carry gen 0 in their meta rows).
        self.gen_rows: dict[int, int] = {0: 0}
        self.table_gen = np.zeros(self.n_layers, np.int32)
        self.page_gen = np.zeros(num_pages, np.int32)
        # page metadata alongside page_gen: integrity checksum of the
        # PACKED planes (stamped at pack/re-pack/unspill, verified on
        # unspill and — when ``verify_on_repack`` — before every re-pack
        # decode) and a read-clock LRU stamp driving cold-first spill
        self.page_crc = np.zeros(num_pages, np.uint32)
        self.page_last_read = np.zeros(num_pages, np.int64)
        self._read_clock = 0
        self.verify_on_repack = verify_on_repack
        # host spill tier: compressed pages of preempted requests parked
        # off-pool (negative page-table entries are ``-handle - 1`` refs)
        self.spill_tier = m.HostSpillTier()
        # fault injection (serve/faults.py) + bounded transfer retry
        self.faults = None
        self.transfer_retries = transfer_retries
        # drift monitor: symbol-frequency sketch of pages sealed since the
        # layer's last (re)calibration + the expected bits/value its
        # current table promised on the histogram it was built from
        self.drift_hists = np.zeros((self.n_layers, 2, 256), np.int64)
        self.drift_pages = np.zeros(self.n_layers, np.int32)
        self.calib_bits = np.zeros((self.n_layers, 2), np.float64)
        self._drift_changed: set[int] = set()   # sketch moved since check
        self._repack_queue: deque[tuple[int, int]] = deque()
        self._state_templates: dict[str, dict] = {}
        self.page_tables: dict[int, list[list[int]]] = {}
        self.page_base: dict[int, list[int]] = {}   # evicted-page count
        self.states: dict[int, dict[int, dict[str, np.ndarray]]] = {}
        self.seq_len: dict[int, int] = {}
        self.traffic = {"kv_raw_bytes": 0, "kv_read_bytes": 0,
                        "kv_table_bytes": 0, "kv_pages_packed": 0,
                        "kv_raw_bytes_global": 0, "kv_read_bytes_global": 0,
                        "kv_raw_bytes_local": 0, "kv_read_bytes_local": 0,
                        "state_raw_bytes": 0, "state_snapshot_bytes": 0,
                        "state_snapshots": 0,
                        # table-refresh re-pack traffic: the read of the
                        # old planes + write of the new ones.  Kept OUT of
                        # kv_read_bytes/kv_raw_bytes — a re-pack is not an
                        # attention read, and folding it in would
                        # double-count the page against the stream ratios
                        "kv_repack_read_bytes": 0, "kv_repack_write_bytes": 0,
                        "kv_repack_pages": 0, "kv_repack_kept": 0,
                        "kv_refresh_count": 0,
                        # spill / readahead traffic: host-tier writes of
                        # compressed pages and the batched h2d that brings
                        # them back.  Own streams, same rule as repack —
                        # NEVER folded into the attention-read ratios
                        "kv_spill_bytes": 0, "kv_spill_raw_bytes": 0,
                        "kv_spill_pages": 0, "kv_spill_calls": 0,
                        "kv_readahead_bytes": 0, "kv_readahead_pages": 0,
                        "kv_readahead_calls": 0,
                        "kv_integrity_failures": 0, "kv_quarantined_pages": 0,
                        "kv_transfer_drops": 0, "kv_transfer_retries": 0}
        # host<->device transfer accounting: every byte the KV path moves
        # across the boundary goes through _fetch/_put so the decode bench
        # and the steady-state zero-device_get guard have ground truth
        self.transfers = {"h2d_bytes": 0, "d2h_bytes": 0,
                          "h2d_calls": 0, "d2h_calls": 0}
        # device-resident mode (fused decode): plane mirror + state store
        self.dev: DevicePoolPlanes | None = None
        self.dev_states: dict | None = None
        self._dirty: set[int] = set()       # pages needing a device sync
        self._tables_dirty = False
        self._page_pull = None              # cached jitted seal-pull gather
        self._plane_push = None             # cached jitted event-sync scatter

    # ------------------------------------------------------------ sizing
    def pages_per_seq(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    @property
    def window_pages(self) -> int:
        """Max live pages of a rolling layer: the window can straddle one
        more page boundary than ``ceil(window / page_size)`` covers."""
        return -(-self.window // self.page_size) + 1

    def pages_needed(self, n_tokens: int) -> int:
        """Pool pages a request storing ``n_tokens`` occupies, summed over
        layers with per-kind reservation: global layers hold the full
        sequence, rolling layers at most ``window_pages``, recurrent-kind
        layers none (their state is not paged)."""
        return self.pages_for_config(self.cfg, n_tokens, self.page_size)

    @staticmethod
    def pages_for_config(cfg: ModelConfig, n_tokens: int,
                         page_size: int) -> int:
        """Worst-case per-request page count (shared with the engine's
        pool sizing, so the default pool can be computed pre-construction)."""
        full = -(-n_tokens // page_size)
        rolling = min(full, -(-cfg.window_size // page_size) + 1)
        total = 0
        for kind in _layer_kinds(cfg):
            if kind == "global":
                total += full
            elif kind == "local":
                total += rolling
        return total

    @property
    def free_pages(self) -> int:
        return self.pool.free_count

    def kv_ratio(self) -> float | None:
        """Cumulative compressed-vs-raw KV read traffic (< 1.0 is a win).

        ``None`` before any read has moved a byte: reporting 1.0 there
        would claim break-even for an engine that has not served anything
        (and would hide table overhead already accrued)."""
        raw = self.traffic["kv_raw_bytes"]
        if raw == 0:
            return None
        return (self.traffic["kv_read_bytes"]
                + self.traffic["kv_table_bytes"]) / raw

    def stream_stats(self) -> dict:
        """Per-stream accounting: global KV reads, rolling/local KV reads,
        recurrent-state snapshot bytes.  Stream ratios are payload-only
        (table overhead is global, counted once in ``kv_ratio``)."""
        out = {}
        for kind in ("global", "local"):
            raw = self.traffic[f"kv_raw_bytes_{kind}"]
            read = self.traffic[f"kv_read_bytes_{kind}"]
            out[kind] = {"raw_bytes": raw, "read_bytes": read,
                         "ratio": (read / raw) if raw else None}
        raw = self.traffic["state_raw_bytes"]
        comp = self.traffic["state_snapshot_bytes"]
        out["state"] = {"raw_bytes": raw, "snapshot_bytes": comp,
                        "snapshots": self.traffic["state_snapshots"],
                        "ratio": (comp / raw) if raw else None}
        # table-refresh re-pack overhead: its own stream (read old planes
        # + write new ones), never folded into the read-path ratios above
        out["repack"] = {
            "read_bytes": self.traffic["kv_repack_read_bytes"],
            "write_bytes": self.traffic["kv_repack_write_bytes"],
            "pages": self.traffic["kv_repack_pages"],
            "kept": self.traffic["kv_repack_kept"],
            "refreshes": self.traffic["kv_refresh_count"],
            "generation": self.generation,
            "pending": len(self._repack_queue)}
        # spill tier: compressed bytes parked on host vs the dense-int8
        # working set they replace (< 1.0 == spilling compressed pays),
        # plus the readahead leg that restores them.  Own stream — spill
        # traffic is not an attention read
        sp, spraw = (self.traffic["kv_spill_bytes"],
                     self.traffic["kv_spill_raw_bytes"])
        out["spill"] = {
            "spill_bytes": sp, "raw_bytes": spraw,
            "ratio": (sp / spraw) if spraw else None,
            "pages": self.traffic["kv_spill_pages"],
            "calls": self.traffic["kv_spill_calls"],
            "readahead_bytes": self.traffic["kv_readahead_bytes"],
            "readahead_pages": self.traffic["kv_readahead_pages"],
            "readahead_calls": self.traffic["kv_readahead_calls"],
            "live_records": self.spill_tier.live_count,
            "live_bytes": self.spill_tier.live_bytes,
            "integrity_failures": self.traffic["kv_integrity_failures"],
            "quarantined": self.traffic["kv_quarantined_pages"]}
        return out

    # ----------------------------------------------------------- requests
    def add_request(self, rid: int, shard: int = 0) -> None:
        if rid in self.page_tables:
            raise ValueError(f"duplicate request id {rid}")
        if not 0 <= shard < self.n_shards:
            raise ValueError(f"shard {shard} out of range "
                             f"(pool has {self.n_shards})")
        self.page_tables[rid] = [[] for _ in range(self.n_layers)]
        self.page_base[rid] = [0] * self.n_layers
        self.request_shard[rid] = shard
        self.states[rid] = {}
        self.seq_len[rid] = 0

    def release(self, rid: int) -> None:
        for layer, pids in enumerate(self.page_tables.pop(rid)):
            for pid in pids:
                if pid < 0:                    # SPILLED: park in tier only
                    self.spill_tier.drop(-pid - 1)
                    continue
                self._cold[layer].discard(pid)
                self._packed[layer].discard(pid)
                self.page_gen[pid] = 0
                self.page_crc[pid] = 0
                self.page_last_read[pid] = 0
                self.pool.free(pid)
        del self.page_base[rid]
        self.request_shard.pop(rid, None)
        del self.states[rid]
        del self.seq_len[rid]

    # ------------------------------------------------------------ appends
    def _claim_page(self, rid: int, layer: int, t: int) -> int:
        """Page that token ``t`` of (rid, layer) writes into, allocating a
        fresh one at page boundaries (shared by the host append path and
        the on-device append's target claim)."""
        pids = self.page_tables[rid][layer]
        if t % self.page_size == 0:
            if t // self.page_size != self.page_base[rid][layer] + len(pids):
                raise RuntimeError(
                    f"page-table desync for rid={rid} layer={layer}: token "
                    f"{t} vs base={self.page_base[rid][layer]} "
                    f"live={len(pids)}")
            shard = self.request_shard.get(rid, 0)
            pid = self.pool.alloc(shard)
            if pid is None:
                raise RuntimeError(
                    f"page shard {shard} exhausted mid-flight "
                    "(admission must reserve per shard)")
            pids.append(pid)
        if pids[-1] < 0:
            raise m.PageIntegrityError(
                f"append into SPILLED page of rid={rid} layer={layer} — "
                "readahead must restore the request before it decodes",
                rid=rid, layer=layer)
        return pids[-1]

    def _append_layer_token(self, rid: int, layer: int, kq, vq, ks, vs,
                            t: int) -> None:
        pid = self._claim_page(rid, layer, t)
        self.pool.write_token(pid, kq, vq, ks, vs)
        if int(self.pool.fill[pid]) == self.page_size:
            self._seal(layer, pid)

    def append_token(self, rid: int, kq: np.ndarray, vq: np.ndarray,
                     ks: np.ndarray, vs: np.ndarray) -> None:
        """Append one token's KV for every attention layer.  kq/vq:
        [n_layers, H, dh] int8; ks/vs: [n_layers, H] f32 (the model's
        per-token scales).  Rows of recurrent-kind layers are ignored —
        their state is not per-token (see ``append_step_tokens``)."""
        t = self.seq_len[rid]
        for layer in self.attn_layers:
            self._append_layer_token(rid, layer, kq[layer], vq[layer],
                                     ks[layer], vs[layer], t)
        self.seq_len[rid] = t + 1
        self.evict_rolled(rid)

    def evict_rolled(self, rid: int) -> None:
        """Rolling-window eviction: free every local-layer page whose
        tokens have *all* left the attention window.  Page ``p`` holds
        tokens ``[p*ps, (p+1)*ps)``; with the next decode position at
        ``qpos = seq_len`` the attention mask keeps ``kpos > qpos -
        window``, so the page is dead once ``(p+1)*ps - 1 <= qpos -
        window``.  Only the oldest live page can die, and it is always
        sealed (COLD/PACKED) because pages seal the moment they fill."""
        qpos = self.seq_len[rid]
        ps = self.page_size
        for layer in self.local_layers:
            pids = self.page_tables[rid][layer]
            base = self.page_base[rid][layer]
            while pids and (base + 1) * ps - 1 <= qpos - self.window:
                pid = pids.pop(0)
                if pid < 0:                   # SPILLED page rolled out
                    self.spill_tier.drop(-pid - 1)
                    base += 1
                    continue
                self._cold[layer].discard(pid)
                self._packed[layer].discard(pid)
                self.page_gen[pid] = 0
                self.page_crc[pid] = 0
                self.pool.evict(pid)
                base += 1
            self.page_base[rid][layer] = base

    # --------------------------------------------------- cache plumbing
    def _layer_cache(self, caches: dict, layer: int):
        """(leaf-dict, stack-index) of one network layer in a cache pytree
        — prefix leaves are [B, ...], scanned leaves [n_stack, B, ...]."""
        if layer < self.n_prefix:
            return caches["prefix"][layer], None
        off = layer - self.n_prefix
        return caches["blocks"][off % self.n_cycle], off // self.n_cycle

    def _state_template(self, kind: str) -> dict[str, np.ndarray]:
        """Init-value state leaves (batch dim stripped) for empty slots."""
        if kind not in self._state_templates:
            one = _init_block_cache(self.cfg, kind, 1, 1)
            self._state_templates[kind] = {
                f: np.asarray(self._fetch(x))[0] for f, x in one.items()}
        return self._state_templates[kind]

    def _ring(self, max_len: int) -> int:
        """Rolling-layer dense-cache width (matches init_attention_cache)."""
        return min(self.window, max_len)

    def append_step_tokens(self, caches: dict, slot_rids: list,
                           positions) -> None:
        """Extract what a decode step wrote for every active slot of a
        dense cache pytree: the token at ``positions[slot]`` (ring slot
        ``pos % ring`` for rolling layers) for attention layers, the whole
        updated fixed-size state for recurrent-kind layers."""
        b = len(slot_rids)
        positions = np.asarray(positions, np.int32)
        barange = jnp.arange(b)
        fetched: dict[int, dict[str, np.ndarray]] = {}
        done_groups = set()
        for layer in range(self.n_layers):
            kind = self.layer_kinds[layer]
            leaf, j = self._layer_cache(caches, layer)
            group = ("p", layer) if j is None else ("c",
                                                    (layer - self.n_prefix)
                                                    % self.n_cycle)
            if group in done_groups:
                continue
            done_groups.add(group)
            if kind in ATTN_KINDS:
                sc = leaf["k"].shape[-3]
                slot_idx = jnp.asarray(
                    positions % sc if kind == "local" else positions)
                vals = {}
                for f in ("k", "v", "k_scale", "v_scale"):
                    x = leaf[f]
                    if j is None:
                        vals[f] = np.asarray(
                            self._fetch(x[barange, slot_idx]))[None]
                    else:
                        vals[f] = np.asarray(
                            self._fetch(x[:, barange, slot_idx]))
            else:
                vals = {f: (np.asarray(self._fetch(x))[None] if j is None
                            else np.asarray(self._fetch(x)))
                        for f, x in leaf.items()}
            # vals leaves are [n_stack(or 1), B, ...]; distribute to layers
            if j is None:
                fetched[layer] = {f: v[0] for f, v in vals.items()}
            else:
                c = (layer - self.n_prefix) % self.n_cycle
                for jj in range(self.n_stack):
                    fetched[self.n_prefix + jj * self.n_cycle + c] = {
                        f: v[jj] for f, v in vals.items()}
        h, dh = self.pool.kv_heads, self.pool.head_dim
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            kq = np.zeros((self.n_layers, h, dh), np.int8)
            vq = np.zeros((self.n_layers, h, dh), np.int8)
            ks = np.zeros((self.n_layers, h), np.float32)
            vs = np.zeros((self.n_layers, h), np.float32)
            for layer in self.attn_layers:
                kq[layer] = fetched[layer]["k"][slot]
                vq[layer] = fetched[layer]["v"][slot]
                ks[layer] = fetched[layer]["k_scale"][slot]
                vs[layer] = fetched[layer]["v_scale"][slot]
            self.append_token(rid, kq, vq, ks, vs)
            for layer in self.state_layers:
                self.states[rid][layer] = {
                    f: v[slot].copy() for f, v in fetched[layer].items()}

    def ingest_prefill(self, rid: int, caches: dict, s: int) -> None:
        """Chop a (batch-1) prefill cache into pages, token order.

        Global layers ingest every position.  Rolling layers only have
        the last ``min(s, window)`` positions in the prefill cache (the
        model emits the rolling ring, not the full sequence) — exactly
        the live window: fully-dead leading pages are skipped outright
        (``page_base`` starts past them) and in-page positions older than
        the window ingest as zeros (dead by construction, never
        materialized).  Recurrent-kind layers store their final state.

        This is the monolithic wrapper over the resumable chunk API
        (``prefill_host_view`` -> ``ingest_prefill_chunk``* ->
        ``finish_prefill``) that the async engine paginates across decode
        steps, so one long prompt never stalls the batch."""
        view = self.prefill_host_view(caches)
        self.ingest_prefill_chunk(rid, view, 0, s, s)
        self.finish_prefill(rid, view, s)

    def prefill_host_view(self, caches: dict) -> dict:
        """One batched d2h pull of a (batch-1) prefill cache into host
        numpy — attn layers as ``(k, v, k_scale, v_scale)`` tuples, state
        layers as their field dicts.  Forcing the view blocks on the
        prefill computation, so the async engine calls this during the
        overlap window where the wait rides the in-flight decode step."""
        view: dict = {}
        for layer in self.attn_layers:
            leaf, j = self._layer_cache(caches, layer)

            def one(f, leaf=leaf, j=j):
                x = leaf[f] if j is None else leaf[f][j]
                return np.asarray(self._fetch(x))[0]

            view[layer] = (one("k"), one("v"), one("k_scale"),
                           one("v_scale"))
        for layer in self.state_layers:
            leaf, j = self._layer_cache(caches, layer)
            view[layer] = {
                f: np.asarray(self._fetch(x if j is None else x[j]))[0]
                for f, x in leaf.items()}
        return view

    def ingest_prefill_chunk(self, rid: int, view: dict, t0: int, t1: int,
                             s: int) -> None:
        """Ingest prompt positions ``[t0, t1)`` of an ``s``-token prefill
        from a host view.  Resumable: chunks may arrive across decode
        steps; page/seal/sketch work is identical to a single monolithic
        call (same tokens, same order)."""
        ps = self.page_size
        for layer in self.attn_layers:
            kind = self.layer_kinds[layer]
            k, v, ksc, vsc = view[layer]               # [S or window, H, dh]
            if kind == "local":
                w = k.shape[0]                         # ring width == window
                start = (max(0, s - w) // ps) * ps
                self.page_base[rid][layer] = start // ps
            else:
                w, start = None, 0
            for t in range(max(t0, start), t1):
                if kind == "local":
                    if t < s - w:
                        kq, vq = np.zeros_like(k[0]), np.zeros_like(v[0])
                        kss, vss = np.zeros_like(ksc[0]), np.zeros_like(vsc[0])
                    else:
                        kq, vq = k[t % w], v[t % w]
                        kss, vss = ksc[t % w], vsc[t % w]
                else:
                    kq, vq, kss, vss = k[t], v[t], ksc[t], vsc[t]
                self._append_layer_token(rid, layer, kq, vq, kss, vss, t)

    def finish_prefill(self, rid: int, view: dict, s: int) -> None:
        """Final chunk bookkeeping: store recurrent-kind final states,
        stamp the sequence length, evict rolled-out local pages."""
        for layer in self.state_layers:
            self.states[rid][layer] = dict(view[layer])
        self.seq_len[rid] = s
        self.evict_rolled(rid)

    # ------------------------------------------------- seal/calibrate/pack
    def _seal(self, layer: int, pid: int) -> None:
        """Full HOT page -> COLD: re-quantize to one scale per (page, head)
        — scale amortization — then calibrate or pack."""
        from repro.core import quant, tables as ctables
        from repro.core.tables import TABLE_OVERHEAD_BITS
        pool = self.pool
        q2 = np.zeros((2, self.page_size, pool.kv_heads, pool.head_dim),
                      np.int8)
        scale2 = np.zeros((2, pool.kv_heads), np.float32)
        for kind in (0, 1):
            f = (pool.tok_q[kind, pid].astype(np.float32)
                 * pool.tok_scale[kind, pid][..., None])
            sc = np.maximum(np.abs(f).max(axis=(0, 2)), 1e-8) / 127.0
            q2[kind] = np.clip(np.round(f / sc[None, :, None]),
                               -127, 127).astype(np.int8)
            scale2[kind] = sc
        pool.seal(pid, q2, scale2)
        self._cold[layer].add(pid)
        self._mark_dirty(pid)
        if self.tables[layer][0] is not None:
            # drift monitor: every post-calibration sealed page feeds the
            # layer's symbol-frequency sketch — the same 256-bin histogram
            # calibration used, accumulated here where the page payload is
            # already in host memory (zero extra transfers; in fused mode
            # this rides the amortized seal pull)
            for kind in (0, 1):
                u = quant.to_unsigned(q2[kind]).reshape(-1)
                self.drift_hists[layer, kind] += np.bincount(u,
                                                             minlength=256)
            self.drift_pages[layer] += 1
            self._drift_changed.add(layer)
            self._pack(layer, pid)
            return
        for kind in (0, 1):
            u = quant.to_unsigned(q2[kind]).reshape(-1)
            self.hists[layer, kind] += np.bincount(u, minlength=256)
            self.hist_pages[layer, kind] += 1
        if int(self.hist_pages[layer, 0]) >= self.calib_pages:
            for kind in (0, 1):
                self.tables[layer][kind] = ctables.find_table(
                    self.hists[layer, kind], bits=8, is_activation=True)
                self.calib_bits[layer, kind] = \
                    ctables.expected_bits_per_value(self.hists[layer, kind],
                                                    self.tables[layer][kind])
            # a late-calibrating layer installs into the *current*
            # generation (its rows in older generations stay zero and are
            # never referenced: no page of this layer is PACKED yet)
            self.table_gen[layer] = self.generation
            self._table_stack = None
            self._tables_dirty = True
            self.traffic["kv_table_bytes"] += 2 * TABLE_OVERHEAD_BITS // 8
            for cold_pid in sorted(self._cold[layer]):
                self._pack(layer, cold_pid)

    def _pack(self, layer: int, pid: int) -> None:
        """COLD -> PACKED: APack-encode both kinds with the layer's
        activation tables into the pool's fixed-capacity planes."""
        from repro.core import quant
        from repro.kernels import ref as _codec
        pool = self.pool
        outs = []
        for kind in (0, 1):
            vals = quant.to_unsigned(pool.cold_q[kind, pid]).reshape(
                pool.n_streams, pool.elems_per_stream)
            ta = _codec.TableArrays.from_table(self.tables[layer][kind])
            planes = _codec.encode(jnp.asarray(vals.astype(np.int32)), ta,
                                   pool.elems_per_stream, 8)
            # apack: allow-transfer(page-seal event: encoding a sealed COLD
            # page is host work off the step critical path)
            outs.append(tuple(np.asarray(p) for p in planes))
        pool.pack(pid, tuple(np.stack([o[i] for o in outs])
                             for i in range(5)))
        self._cold[layer].discard(pid)
        self._packed[layer].add(pid)
        # stamp the generation the coding table belongs to (earliest
        # generation holding this content — stays valid across later
        # refreshes of *other* layers thanks to copy-forward stacking)
        self.page_gen[pid] = int(self.table_gen[layer])
        self.page_crc[pid] = self._plane_crc(pid)
        self._mark_dirty(pid)
        self.traffic["kv_pages_packed"] += 1

    def _plane_crc(self, pid: int) -> int:
        """Integrity checksum of a PACKED page's compressed planes + page
        scales — the page metadata companion of ``page_gen``."""
        pool = self.pool
        return m.payload_crc({"sym": pool.sym[:, pid],
                              "ofs": pool.ofs[:, pid],
                              "sym_bits": pool.sym_bits[:, pid],
                              "ofs_bits": pool.ofs_bits[:, pid],
                              "stored": pool.stored[:, pid],
                              "page_scale": pool.page_scale[:, pid]})

    @property
    def n_table_rows(self) -> int:
        """Rows in the stacked table pool: one ``2 * n_layers`` block per
        *live* generation (``gen_rows`` slot addressing — compacted, not
        one block per historical generation)."""
        return 2 * self.n_layers * (max(self.gen_rows.values()) + 1)

    def _row(self, gen: int, layer: int, kind: int) -> int:
        """Stacked-pool row of ``(gen, layer, kind)`` through the
        compacted ``gen_rows`` slot map — the ONLY way table ids reach
        the kernels, so a compaction is visible everywhere at the next
        ``step_meta``/``materialize`` build."""
        return table_row(self.gen_rows[gen], layer, kind, self.n_layers)

    def _checked_gen(self, pid: int, rid, layer: int) -> int:
        """A page's table generation, validated against the live
        ``gen_rows`` map.  Every read-side consumer (``step_meta`` table
        build, read-traffic accrual) must go through this rather than
        indexing ``gen_rows`` directly: a poisoned/stale generation is an
        *integrity failure of one request*, and it has to surface as
        ``PageIntegrityError`` (so the engine fails the owner and keeps
        serving) — never as a bare ``KeyError`` out of the compacted
        slot map."""
        gen = int(self.page_gen[pid])
        if gen not in self.gen_rows:
            self.traffic["kv_integrity_failures"] += 1
            raise m.PageIntegrityError(
                f"page {pid} of rid={rid} layer={layer} carries "
                f"poisoned table generation {gen} (live: "
                f"{sorted(self.gen_rows)}) — refusing to decode "
                "with an out-of-pool table row",
                rid=rid, layer=layer, pid=pid)
        return gen

    def _table_at(self, gen: int, layer: int, kind: int):
        """The table a page packed at generation ``gen`` was coded with."""
        if gen < len(self._gen_snapshots):
            return self._gen_snapshots[gen][layer][kind]
        return self.tables[layer][kind]

    def _live_generations(self) -> set[int]:
        """Generations that must keep a row block: the current one (new
        packs address it), generation 0 (HOT/COLD pages carry gen 0 in
        their — masked but bounds-checked — meta rows), every generation
        owning a resident PACKED page, and every generation of a page
        parked in the host spill tier (it returns at readahead and must
        still decode with its own table)."""
        live = {0, self.generation}
        for packed in self._packed:
            for pid in packed:
                live.add(int(self.page_gen[pid]))
        live |= {int(g) for g in self.spill_tier.live_gens()}
        return live

    def compact_table_rows(self) -> int:
        """Reclaim stacked-table row blocks of dead generations: after the
        budgeted re-pack migrates (or eviction frees) the last PACKED page
        coded under generation ``g``, nothing can ever reference ``g``'s
        rows again — drop it from ``gen_rows`` and renumber the surviving
        generations onto contiguous slots.  Without this the device table
        planes grow a ``2 * n_layers`` block per refresh *forever* on a
        long-running server.  Returns the number of rows reclaimed;
        on any change the stack rebuilds and the device mirror re-uploads
        at the next flush (an event, never the steady-state step)."""
        live = self._live_generations()
        kept = sorted(g for g in self.gen_rows if g in live)
        new_rows = {g: i for i, g in enumerate(kept)}
        if new_rows == self.gen_rows:
            return 0
        reclaimed = 2 * self.n_layers * (
            max(self.gen_rows.values()) - max(new_rows.values()))
        self.gen_rows = new_rows
        self._table_stack = None
        self._tables_dirty = True
        return reclaimed

    def _tables_stacked(self):
        """np table arrays stacked ``[n_live_gens * 2 * n_layers, ...]``,
        row ``table_row(gen_rows[gen], layer, kind)`` — the per-page
        table-id space of the batched gather-decode and fused-attention
        calls.  The current generation's block is the live
        ``self.tables``; earlier live blocks come from the refresh
        snapshots (copy-forward: a layer that did not refresh at
        generation g repeats its previous table there, so any (gen,
        layer) a PACKED page can reference is populated).  Rebuilt lazily
        on calibration/refresh/compaction — individual tables are
        immutable.  Uncalibrated rows stay zero and are never referenced
        (PACKED requires a table)."""
        if self._table_stack is None:
            rows = self.n_table_rows
            vm = np.zeros((rows, 17), np.int32)
            ol = np.zeros((rows, 16), np.int32)
            cm = np.zeros((rows, 17), np.int32)
            for gen in self.gen_rows:
                for layer in range(self.n_layers):
                    for kind in (0, 1):
                        t = self._table_at(gen, layer, kind)
                        if t is not None:
                            a, b, c = t.as_arrays()
                            row = self._row(gen, layer, kind)
                            vm[row], ol[row], cm[row] = a, b, c
            self._table_stack = (vm, ol, cm)
        return self._table_stack

    # ------------------------------------------- table refresh / re-pack
    def drift_status(self, layer: int) -> dict | None:
        """Drift-monitor readout for one layer: expected bits/value of the
        post-calibration sketch under the layer's *current* table vs. what
        the table promised on the histogram it was built from.  ``None``
        until the layer is calibrated and ``refresh_min_pages`` pages of
        sketch exist."""
        from repro.core import tables as ctables
        if self.tables[layer][0] is None:
            return None
        pages = int(self.drift_pages[layer])
        if pages < self.refresh_min_pages:
            return None
        cur = [ctables.expected_bits_per_value(self.drift_hists[layer, k],
                                               self.tables[layer][k])
               for k in (0, 1)]
        regress = max(cur[k] / max(float(self.calib_bits[layer, k]), 1e-9)
                      for k in (0, 1))
        return {"pages": pages, "cur_bits": cur,
                "calib_bits": [float(b) for b in self.calib_bits[layer]],
                "regression": regress}

    def check_refresh(self) -> list[int]:
        """Layers whose refresh trigger fired: sketch compression regressed
        ``refresh_threshold`` past the calibration-time expectation, or
        ``refresh_every_pages`` pages sealed since the last
        (re)calibration.  Only layers whose sketch *moved* since the last
        check are evaluated (triggers can only change state at a page
        seal), so the per-decode-step call is O(1) host work on non-seal
        steps.  ``maybe_refresh`` acts on the result."""
        due = []
        for layer in sorted(self._drift_changed):
            st = self.drift_status(layer)
            if st is None:
                continue
            if (self.refresh_every_pages is not None
                    and st["pages"] >= self.refresh_every_pages):
                due.append(layer)
            elif st["regression"] > 1.0 + self.refresh_threshold:
                due.append(layer)
        self._drift_changed.clear()
        return due

    def maybe_refresh(self) -> list[int]:
        """Check drift triggers and re-calibrate every due layer under a
        single generation bump.  Returns the refreshed layers."""
        due = self.check_refresh()
        if due:
            self._refresh(due)
        return due

    def _refresh(self, layers: list[int]) -> None:
        """Re-calibrate ``layers`` from their drift sketches: snapshot the
        current table set as generation ``G`` (copy-forward — unrefreshed
        layers repeat their table there), bump to ``G+1``, install new
        activation-mode tables via the same ``find_table`` heuristic
        calibration used, and queue every PACKED page of the refreshed
        layers for re-pack.  Old pages stay decodable throughout: their
        ``page_gen`` keeps addressing the snapshot rows until the
        (budgeted, incremental) re-pack atomically swaps their planes."""
        from repro.core import tables as ctables
        from repro.core.tables import TABLE_OVERHEAD_BITS
        self._gen_snapshots.append([list(t) for t in self.tables])
        self.generation += 1
        self.gen_rows[self.generation] = max(self.gen_rows.values()) + 1
        for layer in layers:
            for kind in (0, 1):
                self.tables[layer][kind] = ctables.find_table(
                    self.drift_hists[layer, kind], bits=8,
                    is_activation=True)
                self.calib_bits[layer, kind] = \
                    ctables.expected_bits_per_value(
                        self.drift_hists[layer, kind],
                        self.tables[layer][kind])
            self.table_gen[layer] = self.generation
            self.drift_hists[layer] = 0
            self.drift_pages[layer] = 0
            # a refreshed table ships off-chip like the original did
            self.traffic["kv_table_bytes"] += 2 * TABLE_OVERHEAD_BITS // 8
            self.traffic["kv_refresh_count"] += 1
            # newest-first: recently sealed pages are the ones whose
            # content resembles the sketch the new table was fitted to,
            # so they gain the most from migrating early (pool ids are
            # allocation-ordered — an approximate recency order)
            for pid in sorted(self._packed[layer], reverse=True):
                self._repack_queue.append((layer, pid))
        self._table_stack = None
        self._tables_dirty = True
        # a refresh can also *retire* generations (pages of the refreshed
        # layers may have been the last references) — reclaim before the
        # new stack builds so the bumped pool doesn't carry dead blocks
        self.compact_table_rows()

    def repack_pending(self, budget: int | None = None, *,
                       force: bool = False) -> int:
        """Re-code up to ``budget`` queued stale pages (all of them when
        ``budget`` is None) under their layer's current tables.  The queue
        drains across decode steps so refresh never stalls serving; pages
        freed/evicted or already re-packed since being queued are skipped.
        Returns the number of pages processed (swapped + size-gate kept;
        see ``_repack``).  ``force=True`` migrates unconditionally (e.g.
        to drain a generation for compaction)."""
        done = 0
        while self._repack_queue and (budget is None or done < budget):
            layer, pid = self._repack_queue.popleft()
            if pid not in self._packed[layer]:
                continue                      # freed/evicted since queued
            if int(self.page_gen[pid]) >= int(self.table_gen[layer]):
                continue                      # already current
            self._repack(layer, pid, force=force)
            done += 1
        if done:
            # migrations may have drained a generation's last PACKED page
            self.compact_table_rows()
        return done

    def _repack(self, layer: int, pid: int, *, force: bool = False) -> bool:
        """Decode one PACKED page with the table generation it was coded
        under and re-encode with the layer's current tables.  The swap is
        **size-gated**: if the re-code came out larger the old planes are
        kept and ``page_gen`` stays put — an old page whose content still
        matches its old table is already optimally coded, and the
        generation-versioned pool exists precisely so it can stay there
        (a later refresh re-queues and re-evaluates it).  When the swap
        happens it is atomic (whole planes + ``page_gen`` in one host-side
        critical section): pages are immutable and independently coded, so
        every reader sees a consistent (planes, table) pair and decode
        stays bit-exact mid-refresh.  Returns True if swapped."""
        from repro.kernels import ref as _codec
        pool = self.pool
        if (self.verify_on_repack
                and int(self.page_crc[pid]) != self._plane_crc(pid)):
            self.traffic["kv_integrity_failures"] += 1
            self.traffic["kv_quarantined_pages"] += 1
            raise m.PageIntegrityError(
                f"PACKED page {pid} (layer {layer}) failed checksum before "
                "re-pack — planes corrupted in place; owning request must "
                "be failed", rid=self._owner_of(pid), layer=layer, pid=pid)
        old_gen = int(self.page_gen[pid])
        old_bytes = pool.page_bytes(pid)
        old_payload = int(pool.sym_bits[:, pid].sum()
                          + pool.ofs_bits[:, pid].sum())
        outs = []
        for kind in (0, 1):
            old_t = self._table_at(old_gen, layer, kind)
            # apack: allow-transfer(budgeted re-pack event: codec round-trip
            # over sealed PACKED pages, size-gated, never on the step path)
            vals = np.asarray(_codec.decode(
                jnp.asarray(pool.sym[kind, pid]),
                jnp.asarray(pool.ofs[kind, pid]),
                jnp.asarray(pool.stored[kind, pid]),
                _codec.TableArrays.from_table(old_t),
                pool.elems_per_stream, 8))
            ta = _codec.TableArrays.from_table(self.tables[layer][kind])
            planes = _codec.encode(jnp.asarray(vals.astype(np.int32)), ta,
                                   pool.elems_per_stream, 8)
            # apack: allow-transfer(budgeted re-pack event: pulls the
            # re-encoded planes for the host pool, off the step path)
            outs.append(tuple(np.asarray(p) for p in planes))
        # the decode read happened regardless of the gate's verdict
        self.traffic["kv_repack_read_bytes"] += old_bytes
        new_payload = int(sum(int(o[2].sum()) + int(o[3].sum())
                              for o in outs))
        if not force and new_payload >= old_payload:
            self.traffic["kv_repack_kept"] += 1
            return False
        pool.repack(pid, tuple(np.stack([o[i] for o in outs])
                               for i in range(5)))
        self.page_gen[pid] = int(self.table_gen[layer])
        self.page_crc[pid] = self._plane_crc(pid)
        self._mark_dirty(pid)
        # the re-pack write is off-chip traffic too — both legs accounted
        # under their own counters, never folded into the attention-read
        # stream ratios (see traffic init)
        self.traffic["kv_repack_write_bytes"] += pool.page_bytes(pid)
        self.traffic["kv_repack_pages"] += 1
        return True

    def refresh_step(self, budget: int | None = None) -> dict:
        """Engine decode-loop hook: check triggers, refresh due tables
        (one generation bump for the whole batch), re-pack up to
        ``budget`` stale pages, and push the results to the device mirror.
        Host-side only — no device_get; the steady-state zero-d2h
        invariant of the fused loop is preserved with refresh active."""
        refreshed = self.maybe_refresh()
        repacked = self.repack_pending(budget)
        if refreshed or repacked:
            self._flush_device()
        return {"refreshed_layers": refreshed, "repacked": repacked}

    # ------------------------------------------------- state snapshots
    def snapshot_state(self, rid: int) -> dict:
        """Engine checkpoint/preemption path: APack-compress the request's
        fixed-size recurrent/mLSTM/sLSTM states.  Bit-exact lossless — f32
        byte planes through the coder with *weight-mode* tables (the full
        state is profiled at snapshot time, so the §VI activation slack is
        unnecessary; same heuristic choice as ``compress_params`` for
        weights).  Attention KV needs no snapshotting: it already lives
        compressed in the page pool."""
        from repro.core import byteplane
        manifest: list[tuple[int, str, tuple[int, ...]]] = []
        parts: list[np.ndarray] = []
        for layer in self.state_layers:
            st = self.states[rid].get(layer)
            if st is None:
                raise RuntimeError(
                    f"request {rid} has no state for layer {layer} "
                    "(prefill not ingested?)")
            for f in sorted(st):
                arr = np.ascontiguousarray(st[f], np.float32)
                manifest.append((layer, f, arr.shape))
                parts.append(arr.reshape(-1))
        if not parts:
            return {"manifest": [], "planes": None}
        # one stream per snapshot, not one per (field, plane): the 298-byte
        # table overhead amortizes over the whole state, and every byte
        # that will ever be encoded is in the histogram (weight mode)
        flat = np.concatenate(parts)
        planes = byteplane.compress_float(flat, table_mode="weight")
        self.traffic["state_raw_bytes"] += flat.nbytes
        self.traffic["state_snapshot_bytes"] += planes.total_bits // 8
        self.traffic["state_snapshots"] += 1
        return {"manifest": manifest, "planes": planes}

    def restore_state(self, rid: int, snap: dict) -> None:
        """Decompress a ``snapshot_state`` blob back into the request's
        live state store (bit-exact: resumed decode == uninterrupted)."""
        from repro.core import byteplane
        if snap["planes"] is None:
            return
        flat = byteplane.decompress_float(snap["planes"])
        off = 0
        for layer, f, shape in snap["manifest"]:
            n = int(np.prod(shape))
            self.states[rid].setdefault(layer, {})[f] = \
                flat[off:off + n].reshape(shape).copy()
            off += n

    # --------------------------------------------------- host spill tier
    def _owner_of(self, pid: int) -> int | None:
        """Request owning a resident page (integrity-failure attribution;
        O(requests × pages) but only runs on a corruption path)."""
        for rid, layers in self.page_tables.items():
            for pids in layers:
                if pid in pids:
                    return rid
        return None

    def spilled_pages(self, rid: int) -> int:
        """SPILLED page-table entries of a request (kv_stats accounting)."""
        return sum(1 for pids in self.page_tables[rid]
                   for pid in pids if pid < 0)

    def request_last_read(self, rid: int) -> int:
        """Read-clock stamp of the request's most recently read page —
        the cold-LRU key for pressure victim selection (lower == colder)."""
        last = 0
        for layer in self.attn_layers:
            for pid in self.page_tables[rid][layer]:
                if pid >= 0:
                    last = max(last, int(self.page_last_read[pid]))
        return last

    def spill_request(self, rid: int) -> int:
        """Park every page of (a preempted) request ``rid`` in the host
        spill tier, compressed: PACKED pages move as their APack planes,
        COLD as page-requantized int8, partial HOT as per-token int8.
        Page-table entries become SPILLED (negative handle refs) and the
        pool slots return to the free list — this is what turns pool
        capacity into a cache under pressure.  Returns pages spilled.

        Never call for an *active* slot: the fused kernel reads every
        resident page each step (``step_meta`` raises on SPILLED
        entries)."""
        if self.dev is not None:
            self.sync_hot_to_host([rid])      # HOT payload truth -> host
        if self.faults is not None:
            d = self.faults.spill_delay()
            if d:
                time.sleep(d)
        n = 0
        for layer in self.attn_layers:
            pids = self.page_tables[rid][layer]
            for i, pid in enumerate(pids):
                if pid < 0:
                    continue                  # already spilled
                pids[i] = self._spill_page(rid, layer, pid)
                n += 1
        if n:
            self.traffic["kv_spill_calls"] += 1
        return n

    def _spill_page(self, rid: int, layer: int, pid: int) -> int:
        st, fill, payload, comp = self.pool.spill(pid)
        raw = self.pool.dense_bytes(fill if st == m.PAGE_HOT
                                    else self.page_size)
        rec = m.SpillRecord(state=st, fill=fill, layer=layer,
                            gen=int(self.page_gen[pid]), payload=payload,
                            comp_bytes=comp, raw_bytes=raw,
                            meta={"rid": rid, "pid": pid})
        handle = self.spill_tier.put(rec)
        self._cold[layer].discard(pid)
        self._packed[layer].discard(pid)
        self.page_gen[pid] = 0
        self.page_crc[pid] = 0
        self.traffic["kv_spill_bytes"] += comp
        self.traffic["kv_spill_raw_bytes"] += raw
        self.traffic["kv_spill_pages"] += 1
        return -handle - 1

    def unspill_request(self, rid: int) -> list[int]:
        """Readahead: restore every SPILLED page of ``rid`` into fresh
        pool slots ahead of the fused kernel's reads — checksum-verified,
        then pushed to the device mirror in ONE batched h2d flush.  Runs
        at resume/admission (an *event*), never inside the steady-state
        decode step, so the zero-``device_get`` invariant holds.

        A checksum mismatch quarantines the record in the tier and raises
        ``PageIntegrityError`` carrying ``rid`` — the engine fails only
        the owning request; already-restored pages stay consistent (their
        table entries were rewritten as they were adopted) so release
        cleans up normally and neighbors never see the corruption."""
        restored: list[int] = []
        for layer in self.attn_layers:
            pids = self.page_tables[rid][layer]
            for i, entry in enumerate(pids):
                if entry >= 0:
                    continue
                handle = -entry - 1
                try:
                    rec = self.spill_tier.get(handle)
                except m.PageIntegrityError as e:
                    self.traffic["kv_integrity_failures"] += 1
                    self.traffic["kv_quarantined_pages"] += 1
                    raise m.PageIntegrityError(
                        f"unspill of rid={rid} layer={layer} page {i}: "
                        f"{e}", rid=rid, layer=layer, handle=handle) from e
                pid = self.pool.adopt(rec.state, rec.fill, rec.payload,
                                      shard=self.request_shard.get(rid, 0))
                pids[i] = pid
                self.page_gen[pid] = rec.gen
                if rec.state == m.PAGE_PACKED:
                    self._packed[layer].add(pid)
                    self.page_crc[pid] = self._plane_crc(pid)
                    if rec.gen < int(self.table_gen[layer]):
                        # packed under a since-refreshed table: still
                        # decodable via its generation row; queue for the
                        # budgeted migration like any stale resident page
                        self._repack_queue.append((layer, pid))
                elif rec.state == m.PAGE_COLD:
                    self._cold[layer].add(pid)
                    if self.tables[layer][0] is not None:
                        self._pack(layer, pid)   # table arrived while parked
                self._mark_dirty(pid)
                self.spill_tier.drop(handle)
                self.traffic["kv_readahead_pages"] += 1
                self.traffic["kv_readahead_bytes"] += \
                    self.pool.page_bytes(pid)
                restored.append(pid)
        if restored:
            self.traffic["kv_readahead_calls"] += 1
            self._flush_device()              # one batched h2d, pre-kernel
        return restored

    # ---------------------------------------------- device-resident mode
    def _transfer_guard(self, direction: str) -> None:
        """Fault-injection hook on the host<->device boundary: a dropped
        transfer is retried up to ``transfer_retries`` times (each drop
        and retry accounted) before the failure propagates."""
        if self.faults is None:
            return
        for attempt in range(self.transfer_retries + 1):
            try:
                self.faults.check_transfer(direction)
                if attempt:
                    self.traffic["kv_transfer_retries"] += attempt
                return
            except m.TransferDropped:
                self.traffic["kv_transfer_drops"] += 1
                if attempt == self.transfer_retries:
                    raise

    # apack: allow-transfer(sole accounted d2h funnel: every KV pull rides
    # this wrapper so the bench ledger and the zero-device_get gates see it)
    def _fetch(self, tree):
        """``jax.device_get`` with transfer accounting (pytrees allowed,
        one call).  Every device->host byte the KV path moves goes
        through here — the decode bench and the steady-state
        zero-``device_get`` guard read these counters."""
        self._transfer_guard("d2h")
        out = jax.device_get(tree)
        self.transfers["d2h_calls"] += 1
        self.transfers["d2h_bytes"] += sum(
            np.asarray(x).nbytes for x in jax.tree.leaves(out))
        return out

    def _put(self, x):
        """host -> device with transfer accounting (counterpart of
        ``_fetch``)."""
        self._transfer_guard("h2d")
        arr = jnp.asarray(x)
        self.transfers["h2d_calls"] += 1
        self.transfers["h2d_bytes"] += int(arr.size) * arr.dtype.itemsize
        return arr

    def enable_device_pool(self, max_batch: int, mesh=None) -> None:
        """Switch to device-resident decode: mirror the pool planes on
        device (read by the fused kernel, written by the on-device
        append) and allocate the device state store for recurrent-kind
        layers.  Host numpy remains the seal/pack + invariant mirror.

        With ``mesh``: planes place under ``sharding.plane_pspecs`` (page
        shards over "data" matching the per-shard free lists).  The state
        store starts unplaced — the sharded step's out_specs pin it from
        the first step on."""
        self.dev = DevicePoolPlanes(self.pool, max(1, self.n_table_rows),
                                    mesh=mesh)
        self.dev_states = init_state_store(self.cfg, max_batch)
        self._sync_tables_to_device()

    def _mark_dirty(self, pid: int) -> None:
        if self.dev is not None:
            self._dirty.add(pid)

    def _sync_tables_to_device(self) -> None:
        vm, ol, cm = self._tables_stacked()
        n = vm.shape[0]
        # a refresh past the current capacity reallocates the device table
        # planes (doubling -> O(log generations) decode-jit recompiles,
        # each at a refresh boundary, never in the steady-state loop)
        self.dev.ensure_table_capacity(n)
        d = self.dev.planes
        d["vm"] = d["vm"].at[:n].set(self._put(vm))
        d["ol"] = d["ol"].at[:n].set(self._put(ol))
        d["cum"] = d["cum"].at[:n].set(self._put(cm))
        self._tables_dirty = False

    def sync_pages_to_device(self, pids) -> None:
        """Push pages' current-state payloads into the device mirror —
        called at page *events* (seal, pack, prefill ingest), never in
        the steady-state decode loop.  Batched per lifecycle state: on a
        mesh, ONE fused scatter program per group (every plane of the
        state at once), not one eager dispatch per plane — each eager
        ``.at[].set`` there is a full SPMD dispatch, so a PACKED seal's
        8 plane writes would pay 8× the launch overhead; the page-id
        vector pads to a power-of-two bucket by repeating the last id
        (rewriting an identical payload row is idempotent), keeping the
        jit cache log-bounded in group size.  Without a mesh the planes
        stay on the eager per-plane path: single-device dispatch is
        ~100x cheaper than the fused program's one-off XLA compile, and
        that compile landing mid-serve would poison step-time baselines
        (the engine watchdog's trailing window)."""
        pool = self.pool
        groups: dict[int, list[int]] = {}
        for pid in pids:
            groups.setdefault(int(pool.state[pid]), []).append(pid)
        fused = self.dev.mesh is not None
        if fused and self._plane_push is None:
            def _push(d, idx, pay):
                return {k: d[k].at[idx].set(v) for k, v in pay.items()}
            self._plane_push = jax.jit(_push)
        for st, group in groups.items():
            if st == m.PAGE_FREE:
                continue
            if fused:
                b = 1 << max(len(group) - 1, 0).bit_length()
                group = group + [group[-1]] * (b - len(group))
            idx = jnp.asarray(np.asarray(group, np.int32))
            if st == m.PAGE_HOT:
                pay = {"tok_k": pool.tok_q[0, group],
                       "tok_v": pool.tok_q[1, group],
                       "tok_sk": pool.tok_scale[0, group],
                       "tok_sv": pool.tok_scale[1, group]}
            elif st == m.PAGE_COLD:
                pay = {"cold_k": pool.cold_q[0, group],
                       "cold_v": pool.cold_q[1, group]}
            elif st == m.PAGE_PACKED:
                pay = {"sym_k": pool.sym[0, group],
                       "sym_v": pool.sym[1, group],
                       "ofs_k": pool.ofs[0, group],
                       "ofs_v": pool.ofs[1, group],
                       "stored_k": pool.stored[0, group].astype(np.int32),
                       "stored_v": pool.stored[1, group].astype(np.int32)}
            if st in (m.PAGE_COLD, m.PAGE_PACKED):
                pay["pscale_k"] = pool.page_scale[0, group]
                pay["pscale_v"] = pool.page_scale[1, group]
            d = self.dev.planes
            if fused:
                self.dev.planes = dict(d, **self._plane_push(
                    {k: d[k] for k in pay}, idx,
                    {k: self._put(v) for k, v in pay.items()}))
            else:
                for k, v in pay.items():
                    d[k] = d[k].at[idx].set(self._put(v))

    def _flush_device(self) -> None:
        if self.dev is None:
            return
        changed = self._tables_dirty or bool(self._dirty)
        if self._tables_dirty:
            self._sync_tables_to_device()
        if self._dirty:
            self.sync_pages_to_device(sorted(self._dirty))
            self._dirty.clear()
        if changed:
            # mesh mode: eager event scatters can degrade plane layouts;
            # repin here (no-op without a mesh) so the next sharded step
            # sees canonical partitioning instead of an implicit reshard
            self.dev.repin()

    def sync_request_to_device(self, rid: int) -> None:
        """Admission-time push: every page of a freshly-ingested request
        (HOT partials included) plus any pending seal/pack results."""
        if self.dev is None:
            return
        self._flush_device()
        self.sync_pages_to_device(sorted(
            {pid for layer in self.attn_layers
             for pid in self.page_tables[rid][layer] if pid >= 0}))

    def sync_hot_to_host(self, slot_rids=None) -> None:
        """Pull device-resident HOT page payloads back into the host pool
        mirror — the materialize/oracle path and state snapshots need the
        host view; a steady-state decode step never calls this."""
        if self.dev is None:
            return
        rids = [r for r in (slot_rids if slot_rids is not None
                            else list(self.page_tables)) if r is not None]
        pids = sorted({pid for rid in rids for layer in self.attn_layers
                       for pid in self.page_tables[rid][layer]
                       if pid >= 0
                       and self.pool.state[pid] == m.PAGE_HOT
                       and self.pool.fill[pid] > 0})
        if not pids:
            return
        d = self.dev.planes
        idx = jnp.asarray(np.asarray(pids, np.int32))
        kq, vq, ks, vs = self._fetch((d["tok_k"][idx], d["tok_v"][idx],
                                      d["tok_sk"][idx], d["tok_sv"][idx]))
        for i, pid in enumerate(pids):
            self.pool.tok_q[0, pid] = kq[i]
            self.pool.tok_q[1, pid] = vq[i]
            self.pool.tok_scale[0, pid] = ks[i]
            self.pool.tok_scale[1, pid] = vs[i]

    # ------------------------------------------- device-resident appends
    def claim_append_targets(self, slot_rids: list) -> dict:
        """Host-metadata half of the on-device append: allocate/locate the
        (page, offset) each attention layer's new token scatters into.
        Returns a pytree shaped like ``decode_step_paged``'s new-cache
        (``None`` at recurrent-kind positions); idle slots carry the
        out-of-range page sentinel, dropped by the scatter."""
        b = len(slot_rids)
        sentinel = self.pool.num_pages
        per_layer = {layer: (np.full(b, sentinel, np.int32),
                             np.zeros(b, np.int32))
                     for layer in self.attn_layers}
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            t = self.seq_len[rid]
            for layer in self.attn_layers:
                per_layer[layer][0][slot] = self._claim_page(rid, layer, t)
                per_layer[layer][1][slot] = t % self.page_size
        prefix = [(self._put(per_layer[i][0]), self._put(per_layer[i][1]))
                  if kind in ATTN_KINDS else None
                  for i, kind in enumerate(self.cfg.prefix_pattern)]
        blocks = []
        for c, kind in enumerate(self.cfg.cycle):
            if kind not in ATTN_KINDS:
                blocks.append(None)
                continue
            layers = [self.n_prefix + j * self.n_cycle + c
                      for j in range(self.n_stack)]
            blocks.append((self._put(np.stack([per_layer[l][0]
                                               for l in layers])),
                           self._put(np.stack([per_layer[l][1]
                                               for l in layers]))))
        return {"prefix": prefix, "blocks": tuple(blocks)}

    def note_appended(self, slot_rids: list) -> None:
        """Metadata half of the on-device append (fused-path analogue of
        ``append_token``): advance fills and sequence lengths, seal pages
        that just filled (pulling their payload from the device mirror —
        the only steady-state d2h, amortized over ``page_size`` steps),
        evict rolled-out pages, and push freshly sealed/packed planes
        back to the device."""
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            for layer in self.attn_layers:
                pid = self.page_tables[rid][layer][-1]
                self.pool.note_device_write(pid)
                if int(self.pool.fill[pid]) == self.page_size:
                    self._seal_from_device(layer, pid)
            self.seq_len[rid] += 1
            self.evict_rolled(rid)
        self._flush_device()

    def _seal_from_device(self, layer: int, pid: int) -> None:
        d = self.dev.planes
        if self.dev.mesh is None:
            # plain eager gather: compiles in microseconds per pid and the
            # single-device executables are trivial, so no jit is worth a
            # multi-second compile landing mid-serve (it would poison the
            # straggler watchdog's step-time baseline)
            kq, vq, ks, vs = self._fetch((d["tok_k"][pid], d["tok_v"][pid],
                                          d["tok_sk"][pid], d["tok_sv"][pid]))
        else:
            # on a sharded plane the page index must be a *traced* operand:
            # a static python index bakes the pid into the jaxpr, and every
            # distinct pid would pay a fresh SPMD partitioning compile (a
            # recompile storm that dwarfs the seal itself); one dynamic-slice
            # executable serves every page.  Only the four token staging
            # planes are operands — passing the whole planes dict would
            # recompile whenever ensure_table_capacity reallocates the
            # table planes
            if self._page_pull is None:
                self._page_pull = jax.jit(lambda tk, tv, sk, sv, i: (
                    tk[i], tv[i], sk[i], sv[i]))
            kq, vq, ks, vs = self._fetch(self._page_pull(
                d["tok_k"], d["tok_v"], d["tok_sk"], d["tok_sv"],
                jnp.asarray(pid, jnp.int32)))
        self.pool.tok_q[0, pid] = kq
        self.pool.tok_q[1, pid] = vq
        self.pool.tok_scale[0, pid] = ks
        self.pool.tok_scale[1, pid] = vs
        self._seal(layer, pid)

    # ------------------------------------------- device-resident states
    def read_state_slot(self, slot: int) -> dict:
        """Fetch one slot's recurrent-kind states from the device store
        (preemption/snapshot boundary — never the steady-state loop)."""
        picked = {}
        for layer in self.state_layers:
            leaf, j = self._layer_cache(self.dev_states, layer)
            picked[layer] = {f: (x[slot] if j is None else x[j, slot])
                             for f, x in leaf.items()}
        fetched = self._fetch(picked)
        return {layer: {f: np.asarray(v) for f, v in d.items()}
                for layer, d in fetched.items()}

    def write_state_slot(self, slot: int, rid: int) -> None:
        """Push ``self.states[rid]`` (prefill ingest / snapshot restore)
        into the device state store at ``slot``."""
        for layer in self.state_layers:
            st = self.states[rid].get(layer)
            if st is None:
                raise RuntimeError(
                    f"request {rid} has no state for layer {layer} "
                    "(prefill not ingested?)")
            leaf, j = self._layer_cache(self.dev_states, layer)
            for f, v in st.items():
                arr = self._put(np.ascontiguousarray(v))
                leaf[f] = (leaf[f].at[slot].set(arr) if j is None
                           else leaf[f].at[j, slot].set(arr))

    def _pull_states(self, slot_rids: list) -> None:
        if self.dev_states is None or not self.state_layers:
            return
        for slot, rid in enumerate(slot_rids):
            if rid is not None and rid in self.states:
                self.states[rid] = self.read_state_slot(slot)

    # --------------------------------------------------- step metadata
    def meta_pages(self, max_len: int, slot_rids: list | None = None) -> int:
        """Page-slot count of the fused kernel's grid.  Without
        ``slot_rids``: the static worst case for the full context.  With
        ``slot_rids``: the power-of-two bucket over the busiest active
        slot's *occupied* page count (``kernels.paged_decode.page_bucket``)
        capped at the worst case — a batch of mostly-short requests stops
        paying the max-pages grid.  Bit-exact either way: slots past a
        request's table mask via state == FREE, and a fully-masked page
        leaves the online-softmax accumulator unchanged.  Grid sizes
        bucket to powers of two so the decode jit compiles O(log pages)
        variants, with the same recompile-storm guard as the gather."""
        from repro.kernels.paged_decode import page_bucket
        pmax = max(1, self.pages_per_seq(max_len))
        if slot_rids is None:
            return pmax
        used = 1
        for rid in slot_rids:
            if rid is None or rid not in self.page_tables:
                continue
            for layer in self.attn_layers:
                used = max(used, len(self.page_tables[rid][layer]))
        return min(pmax, page_bucket(used))

    def step_meta(self, slot_rids: list, max_len: int) -> dict:
        """Per-step page-table metadata for ``decode_step_paged`` — the
        only per-step host->device upload of the fused path (a few i32
        per page slot).  Also accrues the read-traffic counters the
        materialize path would have charged (same pages are read, just
        decoded at point of use)."""
        b = len(slot_rids)
        pmax = self.meta_pages(max_len, slot_rids)
        ps = self.page_size
        per_layer = {}
        for layer in self.attn_layers:
            per_layer[layer] = {
                "pid": np.zeros((b, pmax), np.int32),
                "tid": np.full((b, pmax), 2 * layer, np.int32),
                "state": np.zeros((b, pmax), np.int32),     # FREE: masked
                "t0": np.zeros((b, pmax), np.int32),
                "qw": np.zeros((b, 2), np.int32),
            }
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            qpos = self.seq_len[rid]
            for layer in self.attn_layers:
                kind = self.layer_kinds[layer]
                d = per_layer[layer]
                base = self.page_base[rid][layer]
                for k_, pid in enumerate(self.page_tables[rid][layer]):
                    d["pid"][slot, k_] = pid
                    # K-row of the (generation, layer, kind) table id the
                    # page was coded under (V row = +1 in-kernel); pages
                    # from different refresh generations coexist per step
                    d["tid"][slot, k_] = self._row(
                        self._checked_gen(pid, rid, layer), layer, 0)
                    d["state"][slot, k_] = int(self.pool.state[pid])
                    d["t0"][slot, k_] = (base + k_) * ps
                d["qw"][slot] = (qpos, self._ring(max_len)
                                 if kind == "local" else 0)
        self._accrue_read_traffic(slot_rids, max_len)

        def pack(layer_arrs):
            return {k: self._put(v) for k, v in layer_arrs.items()}

        prefix = [pack(per_layer[i]) if kind in ATTN_KINDS else {}
                  for i, kind in enumerate(self.cfg.prefix_pattern)]
        blocks = []
        for c, kind in enumerate(self.cfg.cycle):
            if kind not in ATTN_KINDS:
                blocks.append({})
                continue
            layers = [self.n_prefix + j * self.n_cycle + c
                      for j in range(self.n_stack)]
            blocks.append({k: self._put(np.stack([per_layer[l][k]
                                                  for l in layers]))
                           for k in per_layer[layers[0]]})
        return {"prefix": prefix, "blocks": tuple(blocks)}

    def _accrue_read_traffic(self, slot_rids: list, max_len: int) -> None:
        """Charge the per-step KV read traffic (shared by materialize and
        the fused path — both read the same pages, the fused path just
        decodes them at point of use).  Partially-rolled-out pages of
        local layers charge only their *live token range* — the sub-page
        read accounting that reclaims the ``(ps-1)/window`` overhead
        (sub-page decode itself stays whole-page)."""
        pool, ps = self.pool, self.page_size
        raw = {"global": 0, "local": 0}
        read = {"global": 0, "local": 0}
        self._read_clock += 1
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            qpos = self.seq_len[rid]
            for layer in self.attn_layers:
                kind = self.layer_kinds[layer]
                base = self.page_base[rid][layer]
                for k_, pid in enumerate(self.page_tables[rid][layer]):
                    if pid < 0:
                        raise m.PageIntegrityError(
                            f"active request {rid} layer {layer} page {k_} "
                            "is SPILLED at read time — readahead must "
                            "restore before decode", rid=rid, layer=layer)
                    self._checked_gen(pid, rid, layer)
                    self.page_last_read[pid] = self._read_clock
                    t0 = (base + k_) * ps
                    state = pool.state[pid]
                    n_tok = (int(pool.fill[pid]) if state == m.PAGE_HOT
                             else ps)
                    if kind == "local":
                        n_live = int(np.sum(np.arange(t0, t0 + n_tok)
                                            >= qpos - self._ring(max_len)))
                    else:
                        n_live = n_tok
                    raw[kind] += pool.dense_bytes(n_live)
                    charged = pool.page_bytes(pid)
                    if n_live < n_tok:
                        charged = -(-charged * n_live // n_tok)
                    read[kind] += charged
        for kind in ("global", "local"):
            self.traffic[f"kv_raw_bytes_{kind}"] += raw[kind]
            self.traffic[f"kv_read_bytes_{kind}"] += read[kind]
        self.traffic["kv_raw_bytes"] += raw["global"] + raw["local"]
        self.traffic["kv_read_bytes"] += read["global"] + read["local"]

    # -------------------------------------------------------- materialize
    def materialize(self, slot_rids: list, max_len: int) -> dict:
        """Rebuild the dense cache pytree for the active batch.

        Attention layers: HOT/COLD pages copy straight from the pool;
        PACKED pages decode in ONE batched Pallas gather-decode call per
        K/V kind (page-index + table-id vectors padded to a jit bucket),
        spanning every layer.  Global layers land at absolute positions,
        rolling layers in the ring slot ``pos % ring`` with dead positions
        skipped.  Recurrent-kind layers stitch the stored per-request
        states (init template for empty slots).  Also accrues the
        per-stream raw-vs-actual read-traffic counters."""
        from repro.core import quant
        from repro.kernels.paged_decode import gather_bucket, gather_decode
        pool = self.pool
        if self.dev is not None:
            # device-resident mode: HOT payloads + states live on device;
            # the materialize/oracle path needs the host mirror current
            self.sync_hot_to_host(slot_rids)
            self._pull_states(slot_rids)
        self._accrue_read_traffic(slot_rids, max_len)
        b = len(slot_rids)
        h, dh, ps = pool.kv_heads, pool.head_dim, self.page_size

        def span(kind):
            return max_len if kind == "global" else self._ring(max_len)

        kvq = {layer: np.zeros((2, b, span(self.layer_kinds[layer]), h, dh),
                               np.int8) for layer in self.attn_layers}
        kvs = {layer: np.zeros((2, b, span(self.layer_kinds[layer]), h),
                               np.float32) for layer in self.attn_layers}

        def place(layer, kind01, slot, t0, n_tok, q, sc, qpos):
            """q: [n_tok, H, dh], sc: [n_tok, H] -> dense-cache layout."""
            kind = self.layer_kinds[layer]
            if kind == "global":
                n_tok = min(n_tok, max_len - t0)
                kvq[layer][kind01, slot, t0:t0 + n_tok] = q[:n_tok]
                kvs[layer][kind01, slot, t0:t0 + n_tok] = sc[:n_tok]
            else:
                ring = kvq[layer].shape[2]
                a = np.arange(t0, t0 + n_tok)
                live = a >= qpos - ring
                if live.any():
                    kvq[layer][kind01, slot, a[live] % ring] = q[live]
                    kvs[layer][kind01, slot, a[live] % ring] = sc[live]

        jobs: list[tuple] = []           # (layer, pid, slot, t0, qpos)
        for slot, rid in enumerate(slot_rids):
            if rid is None:
                continue
            qpos = self.seq_len[rid]
            for layer in self.attn_layers:
                kind = self.layer_kinds[layer]
                base = self.page_base[rid][layer]
                for k_, pid in enumerate(self.page_tables[rid][layer]):
                    t0 = (base + k_) * ps
                    state = pool.state[pid]
                    n_tok = (int(pool.fill[pid]) if state == m.PAGE_HOT
                             else ps)
                    if state == m.PAGE_HOT:
                        for kind01 in (0, 1):
                            place(layer, kind01, slot, t0, n_tok,
                                  pool.tok_q[kind01, pid, :n_tok],
                                  pool.tok_scale[kind01, pid, :n_tok], qpos)
                    elif state == m.PAGE_COLD:
                        for kind01 in (0, 1):
                            place(layer, kind01, slot, t0, ps,
                                  pool.cold_q[kind01, pid],
                                  np.broadcast_to(
                                      pool.page_scale[kind01, pid][None],
                                      (ps, h)), qpos)
                    else:
                        jobs.append((layer, pid, slot, t0, qpos))
        if jobs:
            vm, ol, cm = self._tables_stacked()
            idx = np.asarray([pid for _, pid, _, _, _ in jobs], np.int32)
            g = gather_bucket(len(idx))
            pad = (0, g - len(idx))
            idx_p = self._put(np.pad(idx, pad, mode="edge"))
            for kind01 in (0, 1):
                tid = np.asarray([self._row(int(self.page_gen[pid]), layer,
                                            kind01)
                                  for layer, pid, *_ in jobs], np.int32)
                out = gather_decode(
                    self._put(pool.sym[kind01]),
                    self._put(pool.ofs[kind01]),
                    self._put(pool.stored[kind01]), idx_p,
                    self._put(vm), self._put(ol), self._put(cm),
                    n_steps=pool.elems_per_stream, backend=self.backend,
                    table_idx=self._put(np.pad(tid, pad, mode="edge")))
                vals = self._fetch(out)[:len(jobs)].astype(np.uint8)
                q = quant.from_unsigned(vals).reshape(len(jobs), ps, h, dh)
                for i, (layer, pid, slot, t0, qpos) in enumerate(jobs):
                    place(layer, kind01, slot, t0, ps, q[i],
                          np.broadcast_to(pool.page_scale[kind01, pid][None],
                                          (ps, h)), qpos)

        def attn_leaves(layer):
            return {"k": kvq[layer][0], "v": kvq[layer][1],
                    "k_scale": kvs[layer][0], "v_scale": kvs[layer][1]}

        def state_leaves(layer):
            tmpl = self._state_template(self.layer_kinds[layer])
            out = {}
            for f, t0_ in tmpl.items():
                rows = []
                for rid in slot_rids:
                    st = self.states[rid].get(layer) if rid is not None \
                        else None
                    rows.append(st[f] if st is not None else t0_)
                out[f] = np.stack(rows)
            return out

        prefix = []
        for i in range(self.n_prefix):
            leaves = (attn_leaves(i) if self.layer_kinds[i] in ATTN_KINDS
                      else state_leaves(i))
            prefix.append({f: self._put(x) for f, x in leaves.items()})
        blocks = []
        for c in range(self.n_cycle):
            layers = [self.n_prefix + j * self.n_cycle + c
                      for j in range(self.n_stack)]
            if self.cfg.cycle[c] in ATTN_KINDS:
                per = [attn_leaves(l) for l in layers]
            else:
                per = [state_leaves(l) for l in layers]
            blocks.append({f: self._put(np.stack([p[f] for p in per]))
                           for f in per[0]})
        return {"prefix": prefix, "blocks": tuple(blocks)}

from .checkpoint import save, restore, latest_step, AsyncCheckpointer

"""Checkpointing: per-leaf files, atomic commit, async save, optional
lossless APack compression, and elastic (reshard-on-restore) loading.

Layout::

    <dir>/step_0000123/
        manifest.json      # tree structure, dtypes, shapes, codec per leaf
        leaf_00000.bin     # raw bytes or APack byteplane container
        ...
        extra.json         # user state (data-pipeline cursors, rng, ...)
    <dir>/LATEST           # atomically updated pointer

APack compression (beyond paper — see core/byteplane.py): float leaves are
split into byte planes and each plane is losslessly coded; exponent planes
of trained weights compress 1.3-2x, mantissa planes fall back to stored
mode.  Restore is bit-exact.  On a real cluster this directly cuts
checkpoint-restore network time — the fault-tolerance path's main cost.
"""
from __future__ import annotations

import dataclasses
import json
import pickle
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import byteplane
from repro.core import format as fmt

_BF16 = "bfloat16"


def _leaf_to_numpy(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def _save_leaf(path: Path, arr: np.ndarray, compress: bool) -> dict:
    info: dict[str, Any] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    if compress and arr.dtype.kind == "f" and arr.size >= 4096:
        cp = byteplane.compress_float(arr)
        if cp.total_bits < arr.nbytes * 8 * 0.98:
            with open(path, "wb") as f:
                pickle.dump(cp, f)
            info["codec"] = "apack_byteplane"
            info["stored_bits"] = cp.total_bits
            return info
        # compression would not pay (container overhead) -> fall through
    raw = arr.view(np.uint16) if str(arr.dtype) == _BF16 else arr
    with open(path, "wb") as f:
        np.save(f, raw, allow_pickle=False)
    info["codec"] = "raw"
    info["stored_bits"] = int(arr.nbytes * 8)
    return info


def _load_leaf(path: Path, info: dict) -> np.ndarray:
    if info["codec"] == "apack_byteplane":
        with open(path, "rb") as f:
            cp = pickle.load(f)
        return byteplane.decompress_float(cp)
    with open(path, "rb") as f:
        raw = np.load(f, allow_pickle=False)
    if info["dtype"] == _BF16:
        raw = raw.view(jnp.bfloat16)
    return raw.reshape(info["shape"])


def save(ckpt_dir: str | Path, step: int, tree: Any,
         extra: dict | None = None, compress: bool = False,
         keep: int = 3) -> Path:
    """Atomic checkpoint write.  ``tree`` may be any pytree of arrays."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = jax.tree.flatten(tree)
    manifest = {"step": step, "treedef": str(treedef), "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = _leaf_to_numpy(leaf)
        name = f"leaf_{i:05d}"
        info = _save_leaf(tmp / name, arr, compress)
        info["name"] = name
        manifest["leaves"].append(info)
    with open(tmp / "treedef.pkl", "wb") as f:
        pickle.dump(treedef, f)
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    with open(tmp / "extra.json", "w") as f:
        json.dump(extra or {}, f)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                               # atomic commit
    latest = ckpt_dir / "LATEST"
    tmp_latest = ckpt_dir / ".LATEST.tmp"
    tmp_latest.write_text(final.name)
    tmp_latest.rename(latest)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    pointer = ckpt_dir / "LATEST"
    if not pointer.exists():
        return None
    name = pointer.read_text().strip()
    if not (ckpt_dir / name / "manifest.json").exists():
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str | Path, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict, int]:
    """Load a checkpoint; if ``shardings`` is given, leaves are device_put
    with those shardings — this is the elastic-rescale path: the same
    checkpoint restores onto any mesh shape."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:08d}"
    with open(d / "manifest.json") as f:
        manifest = json.load(f)
    with open(d / "treedef.pkl", "rb") as f:
        treedef = pickle.load(f)
    leaves = []
    for info in manifest["leaves"]:
        arr = _load_leaf(d / info["name"], info)
        if info["dtype"] == _BF16:
            arr = arr.astype(jnp.bfloat16) if arr.dtype != jnp.bfloat16 else arr
        leaves.append(arr)
    tree = jax.tree.unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    with open(d / "extra.json") as f:
        extra = json.load(f)
    return tree, extra, step


class AsyncCheckpointer:
    """Snapshot-on-main-thread, write-in-background checkpointer."""

    def __init__(self, ckpt_dir: str | Path, compress: bool = False,
                 keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.compress = compress
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        snapshot = jax.tree.map(_leaf_to_numpy, tree)   # sync device->host

        def work():
            try:
                save(self.ckpt_dir, step, snapshot, extra,
                     compress=self.compress, keep=self.keep)
            except Exception as e:                        # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

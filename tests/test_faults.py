"""Fault-injection tests for the serving loop under memory pressure.

Every injected fault must be *detected* (CRC quarantine, generation
guard, verify-on-repack) or *absorbed* (transfer retry, watchdog
preemption with spill) — and the blast radius of a detected corruption
is exactly ONE request: its neighbors' token streams stay bit-identical
to an uncontended control run.  Spill/readahead traffic is its own
accounting stream and must never leak into the KV read ratios."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.models import modules as m
from repro.runtime import StragglerWatchdog, WatchdogEvent
from repro.serve import (AdmissionImpossible, FaultInjector,
                         PageIntegrityError, Request, ServeEngine,
                         TransferDropped)

KEY = jax.random.PRNGKey(0)


def apack_cfg(**kw):
    return dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                               kv_cache_dtype="apack-int8", **kw)


def hetero_cfg(**kw):
    return dataclasses.replace(configs.get_hetero_smoke_config(),
                               kv_cache_dtype="apack-int8", **kw)


def _mk_engine(cfg, params, max_batch=2, max_len=32, **kw):
    return ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                       kv_page_size=4, kv_calib_pages=2, **kw)


def _random_token(rng, kv, lo=0.01, hi=0.02):
    h, dh = kv.pool.kv_heads, kv.pool.head_dim
    n = kv.n_layers
    return (rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
            rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
            rng.uniform(lo, hi, (n, h)).astype(np.float32),
            rng.uniform(lo, hi, (n, h)).astype(np.float32))


def _packed_kv(n_tokens=16):
    """A host-side cache with at least one PACKED page on layer 0."""
    cfg = apack_cfg()
    kv = M.PagedKVCache(cfg, num_pages=64, page_size=4, calib_pages=1)
    kv.add_request(0)
    rng = np.random.default_rng(3)
    for _ in range(n_tokens):
        kv.append_token(0, *_random_token(rng, kv))
    layer = kv.attn_layers[0]
    assert kv._packed[layer], "fixture never packed a page"
    return kv, layer, min(kv._packed[layer])


# --------------------------------------------------- pool + tier plumbing
class TestSpillTier:
    def test_pool_spill_adopt_roundtrip_is_bit_exact(self):
        """A PACKED page's planes survive spill -> adopt unchanged (the
        payload IS the compressed truth; no re-encode on either side)."""
        kv, layer, pid = _packed_kv()
        pool = kv.pool
        want = {pl: getattr(pool, pl)[:, pid].copy()
                for pl in ("sym", "ofs", "sym_bits", "ofs_bits", "stored")}
        want_scale = pool.page_scale[:, pid].copy()
        st, fill, payload, comp = pool.spill(pid)
        assert st == m.PAGE_PACKED and comp > 0
        assert pool.state[pid] == m.PAGE_FREE          # slot returned
        pid2 = pool.adopt(st, fill, payload)
        for pl, arr in want.items():
            assert np.array_equal(getattr(pool, pl)[:, pid2], arr), pl
        assert np.array_equal(pool.page_scale[:, pid2], want_scale)
        assert pool.state[pid2] == m.PAGE_PACKED
        assert pool.fill[pid2] == fill
        assert pool.spill_count == 1 and pool.unspill_count == 1

    def test_adopt_into_exhausted_pool_is_a_hard_error(self):
        kv, layer, pid = _packed_kv()
        st, fill, payload, _ = kv.pool.spill(pid)
        while kv.pool.free_count:
            kv.pool.alloc()
        with pytest.raises(RuntimeError, match="re-reserve"):
            kv.pool.adopt(st, fill, payload)

    def test_checksum_detects_bit_flip_and_quarantines(self):
        """One flipped bit in a parked record: get() raises, the record
        moves to quarantine (kept, never re-served), live accounting
        shrinks, and the handle is dead afterwards."""
        tier = m.HostSpillTier()
        inj = FaultInjector()
        rec = m.SpillRecord(state=m.PAGE_PACKED, fill=4, layer=0, gen=0,
                            payload={"a": np.arange(64, dtype=np.uint8),
                                     "b": np.ones(8, np.float32)},
                            comp_bytes=64, raw_bytes=256)
        h = tier.put(rec)
        assert tier.get(h) is rec                      # clean round-trip
        inj.flip_bit(tier, h, array="a", bit=13)
        with pytest.raises(PageIntegrityError, match="checksum"):
            tier.get(h)
        assert h in tier.quarantined
        assert tier.live_count == 0 and tier.live_bytes == 0
        assert tier.integrity_failures == 1
        with pytest.raises(KeyError, match="quarantined=True"):
            tier.get(h)

    def test_poisoned_generation_refused_at_read_time(self):
        """An out-of-pool table generation must never reach the decode
        kernel — the read guard fails the owning request instead."""
        kv, layer, pid = _packed_kv()
        inj = FaultInjector()
        inj.poison_generation(kv, pid)
        with pytest.raises(PageIntegrityError, match="poisoned table"):
            kv.materialize([0], 32)
        assert inj.stats["generations_poisoned"] == 1

    def test_verify_on_repack_catches_in_place_corruption(self):
        """verify_on_repack: a resident PACKED page whose planes were
        flipped under us fails its CRC *before* the re-pack decodes
        garbage into a fresh encoding."""
        cfg = apack_cfg()
        kv = M.PagedKVCache(cfg, num_pages=64, page_size=4, calib_pages=1,
                            verify_on_repack=True)
        kv.add_request(0)
        rng = np.random.default_rng(4)
        for _ in range(16):
            kv.append_token(0, *_random_token(rng, kv))
        layer = kv.attn_layers[0]
        pid = min(kv._packed[layer])
        FaultInjector().corrupt_packed_page(kv, pid, bit=5)
        with pytest.raises(PageIntegrityError, match="re-pack"):
            kv._repack(layer, pid, force=True)
        assert kv.traffic["kv_integrity_failures"] == 1

    def test_transfer_drops_are_retried_then_propagate(self):
        """The h2d/d2h boundary retries ``transfer_retries`` times; a
        budget bigger than the retry allowance surfaces the failure."""
        cfg = apack_cfg()
        kv = M.PagedKVCache(cfg, num_pages=8, page_size=4, calib_pages=1,
                            transfer_retries=2)
        inj = FaultInjector()
        kv.faults = inj
        inj.drop_transfers("h2d", 2)                   # within allowance
        kv._put(np.zeros(4, np.float32))
        assert kv.traffic["kv_transfer_drops"] == 2
        assert kv.traffic["kv_transfer_retries"] == 2
        assert inj.stats["h2d_dropped"] == 2
        inj.drop_transfers("d2h", 3)                   # exceeds allowance
        with pytest.raises(TransferDropped):
            kv._fetch(np.zeros(4, np.float32))
        assert kv.traffic["kv_transfer_drops"] == 5


# --------------------------------------------- spill -> resume, end to end
class TestSpillResume:
    def _run(self, cfg, params, *, spill_at=None, rid0=0):
        eng = _mk_engine(cfg, params, max_batch=2, max_len=40)
        rng = np.random.default_rng(7)
        r = Request(rid=rid0, prompt=rng.integers(0, cfg.vocab_size, 10)
                    .astype(np.int32), max_new_tokens=10)
        eng.submit(r)
        for step in range(120):
            if r.done:
                break
            if step == spill_at and eng.active[0] is not None:
                eng.preempt(0, spill=True)
            eng.step()
            eng._retire()
        return r, eng

    def test_spill_resume_is_token_identical_qwen(self):
        """Preempt-with-spill mid-decode (pages parked compressed on
        host) and resume: the token stream is bit-identical to the
        uninterrupted run, and the spill traffic never contaminates the
        KV read streams."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        base, ctrl = self._run(cfg, params)
        toks, eng = self._run(cfg, params, spill_at=4)
        assert toks.tokens == base.tokens
        assert toks.error is None
        assert eng.stats["spilled_requests"] == 1
        assert eng.stats["resumed"] == 1
        ks, ks0 = eng.kv_stats(), ctrl.kv_stats()
        sp = ks["kv_spill"]
        assert sp["pages"] > 0 and sp["calls"] >= 1
        assert sp["readahead_pages"] == sp["pages"]    # all came back
        assert 0 < sp["spill_bytes"] < sp["raw_bytes"]  # parked compressed
        # spill/readahead are their own streams: the decode-side read
        # accounting of the interrupted run matches the control exactly
        assert ks["kv_read_bytes"] == ks0["kv_read_bytes"]
        assert ks["kv_raw_bytes"] == ks0["kv_raw_bytes"]
        assert ks["kv_ratio"] == ctrl.kv_stats()["kv_ratio"]
        assert eng.kv.spill_tier.live_count == 0       # tier fully drained
        assert ks["kv_pages_spilled"] == ks["kv_pages_unspilled"]

    def test_spill_resume_is_token_identical_hetero(self):
        """Same invariant on the heterogeneous stack: attention pages
        spill to the tier, recurrent state rides the compressed snapshot,
        resume continues bit-exactly."""
        cfg = hetero_cfg()
        params = M.init_params(configs.get_hetero_smoke_config(), KEY)
        base, _ = self._run(cfg, params)
        toks, eng = self._run(cfg, params, spill_at=4)
        assert toks.tokens == base.tokens
        assert eng.stats["spilled_requests"] == 1
        assert eng.kv_stats()["kv_spill"]["pages"] > 0
        st = eng.kv_stats()["kv_streams"]["state"]
        assert st["snapshots"] == 1                    # recurrent snapshot
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages

    def test_bit_flip_fails_only_the_owning_request(self):
        """Host-DRAM corruption of a parked page: the owner comes back
        with a structured error, the batchmate's tokens are untouched,
        and the pool/tier drain clean (no leaked pages, evidence kept)."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)

        def run(corrupt):
            eng = _mk_engine(cfg, params, max_batch=2, max_len=40)
            rng = np.random.default_rng(9)
            reqs = [Request(rid=i, prompt=rng.integers(
                        0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=8) for i in range(2)]
            for r in reqs:
                eng.submit(r)
            for _ in range(4):
                eng.step()
            eng.preempt(0, spill=True)
            if corrupt:
                handles = [-e - 1
                           for pids in eng.kv.page_tables[0]
                           for e in pids if e < 0]
                assert handles, "spill left no tier handles"
                FaultInjector().flip_bit(eng.kv.spill_tier, handles[0])
            eng.run_until_drained(max_steps=200)
            return reqs, eng

        ctrl, _ = run(corrupt=False)
        reqs, eng = run(corrupt=True)
        assert reqs[0].done and reqs[0].error is not None
        assert "checksum" in reqs[0].error
        assert eng.stats["failed"] == 1
        assert reqs[1].error is None
        assert reqs[1].tokens == ctrl[1].tokens        # neighbor untouched
        ks = eng.kv_stats()
        assert ks["kv_integrity_failures"] == 1
        assert ks["kv_quarantined_pages"] == 1
        assert len(eng.kv.spill_tier.quarantined) == 1  # evidence kept
        assert eng.kv.spill_tier.live_count == 0
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages
        assert eng._reserved_total == 0

    def test_poisoned_generation_fails_owner_in_step_loop(self):
        """The engine's step loop turns a read-guard trip into a
        structured single-request failure, not a crashed batch."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        eng = _mk_engine(cfg, params, max_batch=2, max_len=40)
        rng = np.random.default_rng(2)
        reqs = [Request(rid=i, prompt=rng.integers(
                    0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=8) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        for _ in range(4):
            eng.step()
        layer = eng.kv.attn_layers[0]
        victims = [p for p in eng.kv.page_tables[0][layer] if p >= 0]
        FaultInjector().poison_generation(eng.kv, victims[0])
        eng.run_until_drained(max_steps=200)
        assert reqs[0].done and "poisoned" in (reqs[0].error or "")
        assert eng.stats["failed"] == 1
        assert reqs[1].done and reqs[1].error is None
        assert len(reqs[1].tokens) >= 8


# ------------------------------------------------- pressure + scheduling
class TestPressureScheduling:
    def test_watchdog_preempts_hung_slot_and_recovers(self):
        """Injected step stalls past the straggler threshold: the
        watchdog preempts-with-spill the longest-running slot (structured
        event, shared StragglerWatchdog code path) and the request still
        completes bit-exactly after resume."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)

        def run(inj):
            eng = _mk_engine(cfg, params, max_batch=2, max_len=48,
                             watchdog_ratio=4.0, watchdog_patience=2,
                             faults=inj)
            rng = np.random.default_rng(5)
            reqs = [Request(rid=i, prompt=rng.integers(
                        0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=14) for i in range(2)]
            for r in reqs:
                eng.submit(r)
            for _ in range(9):       # warm the window past the jit step
                eng.step()
            if inj is not None:
                inj.delay_steps(0.5, n=3)          # sustained stall
            eng.run_until_drained(max_steps=300)
            return reqs, eng

        ctrl, _ = run(None)
        reqs, eng = run(FaultInjector())
        assert eng.stats["watchdog_preempted"] >= 1
        assert eng.stats["spilled_requests"] >= 1
        assert all(r.done and r.error is None for r in reqs)
        for r, c in zip(reqs, ctrl):
            assert r.tokens == c.tokens            # stall never costs bits
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages

    def test_admission_impossible_is_structured_under_pressure(self):
        """kv_pressure with nothing to spill and nothing to preempt: the
        escalation raises a typed error naming the stuck request instead
        of spinning."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        eng = _mk_engine(cfg, params, max_batch=1, max_len=24,
                         kv_pressure=True)
        # an external hold on the whole pool (models a co-tenant): no
        # retire, spill, or preemption can ever free these pages
        eng._reserved[999] = eng.kv.pool.num_pages
        eng._reserved_total = eng.kv.pool.num_pages
        req = Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                      max_new_tokens=4)
        eng.submit(req)
        with pytest.raises(AdmissionImpossible,
                           match="no active slots") as ei:
            eng.run_until_drained(max_steps=100)
        assert ei.value.rid == 0
        assert ei.value.pages_needed > 0

    def test_run_until_drained_raises_instead_of_silent_spinning(self):
        """Without the pressure opt-in the FIFO path gets bounded
        patience, then the same structured error — never a silent
        max_steps burn."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        eng = _mk_engine(cfg, params, max_batch=1, max_len=24,
                         pressure_backoff_max=4)
        eng._reserved[999] = eng.kv.pool.num_pages
        eng._reserved_total = eng.kv.pool.num_pages
        eng.submit(Request(rid=0, prompt=np.arange(8, dtype=np.int32),
                           max_new_tokens=4))
        with pytest.raises(AdmissionImpossible, match="no-progress"):
            eng.run_until_drained(max_steps=100)

    def test_pressure_rotation_completes_undersized_pool(self):
        """Pool at ~half the working set, kv_pressure on: preempt-with-
        spill rotation drains every request with tokens identical to an
        uncontended run (the bench's acceptance property, in-suite)."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        per_req = M.PagedKVCache.pages_for_config(cfg, 12, 4)

        def run(pages, pressure):
            eng = ServeEngine(cfg, params, max_batch=3, max_len=16,
                              kv_page_size=4, kv_calib_pages=2,
                              kv_pages=pages, kv_pressure=pressure,
                              slot_deadline_steps=4 if pressure else None)
            rng = np.random.default_rng(11)
            reqs = [Request(rid=i, prompt=rng.integers(
                        0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=4) for i in range(3)]
            for r in reqs:
                eng.submit(r)
            eng.run_until_drained(max_steps=400)
            return reqs, eng

        ctrl, _ = run(None, False)
        reqs, eng = run(max(per_req, (3 * per_req) // 2), True)
        assert all(r.done and r.error is None for r in reqs)
        for r, c in zip(reqs, ctrl):
            assert r.tokens == c.tokens
        assert eng.kv_stats()["kv_spill"]["pages"] > 0
        assert eng.stats["preempted"] > 0
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages
        assert eng.kv.spill_tier.live_count == 0


# ------------------------------------------------ shared watchdog events
class TestStragglerWatchdog:
    def test_structured_events_and_escalation(self):
        """The shared watchdog emits typed events: 'straggler' per slow
        step, 'hung' once ``patience`` consecutive slow steps accrue — and
        a normal step resets the streak.  The stall must *escalate* to
        stay flagged: the windowed mean absorbs a constant slowdown."""
        seen = []
        wd = StragglerWatchdog(ratio=5.0, patience=3, window=8,
                               on_event=seen.append)
        for _ in range(8):
            assert wd.observe(0.01) is None
        ev = wd.observe(1.0)
        assert isinstance(ev, WatchdogEvent)
        assert ev.kind == "straggler" and ev.consecutive == 1
        assert wd.observe(0.01) is None                # streak resets
        assert wd.events == 0
        evs = [wd.observe(dt) for dt in (1.0, 10.0, 100.0)]
        assert [e.kind for e in evs] == \
            ["straggler", "straggler", "hung"]
        assert evs[-1].consecutive == 3
        assert seen[-1].kind == "hung"
        assert len(wd.event_log) == 4
        wd.reset()
        assert wd.events == 0

    def test_supervisor_exposes_shared_watchdog(self, tmp_path):
        """Supervisor delegates to the same StragglerWatchdog and keeps
        its structured event callback + back-compat counters (and the
        TimeoutError escalation contract)."""
        from repro.runtime.supervisor import Supervisor, SupervisorConfig
        seen = []
        sup = Supervisor(SupervisorConfig(str(tmp_path),
                                          straggler_ratio=5.0,
                                          straggler_patience=2),
                         make_state=lambda: (0, {}),
                         step_fn=lambda s, i: (s, {}),
                         on_watchdog_event=seen.append)
        for _ in range(8):
            sup._watchdog(0.01)
        sup._watchdog(1.0)
        assert sup.straggler_events == 1
        assert seen and seen[-1].kind == "straggler"
        with pytest.raises(TimeoutError):
            sup._watchdog(10.0)
        assert seen[-1].kind == "hung"
        assert len(sup.step_times) == 10
        sup.straggler_events = 0                       # run()'s reset path
        assert sup.watchdog.events == 0

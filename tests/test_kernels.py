"""Pallas kernel validation: sweep shapes/bit-widths, assert bit-exact
against the ref.py jnp oracle (lossless codec => exact equality, which is
stricter than assert_allclose)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import format as fmt
from repro.core import tables, distributions
from repro.kernels import ops, ref
from repro.kernels import decompress_matmul as dm


def _random_values(n, kind, seed, bits=8):
    rng = np.random.default_rng(seed)
    if kind == "gaussian":
        v = distributions.gaussian_weights(n, seed=seed)
    elif kind == "sparse":
        v = distributions.pruned_weights(n, seed=seed)
    elif kind == "uniform":
        v = rng.integers(0, 1 << bits, n)
    else:
        v = distributions.relu_activations(n, seed=seed)
    return np.asarray(v, np.int64) & ((1 << bits) - 1)


class TestDecodeKernel:
    @pytest.mark.parametrize("n,e", [(64, 64), (1000, 128), (4096, 512),
                                     (130, 64), (513, 512)])
    @pytest.mark.parametrize("kind", ["gaussian", "sparse", "relu"])
    def test_shape_sweep_vs_ref(self, n, e, kind):
        v = _random_values(n, kind, seed=n + e)
        t = tables.table_for(v, is_activation=True)
        ca = ops.apack_encode(v, t, elems_per_stream=e, backend="ref")
        out_k = ops.apack_decode(ca, backend="pallas_interpret")
        out_r = ops.apack_decode(ca, backend="ref")
        assert np.array_equal(np.asarray(out_k), np.asarray(out_r))
        assert np.array_equal(np.asarray(out_k), v.astype(np.uint8))

    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_bitwidth_sweep(self, bits):
        v = _random_values(777, "gaussian", seed=bits, bits=bits)
        if bits == 4:
            v = v & 0xF
        t = tables.table_for(v, bits=bits, is_activation=True)
        ca = ops.apack_encode(v, t, elems_per_stream=128,
                              backend="pallas_interpret")
        out = ops.apack_decode(ca, backend="pallas_interpret")
        assert np.array_equal(np.asarray(out).astype(np.int64), v)

    def test_stored_mode_streams(self):
        v = _random_values(512, "uniform", seed=0)
        t = tables.uniform_table()
        ca = ops.apack_encode(v, t, elems_per_stream=128,
                              backend="pallas_interpret")
        assert bool(np.asarray(ca.stored).all())
        out = ops.apack_decode(ca, backend="pallas_interpret")
        assert np.array_equal(np.asarray(out).astype(np.int64), v)


class TestEncodeKernel:
    @pytest.mark.parametrize("n,e", [(256, 64), (1500, 128), (2048, 512)])
    @pytest.mark.parametrize("kind", ["gaussian", "sparse"])
    def test_bit_exact_vs_golden_container(self, n, e, kind):
        v = _random_values(n, kind, seed=7 * n)
        t = tables.table_for(v, is_activation=True)
        ct = fmt.compress(v, t, elems_per_stream=e)          # golden
        ca = ops.apack_encode(v, t, elems_per_stream=e,
                              backend="pallas_interpret")    # kernel
        assert np.array_equal(np.asarray(ca.sym_bits), ct.sym_bits)
        assert np.array_equal(np.asarray(ca.ofs_bits), ct.ofs_bits)
        assert np.array_equal(np.asarray(ca.stored), ct.stored)
        ws, wo = ct.sym_plane.shape[0], ct.ofs_plane.shape[0]
        assert np.array_equal(np.asarray(ca.sym_plane[:ws]).astype(np.uint32),
                              ct.sym_plane)
        assert np.array_equal(np.asarray(ca.ofs_plane[:wo]).astype(np.uint32),
                              ct.ofs_plane)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(10, 600), st.integers(0, 99))
    def test_roundtrip_property(self, n, seed):
        v = _random_values(n, ["gaussian", "sparse", "relu"][seed % 3], seed)
        t = tables.table_for(v, is_activation=True)
        assert ops.apack_roundtrip_check(v, t, elems_per_stream=64,
                                         backend="pallas_interpret")


class TestFusedMatmul:
    @pytest.mark.parametrize("m,k,n,tile_k", [
        (8, 128, 128, 128), (17, 300, 130, 128), (64, 512, 256, 256),
    ])
    def test_matches_reference(self, m, k, n, tile_k):
        rng = np.random.default_rng(m * k)
        w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
        x = rng.normal(0, 1, (m, k)).astype(np.float32)
        cw = dm.compress_linear(w, tile_k=tile_k)
        fused = np.asarray(dm.compressed_matmul(jnp.asarray(x), cw,
                                                block_m=max(8, m)))
        oracle = np.asarray(dm.reference_matmul(jnp.asarray(x), cw))
        np.testing.assert_allclose(fused, oracle, rtol=1e-5, atol=1e-5)

    def test_quantization_error_bounded(self):
        rng = np.random.default_rng(0)
        k, n, m = 256, 128, 16
        w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
        x = rng.normal(0, 1, (m, k)).astype(np.float32)
        cw = dm.compress_linear(w, tile_k=128)
        fused = np.asarray(dm.compressed_matmul(jnp.asarray(x), cw, block_m=16))
        dense = x @ w
        rel = np.abs(fused - dense).max() / np.abs(dense).max()
        assert rel < 0.05   # int8 per-channel quantization error only


class TestRefInternals:
    def test_shift_helpers_edge_cases(self):
        x = jnp.asarray([0xFFFFFFFF, 1, 0x80000000], jnp.uint32)
        assert np.array_equal(np.asarray(ref.shr32(x, jnp.asarray([32, 0, 31]))),
                              [0, 1, 1])
        assert np.array_equal(np.asarray(ref.shl32(x, jnp.asarray([32, 31, 0]))),
                              [0, 0x80000000, 0x80000000])

    def test_read_bits_word_straddle(self):
        plane = jnp.asarray(np.array([[0xAAAAAAAA], [0x55555555]], np.uint32))
        # LSB-first: stream bits 30,31 of w0 = (0,1), bits 0,1 of w1 = (1,0)
        # -> value = 0 | 1<<1 | 1<<2 | 0<<3 = 0b0110
        v = ref.read_bits(plane, jnp.asarray([30]), jnp.asarray([4]))
        assert int(v[0]) == 0b0110

    def test_read_past_end_returns_zero(self):
        plane = jnp.full((1, 1), 0xFFFFFFFF, jnp.uint32)
        v = ref.read_bits(plane, jnp.asarray([40]), jnp.asarray([8]))
        assert int(v[0]) == 0

"""Fused gather-decode + attention tests: kernel parity vs the
materialize oracle across page states (HOT/COLD/PACKED mix, rolling
eviction, non-aligned lengths), on-device append parity vs the host-append
trace, the steady-state zero-``device_get`` guard, sub-page rolling read
accounting, and the gather-bucket recompile-storm cap."""
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import paged_decode
from repro.kernels.fused_page_attention import fused_page_attention
from repro.models import model as M
from repro.models import modules as m
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def apack_cfg(arch="qwen3-1.7b", **kw):
    return dataclasses.replace(configs.get_smoke_config(arch),
                               kv_cache_dtype="apack-int8", **kw)


def _random_token(rng, kv):
    h, dh, n = kv.pool.kv_heads, kv.pool.head_dim, kv.n_layers
    return (rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
            rng.integers(-127, 128, (n, h, dh)).astype(np.int8),
            rng.uniform(0.01, 0.02, (n, h)).astype(np.float32),
            rng.uniform(0.01, 0.02, (n, h)).astype(np.float32))


# ------------------------------------------------------- kernel parity
class TestKernelParity:
    @pytest.mark.parametrize("calib_pages,want_state",
                             [(2, m.PAGE_PACKED),     # calibrated: packed
                              (100, m.PAGE_COLD)])    # pre-calib: cold
    def test_mixed_page_states_match_materialize_oracle(self, calib_pages,
                                                        want_state):
        """HOT-partial + sealed pages in one call, both backends, in both
        lifecycle regimes (COLD-only pre-calibration, PACKED after):
        normalized fused output == dense softmax over the materialized
        cache (the decode itself is bit-exact; the output tolerance is fp
        reassociation of the online softmax)."""
        cfg = apack_cfg()
        kv = M.PagedKVCache(cfg, num_pages=kv_pages(cfg, 16),
                            page_size=4, calib_pages=calib_pages)
        rng = np.random.default_rng(0)
        # rid 0: 11 tokens (2 sealed pages + HOT partial), rid 1: 6
        for rid, toks in ((0, 11), (1, 6)):
            kv.add_request(rid)
            for _ in range(toks):
                kv.append_token(rid, *_random_token(rng, kv))
        states = {int(kv.pool.state[p])
                  for r in (0, 1)
                  for p in kv.page_tables[r][kv.attn_layers[0]]}
        assert states == {m.PAGE_HOT, want_state}
        kv.enable_device_pool(2)
        for rid in (0, 1):
            kv.sync_request_to_device(rid)
        max_len = 16
        meta = kv.step_meta([0, 1], max_len)
        cache = kv.materialize([0, 1], max_len)
        hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = rng.normal(0, 1, (2, hq, dh)).astype(np.float32)
        n_streams = kv.dev.planes["sym_k"].shape[2]
        n_steps = (kv.page_size * hkv * dh) // n_streams
        for c in range(kv.n_cycle):
            for j in range(kv.n_stack):
                mt = {f: np.asarray(meta["blocks"][c][f])[j]
                      for f in ("pid", "tid", "state", "t0", "qw")}
                kmeta = np.stack([mt["state"], mt["t0"]], axis=-1)
                outs = {}
                for backend in ("ref", "pallas_interpret"):
                    acc, mm, ll = fused_page_attention(
                        jnp.asarray(q), jnp.asarray(mt["pid"]),
                        jnp.asarray(mt["tid"]), jnp.asarray(kmeta),
                        jnp.asarray(mt["qw"]), kv.dev.planes,
                        n_steps=n_steps, num_heads=hq, backend=backend)
                    outs[backend] = (np.asarray(acc)
                                     / np.asarray(ll)[..., None])
                assert np.allclose(outs["ref"], outs["pallas_interpret"],
                                   atol=1e-5), "backends disagree"
                # oracle: dense attention over the materialized cache
                kd = m._kv_dequantize(
                    cache["blocks"][c]["k"][j],
                    cache["blocks"][c]["k_scale"][j])      # [B, S, H, dh]
                vd = m._kv_dequantize(cache["blocks"][c]["v"][j],
                                      cache["blocks"][c]["v_scale"][j])
                for slot, rid in enumerate((0, 1)):
                    qpos = kv.seq_len[rid]
                    q3 = q[slot].reshape(hkv, hq // hkv, dh)
                    sc = np.einsum("kgd,skd->kgs", q3,
                                   np.asarray(kd[slot])) * dh ** -0.5
                    valid = np.arange(max_len) < qpos
                    sc = np.where(valid[None, None], sc, -1e30)
                    w = np.exp(sc - sc.max(-1, keepdims=True)) \
                        * valid[None, None]
                    want = (np.einsum("kgs,skd->kgd", w,
                                      np.asarray(vd[slot]))
                            / w.sum(-1)[..., None]).reshape(hq, dh)
                    got = outs["ref"][slot]
                    assert np.allclose(got, want, atol=1e-4), (
                        c, j, slot, np.abs(got - want).max())


def kv_pages(cfg, tokens, page_size=4):
    return 4 * M.PagedKVCache.pages_for_config(cfg, tokens, page_size)


# ------------------------------------- fused engine vs materialize oracle
def _lockstep(cfg, params, prompts, max_new, max_len, atol, **kw):
    """Run fused + materialize engines in lockstep on the same requests;
    per-step active-slot logits must agree within ``atol`` and the greedy
    token streams must be identical."""
    engines = {}
    reqs = {}
    kw.setdefault("kv_calib_pages", 2)
    for fused in (False, True):
        engines[fused] = ServeEngine(cfg, params, max_len=max_len,
                                     kv_page_size=4, kv_fused=fused, **kw)
        reqs[fused] = [Request(rid=i, prompt=p.copy(),
                               max_new_tokens=max_new)
                       for i, p in enumerate(prompts)]
        for r in reqs[fused]:
            engines[fused].submit(r)
    worst = 0.0
    for _ in range(300):
        n0 = engines[False].step()
        n1 = engines[True].step()
        assert n0 == n1
        if n0 == 0 and not engines[False].queue:
            break
        active = [s for s, r in enumerate(engines[False].active)
                  if r is not None]
        if active and engines[True].last_logits is not None:
            l0 = np.asarray(engines[False].last_logits)[active]
            l1 = np.asarray(engines[True].last_logits)[active]
            worst = max(worst, float(np.abs(l0 - l1).max()))
    assert all(r.done for r in reqs[False])
    assert all(r.done for r in reqs[True])
    toks0 = [r.tokens for r in reqs[False]]
    toks1 = [r.tokens for r in reqs[True]]
    assert toks0 == toks1, (toks0, toks1)
    assert worst < atol, f"fused-vs-materialize logit drift {worst}"
    return engines


class TestFusedEngineParity:
    def test_qwen_global_stack(self):
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        rng = np.random.default_rng(1)
        # non-page-aligned prompt lengths on purpose
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (9, 11, 6)]
        engines = _lockstep(cfg, params, prompts, max_new=6, max_len=32,
                            atol=2e-3, max_batch=2)
        fused = engines[True].kv_stats()
        mat = engines[False].kv_stats()
        # same pages were read either way: accounting agrees
        assert fused["kv_ratio"] == pytest.approx(mat["kv_ratio"])
        assert fused["kv_pages_packed"] == mat["kv_pages_packed"]
        # the whole point: the fused loop moves orders of magnitude fewer
        # payload bytes across the host<->device boundary
        assert fused["transfers"]["d2h_bytes"] \
            < mat["transfers"]["d2h_bytes"] / 4
        assert fused["transfers"]["h2d_bytes"] \
            < mat["transfers"]["h2d_bytes"] / 4

    def test_hetero_rolling_eviction_mid_window(self):
        """global + local + recurrent cycle with a recurrent prefix;
        window 8 and 12+ generated tokens force rolling eviction *during*
        decode — evicted pages must mask in-kernel identically to the
        materialize ring."""
        base = configs.get_hetero_smoke_config()
        cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
        params = M.init_params(base, KEY)
        rng = np.random.default_rng(2)
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (11, 7)]
        engines = _lockstep(cfg, params, prompts, max_new=12, max_len=40,
                            atol=2e-3, max_batch=2)
        assert engines[True].kv.pool.evict_count > 0
        assert engines[True].kv.pool.evict_count == \
            engines[False].kv.pool.evict_count

    def test_cold_only_pages_before_calibration(self):
        """calib_pages high enough that nothing packs: the fused path must
        serve pure HOT/COLD pools too (the pre-calibration regime)."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        rng = np.random.default_rng(3)
        prompts = [rng.integers(0, cfg.vocab_size, 9).astype(np.int32)]
        engines = _lockstep(cfg, params, prompts, max_new=5, max_len=24,
                            atol=2e-3, max_batch=1, kv_calib_pages=100)
        assert engines[True].kv_stats()["kv_pages_packed"] == 0


# -------------------------------------------------- on-device append
class TestOnDeviceAppend:
    def test_device_append_matches_host_trace(self):
        """After identical serves, the fused engine's pool (HOT planes
        synced back from device) is byte-identical to the host-append
        engine's pool — page tables, fills, states, payloads, planes."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        rng = np.random.default_rng(4)
        prompts = [rng.integers(0, cfg.vocab_size, 7).astype(np.int32)
                   for _ in range(2)]
        pools = {}
        for fused in (False, True):
            eng = ServeEngine(cfg, params, max_batch=2, max_len=24,
                              kv_page_size=4, kv_calib_pages=2,
                              kv_fused=fused)
            reqs = [Request(rid=i, prompt=p.copy(), max_new_tokens=5)
                    for i, p in enumerate(prompts)]
            for r in reqs:
                eng.submit(r)
            for _ in range(4):              # stop mid-flight, pages live
                eng.step()
            eng.sync_host_mirror()
            pools[fused] = eng.kv.pool
        a, b = pools[False], pools[True]
        assert np.array_equal(a.state, b.state)
        assert np.array_equal(a.fill, b.fill)
        for f in ("tok_q", "tok_scale", "cold_q", "page_scale", "sym",
                  "ofs", "stored", "sym_bits", "ofs_bits"):
            if f in ("sym_bits", "ofs_bits"):
                # bit counts only exist host-side; equal encode -> equal
                pass
            assert np.array_equal(getattr(a, f), getattr(b, f)), f

    def test_steady_state_step_has_zero_device_get(self, monkeypatch):
        """The transfer-count guard: a decode step that crosses no page
        boundary (no seal) and admits/retires nothing calls
        ``jax.device_get`` exactly zero times and moves zero d2h bytes —
        the loop is device-resident."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        rng = np.random.default_rng(5)
        eng = ServeEngine(cfg, params, max_batch=1, max_len=32,
                          kv_page_size=4, kv_calib_pages=2)
        assert eng.fused
        req = Request(rid=0, prompt=rng.integers(
            0, cfg.vocab_size, 9).astype(np.int32), max_new_tokens=8)
        eng.submit(req)
        eng.step()                           # admission + prefill + step
        # positions now 10: next append lands mid-page (10 % 4 != 3), no
        # seal, no admission, no retire -> steady state
        assert int(eng.positions[0]) % 4 != 3
        calls = []
        real = jax.device_get
        monkeypatch.setattr(jax, "device_get",
                            lambda x: (calls.append(1), real(x))[1])
        d2h_before = eng.kv.transfers["d2h_bytes"]
        eng.step()
        assert calls == [], f"{len(calls)} device_get calls in steady state"
        assert eng.kv.transfers["d2h_bytes"] == d2h_before
        # ...and a page-boundary step is *allowed* to sync (seal path)
        while int(eng.positions[0]) % 4 != 3:
            eng.step()
        eng.step()                           # fills the page -> seal
        assert eng.kv.transfers["d2h_bytes"] > d2h_before


# ------------------------------------------- rolling read accounting
class TestRollingReadAccounting:
    def test_partial_page_charges_live_range_only(self):
        """The oldest partially-rolled-out page of a local layer charges
        ceil(page_bytes * live / page_size), not the whole page."""
        base = configs.get_hetero_smoke_config()      # window 8
        cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
        kv = M.PagedKVCache(cfg, num_pages=64, page_size=4, calib_pages=2)
        kv.add_request(0)
        rng = np.random.default_rng(6)
        for _ in range(14):                  # qpos 14, window 8
            kv.append_token(0, *_random_token(rng, kv))
        layer = kv.local_layers[0]
        base_pg = kv.page_base[0][layer]
        pids = kv.page_tables[0][layer]
        qpos, ring = 14, 8
        expected = 0
        for k_, pid in enumerate(pids):
            t0 = (base_pg + k_) * 4
            n_tok = (int(kv.pool.fill[pid])
                     if kv.pool.state[pid] == m.PAGE_HOT else 4)
            n_live = int(np.sum(np.arange(t0, t0 + n_tok) >= qpos - ring))
            charged = kv.pool.page_bytes(pid)
            if n_live < n_tok:
                charged = -(-charged * n_live // n_tok)
            expected += charged
        # at least one page must be partially live or the test is vacuous
        assert any(
            0 < np.sum(np.arange((base_pg + k_) * 4,
                                 (base_pg + k_) * 4 + 4) >= qpos - ring) < 4
            for k_ in range(len(pids) - 1)), "no partially-rolled page"
        kv._accrue_read_traffic([0], 40)
        assert kv.traffic["kv_read_bytes_local"] == expected
        full = sum(kv.pool.page_bytes(pid) for pid in pids)
        assert kv.traffic["kv_read_bytes_local"] < full


# ------------------------------------------- mixed table generations
class TestMixedGenerationParity:
    """Table-refresh mid-serve: PACKED pages coded under different table
    generations must attend side by side — the per-page table id addresses
    ``(generation, layer, kind)`` rows of the stacked pool."""

    def _mixed_gen_kv(self):
        cfg = apack_cfg()
        kv = M.PagedKVCache(cfg, num_pages=kv_pages(cfg, 32),
                            page_size=4, calib_pages=2,
                            refresh_every_pages=4, refresh_min_pages=1)
        rng = np.random.default_rng(7)
        for rid, toks in ((0, 19), (1, 10)):
            kv.add_request(rid)
            for _ in range(toks):
                kv.append_token(rid, *_random_token(rng, kv))
        assert kv.maybe_refresh()              # every-M trigger
        # partial budget: only some pages migrate -> generations mix
        # (force: same-distribution re-codes may tie the size gate; this
        # test is about mixed-generation *addressing*, not the gate)
        assert kv.repack_pending(budget=3, force=True) == 3
        gens = {int(kv.page_gen[p]) for s in kv._packed for p in s}
        assert gens == {0, 1}
        return cfg, kv, rng

    def test_two_generations_match_materialize_oracle_both_backends(self):
        cfg, kv, rng = self._mixed_gen_kv()
        kv.enable_device_pool(2)
        for rid in (0, 1):
            kv.sync_request_to_device(rid)
        max_len = 32
        meta = kv.step_meta([0, 1], max_len)
        cache = kv.materialize([0, 1], max_len)
        hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        q = rng.normal(0, 1, (2, hq, dh)).astype(np.float32)
        n_streams = kv.dev.planes["sym_k"].shape[2]
        n_steps = (kv.page_size * hkv * dh) // n_streams
        saw_two_gens = False
        for c in range(kv.n_cycle):
            for j in range(kv.n_stack):
                mt = {f: np.asarray(meta["blocks"][c][f])[j]
                      for f in ("pid", "tid", "state", "t0", "qw")}
                packed = mt["state"] == m.PAGE_PACKED
                tid_gens = set((mt["tid"][packed]
                                // (2 * kv.n_layers)).tolist())
                saw_two_gens |= len(tid_gens) == 2
                kmeta = np.stack([mt["state"], mt["t0"]], axis=-1)
                outs = {}
                for backend in ("ref", "pallas_interpret"):
                    acc, _, ll = fused_page_attention(
                        jnp.asarray(q), jnp.asarray(mt["pid"]),
                        jnp.asarray(mt["tid"]), jnp.asarray(kmeta),
                        jnp.asarray(mt["qw"]), kv.dev.planes,
                        n_steps=n_steps, num_heads=hq, backend=backend)
                    outs[backend] = np.asarray(acc) / np.asarray(ll)[..., None]
                assert np.allclose(outs["ref"], outs["pallas_interpret"],
                                   atol=1e-5)
                kd = m._kv_dequantize(cache["blocks"][c]["k"][j],
                                      cache["blocks"][c]["k_scale"][j])
                vd = m._kv_dequantize(cache["blocks"][c]["v"][j],
                                      cache["blocks"][c]["v_scale"][j])
                for slot, rid in enumerate((0, 1)):
                    qpos = kv.seq_len[rid]
                    q3 = q[slot].reshape(hkv, hq // hkv, dh)
                    sc = np.einsum("kgd,skd->kgs", q3,
                                   np.asarray(kd[slot])) * dh ** -0.5
                    valid = np.arange(max_len) < qpos
                    sc = np.where(valid[None, None], sc, -1e30)
                    w = np.exp(sc - sc.max(-1, keepdims=True)) \
                        * valid[None, None]
                    want = (np.einsum("kgs,skd->kgd", w,
                                      np.asarray(vd[slot]))
                            / w.sum(-1)[..., None]).reshape(hq, dh)
                    assert np.allclose(outs["ref"][slot], want,
                                       atol=1e-4), (c, j, slot)
        # at least one job must actually have seen both generations or the
        # test is vacuous
        assert saw_two_gens

    def test_full_repack_restores_single_generation_ids(self):
        cfg, kv, _ = self._mixed_gen_kv()
        assert kv.repack_pending(force=True) > 0
        meta = kv.step_meta([0, 1], 32)
        for c in range(kv.n_cycle):
            mt_state = np.asarray(meta["blocks"][c]["state"])
            mt_tid = np.asarray(meta["blocks"][c]["tid"])
            packed = mt_state == m.PAGE_PACKED
            assert set((mt_tid[packed] // (2 * kv.n_layers)).tolist()) \
                <= {1}

    def test_refresh_mid_rolling_window_next_to_evicted_pages(self):
        """Hetero stack (global + local + recurrent): a refresh landing
        while the rolling window is evicting pages — fused kernel vs the
        materialize oracle must stay token-identical with evicted slots,
        HOT partials, and two table generations in the same page sets."""
        base = configs.get_hetero_smoke_config()
        cfg = dataclasses.replace(base, kv_cache_dtype="apack-int8")
        params = M.init_params(base, KEY)
        rng = np.random.default_rng(8)
        prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in (11, 7)]
        engines = _lockstep(cfg, params, prompts, max_new=14, max_len=40,
                            atol=2e-3, max_batch=2, kv_refresh=True,
                            kv_refresh_every_pages=3,
                            kv_refresh_min_pages=1, kv_repack_budget=2)
        for eng in engines.values():
            assert eng.kv.pool.evict_count > 0
            assert eng.kv.generation >= 1
            assert eng.stats["kv_pages_repacked"] > 0
        assert engines[True].kv.generation == engines[False].kv.generation


# ---------------------------------------------- gather bucket capping
class TestGatherBucketCap:
    def test_beyond_table_grows_power_of_two(self):
        assert paged_decode.gather_bucket(1025) == 2048
        assert paged_decode.gather_bucket(5000) == 8192
        assert paged_decode.gather_bucket(8193) == 16384
        # existing contract still holds
        assert paged_decode.gather_bucket(3) == 4
        assert paged_decode.gather_bucket(129) == 256

    def test_recompile_storm_warns(self, monkeypatch, caplog):
        monkeypatch.setattr(paged_decode, "_seen_buckets", set())
        monkeypatch.setattr(paged_decode, "GATHER_BUCKET_WARN_THRESHOLD", 3)
        with caplog.at_level(logging.WARNING,
                             logger="repro.kernels.paged_decode"):
            for n in (1, 2, 4):
                paged_decode.gather_bucket(n)
            assert not caplog.records          # at threshold: quiet
            paged_decode.gather_bucket(8)      # 4th distinct size: warn
            assert len(caplog.records) == 1
            assert "recompile storm" in caplog.records[0].message
            paged_decode.gather_bucket(8)      # repeat size: no new warn
            assert len(caplog.records) == 1

"""Golden-codec + table-generation + container-format behaviour tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ac_golden, baselines, byteplane, compress, decompress,
                        distributions, find_table, histogram, table_for,
                        uniform_table)
from repro.core.format import estimate_bits
from repro.core.tables import ApackTable, N_SYMBOLS, encoded_size


# ---------------------------------------------------------------- tables
class TestTables:
    def test_uniform_table_invariants(self):
        t = uniform_table()
        assert t.v_min[0] == 0 and t.v_min[-1] == 256
        assert t.cum[0] == 0 and t.cum[-1] == 1024
        assert all(b - a == 16 for a, b in zip(t.v_min, t.v_min[1:]))
        assert all(o == 4 for o in t.ol)

    @pytest.mark.parametrize("gen", list(distributions.PAPER_LIKE))
    def test_found_table_invariants(self, gen):
        v = distributions.PAPER_LIKE[gen](4096)
        t = table_for(v, is_activation=True)
        assert len(t.v_min) == N_SYMBOLS + 1
        assert t.v_min[0] == 0 and t.v_min[-1] == 256
        assert all(b > a for a, b in zip(t.v_min, t.v_min[1:]))
        assert t.cum[0] == 0 and t.cum[-1] == 1024
        assert all(b >= a for a, b in zip(t.cum, t.cum[1:]))
        # activation tables: every range encodable (stealing)
        assert all(b > a for a, b in zip(t.cum, t.cum[1:]))
        # OL consistency
        for i in range(N_SYMBOLS):
            size = t.v_min[i + 1] - t.v_min[i]
            assert (1 << t.ol[i]) >= size

    def test_search_improves_on_uniform(self):
        v = distributions.gaussian_weights(16384)
        h = histogram(v)
        uni = uniform_table()
        found = find_table(h)
        assert (encoded_size(h, list(found.v_min[:-1]))
                <= encoded_size(h, list(uni.v_min[:-1])))

    def test_table_matches_paper_shape(self):
        # Paper Table I: bimodal weights -> dense short ranges near 0 and 255,
        # wide dead ranges in the middle.
        v = distributions.gaussian_weights(65536, sigma=3.0)
        t = table_for(v)
        assert t.v_min[1] <= 8, "first range should be short (dense near 0)"
        assert t.v_min[-2] >= 240, "last range should be short (dense near 255)"
        counts = np.diff(np.asarray(t.cum))
        assert counts[0] + counts[-1] > 700, "mass concentrates at the ends"

    def test_zero_count_stealing(self):
        v = np.zeros(1000, np.uint8)          # only value 0 ever seen
        t = table_for(v, is_activation=True)
        counts = np.diff(np.asarray(t.cum))
        assert (counts >= 1).all(), "activation table must cover unseen values"
        tw = table_for(v, is_activation=False)
        cw = np.diff(np.asarray(tw.cum))
        assert cw[0] > 900  # weights may dedicate nearly everything to 0


# ---------------------------------------------------------------- golden codec
class TestGoldenCodec:
    @pytest.mark.parametrize("gen", list(distributions.PAPER_LIKE))
    def test_roundtrip(self, gen):
        v = distributions.PAPER_LIKE[gen](2048).astype(np.int64)
        t = table_for(v, is_activation=True)
        sw, sb, ow, ob = ac_golden.encode_stream(v, t)
        out = ac_golden.decode_stream(sw, ow, len(v), t, sb, ob)
        assert list(v) == out

    def test_single_value_stream(self):
        t = uniform_table()
        sw, sb, ow, ob = ac_golden.encode_stream([7], t)
        assert ac_golden.decode_stream(sw, ow, 1, t, sb, ob) == [7]

    def test_extreme_skew_fraction_of_a_bit(self):
        # Very frequent symbol must cost well under 1 bit on average (the
        # paper's core claim for AC over Huffman).
        v = np.zeros(4096, np.int64)
        v[::64] = 255
        t = table_for(v, is_activation=False)
        sw, sb, ow, ob = ac_golden.encode_stream(v, t)
        assert (sb + ob) / len(v) < 0.5
        assert ac_golden.decode_stream(sw, ow, len(v), t, sb, ob) == list(v)

    def test_zero_probability_symbol_rejected(self):
        v = np.zeros(128, np.int64)
        t = table_for(v, is_activation=False)   # most ranges get 0 counts
        dead = next(s for s in range(N_SYMBOLS) if t.cum[s + 1] == t.cum[s])
        with pytest.raises(ValueError):
            ac_golden.encode_stream([t.v_min[dead]], t)

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.integers(0, 255), min_size=1, max_size=512),
           st.integers(0, 2**32 - 1))
    def test_roundtrip_property(self, vals, seed):
        v = np.asarray(vals, np.int64)
        t = table_for(v, is_activation=True)
        sw, sb, ow, ob = ac_golden.encode_stream(v, t)
        assert ac_golden.decode_stream(sw, ow, len(v), t, sb, ob) == list(v)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 1000), st.floats(0.0, 0.99), st.integers(0, 999))
    def test_roundtrip_sparse_property(self, n, sparsity, seed):
        rng = np.random.default_rng(seed)
        v = np.where(rng.random(n) < sparsity, 0,
                     rng.integers(0, 256, n)).astype(np.int64)
        t = table_for(v, is_activation=True)
        sw, sb, ow, ob = ac_golden.encode_stream(v, t)
        assert ac_golden.decode_stream(sw, ow, len(v), t, sb, ob) == list(v)


# ---------------------------------------------------------------- container
class TestContainer:
    @pytest.mark.parametrize("n", [1, 511, 512, 513, 5000])
    def test_compress_roundtrip_sizes(self, n):
        v = distributions.relu_activations(n, seed=n)
        ct = compress(v, is_activation=True)
        out = decompress(ct)
        assert out.shape == v.shape
        assert np.array_equal(out, v)

    def test_multidim_shape_preserved(self):
        v = distributions.gaussian_weights(6144).reshape(3, 64, 32)
        ct = compress(v)
        assert np.array_equal(decompress(ct), v)

    def test_stored_mode_bounds_worst_case(self):
        rng = np.random.default_rng(0)
        v = rng.integers(0, 256, 4096).astype(np.uint8)   # incompressible
        ct = compress(v, table=uniform_table())
        assert ct.payload_bits <= v.size * 8 + ct.n_streams  # stored-mode bound
        assert np.array_equal(decompress(ct), v)

    def test_ratio_accounting(self):
        v = distributions.pruned_weights(32768)
        ct = compress(v)
        assert ct.ratio(include_metadata=True) <= ct.ratio(include_metadata=False)
        assert ct.original_bits == v.size * 8

    def test_estimate_matches_actual(self):
        v = distributions.gaussian_weights(65536)
        t = table_for(v)
        ct = compress(v, table=t)
        est = estimate_bits(histogram(v), t)
        actual = ct.payload_bits
        assert abs(est - actual) / actual < 0.01

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 2000), st.integers(0, 99))
    def test_container_roundtrip_property(self, n, seed):
        rng = np.random.default_rng(seed)
        kind = seed % 3
        if kind == 0:
            v = rng.integers(0, 256, n).astype(np.uint8)
        elif kind == 1:
            v = distributions.relu_activations(n, seed=seed)
        else:
            v = distributions.pruned_weights(n, seed=seed)
        ct = compress(v, is_activation=True, elems_per_stream=256)
        assert np.array_equal(decompress(ct), v)


# ---------------------------------------------------------------- baselines
class TestBaselines:
    def test_apack_beats_others_on_paper_distributions(self):
        # Fig. 5: APack outperforms RLE/RLEZ/ShapeShifter on every tensor.
        for name, gen in distributions.PAPER_LIKE.items():
            v = gen(16384)
            ct = compress(v, is_activation=True)
            apack = ct.payload_bits
            assert apack <= baselines.shapeshifter_bits(v), name
            assert apack <= baselines.rle_bits(v), name
            assert apack <= baselines.rlez_bits(v), name

    def test_rle_runs(self):
        v = np.array([5] * 20 + [3] + [0] * 10, np.uint8)
        # runs: 20x5 -> 2 tuples (16+4), 1x3 -> 1, 10x0 -> 1 tuple
        assert baselines.rle_bits(v) == 4 * 12

    def test_rlez_counts_zero_gaps(self):
        v = np.array([1, 0, 0, 2, 3], np.uint8)
        assert baselines.rlez_bits(v) == 3 * 12   # three nonzero tuples

    def test_shapeshifter_sign_extension(self):
        # all-0xFF (-1) group needs 1 bit per value, not 8
        v = np.full(8, 0xFF, np.uint8)
        assert baselines.shapeshifter_bits(v, zero_vector=False) <= 8 * 2 + 3


# ---------------------------------------------------------------- byteplane
class TestByteplane:
    @pytest.mark.parametrize("dtype", [np.float32, np.dtype("bfloat16") if hasattr(np, "bfloat16") else np.float16])
    def test_lossless_float_roundtrip(self, dtype):
        rng = np.random.default_rng(0)
        x = (rng.normal(0, 0.02, 2048)).astype(np.float32).astype(dtype)
        cp = byteplane.compress_float(x)
        out = byteplane.decompress_float(cp)
        assert out.dtype == x.dtype
        assert np.array_equal(out.view(np.uint8), x.view(np.uint8))

    def test_trained_like_weights_compress(self):
        rng = np.random.default_rng(1)
        x = (rng.normal(0, 0.02, 65536)).astype(np.float32)
        cp = byteplane.compress_float(x)
        assert cp.ratio() > 1.15   # exponent plane is highly skewed

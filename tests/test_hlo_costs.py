"""HLO cost-walker tests: shape parsing, dot flops, while-trip handling —
verified against a compiled toy whose analytic costs are known."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_costs as hc


class TestShapeParsing:
    def test_bytes(self):
        assert hc._shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
        assert hc._shape_bytes("f32[16]") == 64
        assert hc._shape_bytes("(bf16[4,4]{1,0}, s32[2])") == 32 + 8
        assert hc._shape_bytes("pred[]") == 1

    def test_numel_and_dims(self):
        assert hc._shape_numel("f32[3,5]{1,0}") == 15
        assert hc._shape_dims("bf16[7,9]{1,0}") == [7, 9]


class TestToyPrograms:
    def test_matmul_flops_counted(self):
        m, k, n = 64, 128, 32

        def f(a, b):
            return a @ b

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((m, k), jnp.float32),
            jax.ShapeDtypeStruct((k, n), jnp.float32)).compile()
        out = hc.analyze(compiled.as_text(), {})
        expect = 2 * m * k * n
        assert abs(out["flops"] - expect) / expect < 0.05

    def test_scan_body_multiplied_by_trip(self):
        L, d = 8, 32

        def f(ws, x):
            def body(h, w):
                return jnp.tanh(h @ w), ()
            h, _ = jax.lax.scan(body, x, ws)
            return h

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((L, d, d), jnp.float32),
            jax.ShapeDtypeStruct((d, d), jnp.float32)).compile()
        txt = compiled.as_text()
        once = hc.analyze(txt, {0: 1})["flops"]
        tripped = hc.analyze(txt, {0: L})["flops"]
        per_layer = 2 * d * d * d
        assert tripped - once >= (L - 1) * per_layer * 0.9
        # XLA's own cost analysis counts the body once — our walker with
        # trip=1 should be in its ballpark.  (cost_analysis() returned a
        # one-element list in older jax, a dict in newer versions.)
        ca = compiled.cost_analysis()
        xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
        assert once <= xla * 2 + per_layer

    def test_nested_scan_depths(self):
        def f(x):
            def outer(h, _):
                def inner(g, _):
                    return g * 2.0, ()
                g, _ = jax.lax.scan(inner, h, None, length=5)
                return g, ()
            h, _ = jax.lax.scan(outer, x, None, length=3)
            return h

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((16,), jnp.float32)).compile()
        txt = compiled.as_text()
        flat = hc.analyze(txt, {0: 1, 1: 1})["flops"]
        deep = hc.analyze(txt, {0: 3, 1: 5})["flops"]
        assert deep > flat * 3           # multiplies through both depths

    def test_collectives_absent_on_single_device(self):
        compiled = jax.jit(lambda x: x + 1).lower(
            jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
        out = hc.analyze(compiled.as_text(), {})
        assert out["collective_wire_bytes"] == 0


class TestCollectiveParsing:
    HLO = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ag = f32[4096]{0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%p0), to_apply=%add
  %slice = f32[1024]{0} slice(%ag), slice={[0:1024]}
  ROOT %out = f32[1024]{0} add(%ar, %slice)
}
"""

    def test_wire_model(self):
        out = hc.analyze(self.HLO, {})
        # all-gather: result - operand = (4096-1024)*4; all-reduce: 2*operand
        assert out["collective_wire_bytes"] == (4096 - 1024) * 4 + 2 * 1024 * 4
        assert out["collective_by_kind"]["all-gather"] == (4096 - 1024) * 4

"""Packed-weight serving tests: the APack planes as the live weight
store (``ServeEngine(weights="apack-int8")``), the fused
decompress-matmul routing in ``models.modules.proj``, and the four
weight-codec regressions this PR fixes:

1. kernel accumulation — ``out_ref`` accumulation across non-consecutive
   grid revisits (Mosaic only guarantees consecutive revisits); partial
   products must accumulate in VMEM scratch and flush once,
2. quantization-axis mismatch — ``compress_linear``'s private
   ``abs(w).max(axis=0)`` vs the serving layer's
   ``quantize_symmetric(..., axis=-1)`` diverged on >2-D tensors,
3. ratio accounting — ``compress_params`` floored payload bits to bytes
   and dropped the dequant scale stream, overstating the ratio,
4. min_size inconsistency — the CLI hardcoded 4096 while the engine
   defaulted 16384; both now share ``DEFAULT_WEIGHT_MIN_SIZE``.
"""
import dataclasses
import inspect
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import configs
from repro.core import quant
from repro.kernels import decompress_matmul as dm
from repro.models import model as M
from repro.models import modules as mm
from repro.serve import (DEFAULT_WEIGHT_MIN_SIZE, Request, ServeEngine,
                         compress_params)

KEY = jax.random.PRNGKey(0)
SRC = Path(list(repro.__path__)[0]).resolve()


def heavy_tail(rs, shape, sigma=0.015, outlier=0.64):
    """Compressible weights: narrow normal bulk + one planted outlier
    every 32 rows of each output channel, so every per-channel int8
    range is outlier-set and the bulk quantizes to a few codes."""
    flat = rs.normal(0.0, sigma, shape).reshape(-1, shape[-1])
    for c in range(flat.shape[1]):
        rows = rs.randint(0, 32) + 32 * np.arange(max(flat.shape[0] // 32, 1))
        rows = rows[rows < flat.shape[0]]
        flat[rows, c] = rs.choice([-1.0, 1.0], rows.size) * outlier
    return flat.reshape(shape).astype(np.float32)


def redraw_params(params, rs, min_size=1024):
    def one(w):
        arr = np.asarray(jax.device_get(w))
        if arr.ndim < 2 or arr.dtype.kind != "f" or arr.size < min_size:
            return w
        return jnp.asarray(heavy_tail(rs, arr.shape).astype(arr.dtype))
    return jax.tree.map(one, params)


# ------------------------------------------- kernel accumulation regression
class TestKernelAccumulation:
    def test_no_output_block_accumulation(self):
        """Structural pin: the kernel must never read-modify-write
        ``out_ref`` across grid steps (the accumulation bug — Mosaic
        does not preserve a revisited output block across the
        non-consecutive revisits this grid produces).  The running sum
        lives in scratch and ``out_ref`` is written exactly once, under
        the final-K-tile guard."""
        src = inspect.getsource(dm._fused_kernel)
        assert "out_ref[...] +=" not in src.replace("  ", " ")
        flush = src[src.index("kt == nk - 1"):]
        assert "out_ref[...] = acc_ref" in flush

    def test_multi_ktile_multi_mblock_matches_reference(self):
        """The failing-before shape: nk > 1 AND multiple M blocks, so
        every output block is revisited with other M blocks in between.
        With the bug, later K-tiles overwrite (or misread) the partial
        sums; fixed, the kernel matches the decode-then-dense oracle."""
        rs = np.random.RandomState(0)
        w = heavy_tail(rs, (96, 40))
        x = rs.normal(0, 1, (20, 96)).astype(np.float32)
        cw = dm.compress_linear(w, tile_k=32)          # nk = 3
        y = dm.compressed_matmul(jnp.asarray(x), cw, block_m=8)  # 3 M blocks
        ref = dm.reference_matmul(jnp.asarray(x), cw)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_block_m_invariance(self):
        """The result cannot depend on the M-block partitioning — a
        direct consequence of the scratch strip holding per-row-block
        sums correctly across interleaved visits."""
        rs = np.random.RandomState(1)
        w = heavy_tail(rs, (64, 24))
        x = rs.normal(0, 1, (17, 64)).astype(np.float32)
        cw = dm.compress_linear(w, tile_k=32)
        ys = [np.asarray(dm.compressed_matmul(jnp.asarray(x), cw,
                                              block_m=bm))
              for bm in (8, 16, 32)]
        for y in ys[1:]:
            np.testing.assert_array_equal(ys[0], y)


# -------------------------------------------- quantization-axis regression
class TestCrossPathQuantization:
    def test_3d_tensor_bit_parity_with_serving_path(self):
        """The mismatch bug: for a wq-like [d, h, dh] tensor the kernel
        path used ``abs(w).max(axis=0)`` over the folded 2-D view (one
        scale per flattened (h, dh) column) while the serving layer
        quantizes the ORIGINAL shape with axis=-1 (one scale per dh,
        reduced over d AND h).  Both paths must produce bit-identical
        int8 codes and dequantized values."""
        rs = np.random.RandomState(2)
        w = heavy_tail(rs, (64, 4, 16))
        # serving-layer convention, on the original shape
        q_ref, qp = quant.quantize_symmetric(jnp.asarray(w), axis=-1)
        q_ref = np.asarray(q_ref).reshape(64, 64)
        sc_ref = np.broadcast_to(np.asarray(qp.scale, np.float32),
                                 w.shape).reshape(64, 64)[0]
        # pack_weights' folded view
        q2, sc = M._pack_quantize(w, 1)
        np.testing.assert_array_equal(q2, q_ref)
        np.testing.assert_array_equal(sc, sc_ref)

    def test_compress_linear_roundtrip_matches_dequant(self):
        """compress_linear -> reference decode dequantizes bit-identically
        to quantize_symmetric's own roundtrip (same codes, same scale)."""
        rs = np.random.RandomState(3)
        w = heavy_tail(rs, (64, 32))
        cw = dm.compress_linear(w, tile_k=32)
        got = np.asarray(dm.reference_matmul(jnp.eye(64, dtype=jnp.float32),
                                             cw))
        q, qp = quant.quantize_symmetric(jnp.asarray(w), axis=-1)
        want = np.asarray(q, np.float32) * np.asarray(qp.scale, np.float32)
        np.testing.assert_array_equal(got, want)


# -------------------------------------------- ratio accounting regression
class TestRatioAccounting:
    def test_compressed_bytes_include_ceil_and_scale(self):
        """The accounting bug floored ``total_bits // 8`` and dropped
        the per-channel scale stream.  The corrected compressed_bytes
        must equal ceil-bytes(payload) + scale bytes + passthrough."""
        rs = np.random.RandomState(4)
        tree = {"w": jnp.asarray(heavy_tail(rs, (64, 64))),
                "b": jnp.zeros((7,), jnp.float32)}
        cp = compress_params(tree, min_size=1024)
        assert len(cp.containers) == 1
        (ct, scale, _dtype), = cp.containers.values()
        expect = -(-ct.total_bits // 8) + scale.nbytes + 7 * 4
        assert cp.compressed_bytes == expect
        assert scale.nbytes == 64 * 4          # per-channel f32, not dropped


# --------------------------------------------- min_size shared default
class TestMinSizeConsistency:
    def test_one_shared_default(self):
        assert dm.DEFAULT_WEIGHT_MIN_SIZE == DEFAULT_WEIGHT_MIN_SIZE
        sig = inspect.signature(compress_params)
        assert sig.parameters["min_size"].default == DEFAULT_WEIGHT_MIN_SIZE

    def test_pack_weights_default_matches(self):
        """pack_weights(min_size=None) must use the shared default: the
        smoke model's largest packable tensor is under 16384 elements,
        so the default packs nothing — while min_size=1024 packs the
        projection/FFN sites."""
        cfg = configs.get_smoke_config("qwen3-1.7b")
        params = M.init_params(cfg, KEY)
        _, st_default = M.pack_weights(cfg, params)
        _, st_small = M.pack_weights(cfg, params, min_size=1024)
        assert st_default["packed_tensors"] == 0
        assert st_small["packed_tensors"] == 7

    def test_cli_uses_shared_default(self):
        """The CLI regression: launch/serve.py hardcoded min_size=4096
        while the engine defaulted 16384.  The flag must default to the
        shared constant and the hardcode must be gone."""
        src = (SRC / "launch" / "serve.py").read_text()
        assert "default=DEFAULT_WEIGHT_MIN_SIZE" in src
        assert "4096" not in src
        assert "min_size=args.weight_min_size" in src


# ------------------------------------------------- pack_weights structure
class TestPackWeights:
    def test_packed_sites_and_dense_exclusions(self):
        cfg = configs.get_smoke_config("qwen3-1.7b")
        params = M.init_params(cfg, KEY)
        packed, stats = M.pack_weights(cfg, params, min_size=1024)
        blk = packed["blocks"][0]
        for name in ("wq", "wk", "wv", "wo"):
            assert isinstance(blk["inner"][name], mm.PackedWeight), name
        for name in ("w_up", "w_gate", "w_down"):
            assert isinstance(blk["ffn"][name], mm.PackedWeight), name
        # the embedding serves the token lookup: stays dense
        assert isinstance(packed["embed"], jax.Array)
        assert stats["packed_tensors"] == 7
        assert 0 < stats["payload_bytes"] < stats["int8_bytes"] * 2
        assert stats["scale_bytes"] > 0

    def test_stacked_planes_carry_layer_axis(self):
        cfg = configs.get_smoke_config("qwen3-1.7b")
        params = M.init_params(cfg, KEY)
        packed, _ = M.pack_weights(cfg, params, min_size=1024)
        pw = packed["blocks"][0]["ffn"]["w_up"]
        L = cfg.num_layers // len(cfg.cycle)
        assert pw.cw.sym_plane.shape[0] == L
        assert pw.cw.stored.shape[0] == L
        assert pw.shape == (cfg.d_model, cfg.d_ff)

    def test_packed_param_specs_split_rules(self):
        """K-split over "model" only when the stream layout divides:
        stream axis sharded for sym/ofs/stored, tables and scale
        replicated, dense leaves P()."""
        from jax.sharding import PartitionSpec as P
        cfg = configs.get_smoke_config("qwen3-1.7b")
        params = M.init_params(cfg, KEY)
        packed, _ = M.pack_weights(cfg, params, min_size=1024, tile_k=32)
        # d_model=64, tile_k=32 -> nk=2, divisible by n_model=2
        specs = M.packed_param_specs(packed, n_model=2)
        sp = specs["blocks"][0]["ffn"]["w_up"]
        leaves = jax.tree_util.tree_leaves(
            sp, is_leaf=lambda x: isinstance(x, P))
        split = [s for s in leaves if s and s[-1] == "model"]
        assert len(split) == 3                  # sym, ofs, stored
        assert specs["embed"] == P()
        # indivisible nk -> replicate everywhere
        specs1 = M.packed_param_specs(packed, n_model=3)
        sp1 = specs1["blocks"][0]["ffn"]["w_up"]
        assert all(s == P() for s in jax.tree_util.tree_leaves(
            sp1, is_leaf=lambda x: isinstance(x, P)))


# ------------------------------------------------- packed serving parity
def _decode_wave(cfg, params, prompts, max_new, **engine_kw):
    eng = ServeEngine(cfg, params, max_batch=len(prompts),
                      max_len=max(len(p) for p in prompts) + max_new + 8,
                      **engine_kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=600)
    assert all(r.done and r.error is None for r in reqs)
    return reqs, eng


def _parity(cfg, packed_params, dense_params, reqs, prompt_len):
    """Teacher-forced parity: re-score the packed engine's sequences
    under both weight stores with one full forward each.  Free-running
    greedy decode compounds a single near-tie argmax flip, so the
    lockstep comparison is the per-position bound."""
    seqs = [np.concatenate([r.prompt, np.asarray(r.tokens, np.int32)])
            for r in reqs]
    batch = {"tokens": jnp.asarray(np.stack(seqs), jnp.int32)}
    lp, _, _ = M.forward(cfg, packed_params, batch, remat=False)
    ld, _, _ = M.forward(cfg, dense_params, batch, remat=False)
    pred = slice(prompt_len - 1, -1)
    lp = lp[:, pred].astype(jnp.float32)
    ld = ld[:, pred].astype(jnp.float32)
    agree = float((jnp.argmax(lp, -1) == jnp.argmax(ld, -1)).mean())
    return agree, float(jnp.max(jnp.abs(lp - ld)))


def _packed_and_dense(cfg, seed=7):
    params = redraw_params(M.init_params(cfg, KEY),
                           np.random.RandomState(seed))
    packed, _ = M.pack_weights(cfg, params, min_size=1024)

    def deq(pw, w):
        if not isinstance(pw, mm.PackedWeight):
            return w
        q, qp = quant.quantize_symmetric(jnp.asarray(w, jnp.float32),
                                         axis=-1)
        return (q.astype(jnp.float32) * qp.scale).astype(w.dtype)

    dense_q = jax.tree.map(deq, packed, params,
                           is_leaf=lambda x: isinstance(x, mm.PackedWeight))
    return params, dense_q


class TestPackedServing:
    def _run(self, cfg, requests=3, prompt_len=8, max_new=5):
        params, dense_q = _packed_and_dense(cfg)
        rng = np.random.default_rng(5)
        prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
                   .astype(np.int32) for _ in range(requests)]
        kw = dict(kv_page_size=4, kv_calib_pages=2)
        reqs_p, eng_p = _decode_wave(cfg, params, prompts, max_new,
                                     weights="apack-int8",
                                     weight_min_size=1024, **kw)
        _decode_wave(cfg, dense_q, prompts, max_new, **kw)
        agree, logit_diff = _parity(cfg, eng_p.params, dense_q, reqs_p,
                                    prompt_len)
        assert agree >= 0.95, (agree, logit_diff)
        # both stores hold the SAME int8 codes; the gap is bf16 weight
        # rounding on the dense einsum + f32 accumulation order
        assert logit_diff < 0.5, logit_diff
        ws = eng_p.weight_stats()
        assert ws["weights"] == "apack-int8"
        assert ws["weight_ratio"] < 1.0
        assert ws["compressed_read_bytes_per_step"] < \
            ws["dense_read_bytes_per_step"]
        return eng_p

    def test_qwen3_lockstep_parity(self):
        cfg = dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                                  kv_cache_dtype="apack-int8")
        self._run(cfg)

    def test_hetero_lockstep_parity(self):
        cfg = dataclasses.replace(configs.get_hetero_smoke_config(),
                                  kv_cache_dtype="apack-int8")
        self._run(cfg)

    def test_dense_default_unchanged(self):
        """weights=None keeps the dense store: no PackedWeight leaves,
        weight_stats reports the dense sentinel."""
        cfg = configs.get_smoke_config("qwen3-1.7b")
        params = M.init_params(cfg, KEY)
        eng = ServeEngine(cfg, params, max_batch=1, max_len=16)
        assert eng.weight_stats() == {"weights": "dense"}
        assert not any(isinstance(x, mm.PackedWeight)
                       for x in jax.tree_util.tree_leaves(
                           eng.params,
                           is_leaf=lambda x: isinstance(x, mm.PackedWeight)))

    def test_unknown_weights_mode_rejected(self):
        cfg = configs.get_smoke_config("qwen3-1.7b")
        params = M.init_params(cfg, KEY)
        with pytest.raises(ValueError, match="apack-int8"):
            ServeEngine(cfg, params, max_batch=1, max_len=16,
                        weights="int4")

    def test_packed_survives_preempt_spill_resume(self):
        """kv_pressure rotation with an undersized pool: the packed
        engine's greedy tokens must be bit-identical to the uncontended
        packed run — preempt/spill/resume replays through the fused
        weight path deterministically."""
        cfg = dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                                  kv_cache_dtype="apack-int8")
        params = redraw_params(M.init_params(cfg, KEY),
                               np.random.RandomState(7))
        per_req = M.PagedKVCache.pages_for_config(cfg, 12, 4)

        def run(pages, pressure):
            eng = ServeEngine(cfg, params, max_batch=3, max_len=16,
                              weights="apack-int8", weight_min_size=1024,
                              kv_page_size=4, kv_calib_pages=2,
                              kv_pages=pages, kv_pressure=pressure,
                              slot_deadline_steps=4 if pressure else None)
            rng = np.random.default_rng(11)
            reqs = [Request(rid=i, prompt=rng.integers(
                        0, cfg.vocab_size, 8).astype(np.int32),
                        max_new_tokens=4) for i in range(3)]
            for r in reqs:
                eng.submit(r)
            eng.run_until_drained(max_steps=400)
            return reqs, eng

        ctrl, _ = run(None, False)
        reqs, eng = run(max(per_req, (3 * per_req) // 2), True)
        assert all(r.done and r.error is None for r in reqs)
        for r, c in zip(reqs, ctrl):
            assert r.tokens == c.tokens
        assert eng.kv_stats()["kv_spill"]["pages"] > 0
        assert eng.weight_stats()["weight_ratio"] < 1.0

"""Checkpoint + supervisor (fault tolerance) tests."""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.runtime import Supervisor, SupervisorConfig


def tree():
    rng = np.random.default_rng(0)
    return {
        "w": jnp.asarray(rng.normal(0, 0.02, (256, 128)), jnp.float32),
        "b16": jnp.asarray(rng.normal(0, 1, (128, 64)), jnp.bfloat16),
        "step": jnp.asarray(7, jnp.int32),
        "nested": {"scale": jnp.ones((64,))},
    }


class TestCheckpoint:
    def test_save_restore_identity(self, tmp_path):
        t = tree()
        ckpt.save(tmp_path, 5, t, extra={"foo": 1})
        out, extra, step = ckpt.restore(tmp_path)
        assert step == 5 and extra == {"foo": 1}
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
            assert np.asarray(a).dtype == np.asarray(b).dtype

    def test_compressed_save_is_bit_exact(self, tmp_path):
        t = tree()
        ckpt.save(tmp_path, 1, t, compress=True)
        out, _, _ = ckpt.restore(tmp_path)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()

    def test_compression_shrinks_trained_like_weights(self, tmp_path):
        rng = np.random.default_rng(0)
        t = {"w": jnp.asarray(rng.normal(0, 0.02, (512, 512)), jnp.float32)}
        d = ckpt.save(tmp_path, 1, t, compress=True)
        with open(d / "manifest.json") as f:
            man = json.load(f)
        stored = sum(l["stored_bits"] for l in man["leaves"])
        assert stored < 512 * 512 * 32 * 0.92

    def test_latest_pointer_and_gc(self, tmp_path):
        t = tree()
        for s in (1, 2, 3, 4, 5):
            ckpt.save(tmp_path, s, t, keep=2)
        assert ckpt.latest_step(tmp_path) == 5
        dirs = sorted(p.name for p in tmp_path.iterdir()
                      if p.name.startswith("step_"))
        assert dirs == ["step_00000004", "step_00000005"]

    def test_async_checkpointer(self, tmp_path):
        saver = ckpt.AsyncCheckpointer(tmp_path)
        saver.save(3, tree())
        saver.wait()
        assert ckpt.latest_step(tmp_path) == 3

    def test_elastic_restore_with_shardings(self, tmp_path):
        t = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        ckpt.save(tmp_path, 1, t)
        sh = {"w": jax.sharding.SingleDeviceSharding(jax.devices()[0])}
        out, _, _ = ckpt.restore(tmp_path, shardings=sh)
        assert np.array_equal(np.asarray(out["w"]), np.asarray(t["w"]))


class TestSupervisor:
    def _sup(self, tmp_path, fail_at=(), max_steps=20, **kw):
        calls = {"n": 0}

        def make_state():
            return {"x": jnp.zeros(())}, {}

        def step_fn(state, step_idx):
            calls["n"] += 1
            if calls["n"] in fail_at:
                raise RuntimeError(f"injected failure at call {calls['n']}")
            return {"x": state["x"] + 1}, {"loss": float(state["x"])}

        cfg = SupervisorConfig(ckpt_dir=str(tmp_path), save_every=5,
                               max_steps=max_steps, async_save=False, **kw)
        return Supervisor(cfg, make_state=make_state, step_fn=step_fn)

    def test_runs_to_completion(self, tmp_path):
        state, hist = self._sup(tmp_path).run()
        assert float(state["x"]) == 20
        assert len(hist) == 20

    def test_restart_from_checkpoint_after_failure(self, tmp_path):
        sup = self._sup(tmp_path, fail_at=(8, 13))
        state, hist = sup.run()
        assert sup.restarts == 2
        # bit-exact final state despite two failures (restored from step 5/10)
        assert float(state["x"]) == 20

    def test_gives_up_after_max_restarts(self, tmp_path):
        sup = self._sup(tmp_path, fail_at=tuple(range(1, 100)),
                        max_restarts=3)
        with pytest.raises(RuntimeError):
            sup.run()

    def test_straggler_watchdog_flags(self, tmp_path):
        calls = {"n": 0}

        def make_state():
            return {"x": jnp.zeros(())}, {}

        def step_fn(state, step_idx):
            calls["n"] += 1
            if calls["n"] >= 12:
                time.sleep(0.3)       # sustained straggle
            return state, {}

        cfg = SupervisorConfig(ckpt_dir=str(tmp_path), save_every=100,
                               max_steps=30, async_save=False,
                               straggler_ratio=4.0, straggler_patience=2,
                               max_restarts=0)
        sup = Supervisor(cfg, make_state=make_state, step_fn=step_fn)
        with pytest.raises(TimeoutError):
            sup.run()
        assert sup.straggler_events >= 2

"""Minimal stand-in for ``hypothesis`` when it is not installed.

The CI image bakes in jax/numpy/pytest but not always hypothesis; rather
than skip every property test, ``conftest.py`` installs this module as
``hypothesis`` so ``@given`` tests still run — with a fixed number of
deterministic pseudo-random examples instead of adaptive search.  Only the
tiny API surface the test-suite uses is provided (``given``, ``settings``,
``strategies.integers/floats/lists``).  Install the real package (see
``requirements.txt``) to get shrinking and adaptive example generation.
"""
from __future__ import annotations

import functools
import inspect
import random

DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def draw(rng: random.Random):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng: rng.choice(options))


class strategies:                                  # "from hypothesis import strategies as st"
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    lists = staticmethod(lists)
    sampled_from = staticmethod(sampled_from)


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(fn, "_stub_max_examples", DEFAULT_EXAMPLES)
            # deterministic per-test seed so failures reproduce
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                fn(*args, *(s.example(rng) for s in strats), **kwargs)
        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[:-len(strats)]
        wrapper.__signature__ = sig.replace(parameters=params)
        wrapper.hypothesis_stub = True
        return wrapper
    return deco


class settings:
    """Both the decorator (``@settings(max_examples=...)``) and the profile
    registry (``settings.register_profile`` / ``load_profile``)."""

    _profiles: dict = {}

    def __init__(self, max_examples: int | None = None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        if self.max_examples is not None:
            # applies whether @settings sits above or below @given
            target = fn.__wrapped__ if hasattr(fn, "__wrapped__") else fn
            target._stub_max_examples = self.max_examples
            fn._stub_max_examples = self.max_examples
        return fn

    @classmethod
    def register_profile(cls, name, *args, **kwargs):
        cls._profiles[name] = (args, kwargs)

    @classmethod
    def load_profile(cls, name):
        pass


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"

"""Paged APack-compressed KV cache tests: activation-mode tables, the page
pool, the Pallas gather-decode kernel, decode parity with the raw int8-KV
path, and ServeEngine scheduling edge cases."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.core import format as fmt, quant, tables
from repro.kernels import fastpath, ref as _ref
from repro.kernels.paged_decode import (gather_bucket, gather_decode,
                                        gather_decode_pallas)
from repro.models import model as M
from repro.models import modules as m
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def apack_cfg(**kw):
    return dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                               kv_cache_dtype="apack-int8", **kw)


# ------------------------------------------------------ activation tables
class TestActivationTables:
    @settings(max_examples=25)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(1, 40))
    def test_every_range_has_nonzero_probability(self, seed, spread):
        """Activation-mode tables must keep every value space encodable:
        no range — however empty during profiling — may get a zero count
        (a zero-count group would brick any unprofiled value landing in
        it, paper §VI "Final Adjustment for Activations")."""
        rng = np.random.default_rng(seed)
        # heavily clustered sample: most of the 256-value space unseen
        vals = (rng.normal(128, spread, 4096).astype(np.int64)) & 0xFF
        t = tables.table_for(vals, is_activation=True)
        counts = np.diff(np.asarray(t.cum))
        assert t.mode == "activation"
        assert counts.shape == (16,)
        assert (counts > 0).all(), counts
        assert counts.sum() == 1024

    @settings(max_examples=10)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_roundtrip_outside_calibration_sample(self, seed):
        """Values never seen while profiling must round-trip bit-exactly
        through the codec (lossless even for unprofiled symbols)."""
        rng = np.random.default_rng(seed)
        calib = (rng.normal(100, 10, 2048).astype(np.int64)) & 0xFF
        t = tables.table_for(calib, is_activation=True)
        # full value space, including everything the table never saw
        unseen = np.setdiff1d(np.arange(256), np.unique(calib))
        assert unseen.size > 0, "calibration sample unexpectedly covered 0..255"
        payload = np.concatenate([np.arange(256), unseen, unseen])
        ct = fastpath.compress_np(payload.astype(np.uint8), t)
        out = fastpath.decompress_np(ct)
        assert np.array_equal(out.astype(np.int64), payload)

    def test_weight_mode_can_brick_unseen_values(self):
        """Contrast case documenting why activations need the slack: a
        weight-mode table may assign empty ranges zero counts."""
        calib = np.full(1024, 7, np.int64)
        t = tables.table_for(calib, is_activation=False)
        counts = np.diff(np.asarray(t.cum))
        assert (counts == 0).any()


# ----------------------------------------------------------- page pool
class TestKVPagePool:
    def make(self, num_pages=6, page_size=4, h=2, dh=8):
        return m.KVPagePool(num_pages, page_size, h, dh, elems_per_stream=16)

    def test_alloc_free_reuse(self):
        pool = self.make()
        pids = [pool.alloc() for _ in range(6)]
        assert sorted(pids) == list(range(6))
        assert pool.alloc() is None                    # exhausted
        for pid in pids[:3]:
            pool.free(pid)
        again = [pool.alloc() for _ in range(3)]
        assert sorted(again) == sorted(pids[:3])       # ids recycled
        assert pool.alloc_count == 9
        assert pool.high_water == 6

    def test_lifecycle_and_accounting(self):
        pool = self.make()
        pid = pool.alloc()
        k = np.ones((2, 8), np.int8)
        s = np.ones(2, np.float32)
        for _ in range(4):
            pool.write_token(pid, k, k, s, s)
        assert pool.state[pid] == m.PAGE_HOT
        hot_bytes = pool.page_bytes(pid)
        assert hot_bytes == pool.dense_bytes(4)
        q2 = np.ones((2, 4, 2, 8), np.int8)
        pool.seal(pid, q2, np.ones((2, 2), np.float32))
        assert pool.state[pid] == m.PAGE_COLD
        # scale amortization alone shrinks the page
        assert pool.page_bytes(pid) < hot_bytes
        assert (pool.tok_q[:, pid] == 0).all()         # hot copy dropped

    def test_overfull_page_rejected(self):
        pool = self.make()
        pid = pool.alloc()
        k = np.zeros((2, 8), np.int8)
        s = np.zeros(2, np.float32)
        for _ in range(4):
            pool.write_token(pid, k, k, s, s)
        # real exceptions, not bare asserts: -O must not strip the guard
        with pytest.raises(RuntimeError, match="overfull"):
            pool.write_token(pid, k, k, s, s)

    def test_double_free_and_bad_seal_rejected(self):
        pool = self.make()
        pid = pool.alloc()
        k = np.zeros((2, 8), np.int8)
        s = np.zeros(2, np.float32)
        pool.write_token(pid, k, k, s, s)
        with pytest.raises(ValueError, match="non-full or non-HOT"):
            pool.seal(pid, np.zeros((2, 4, 2, 8), np.int8),
                      np.zeros((2, 2), np.float32))
        pool.free(pid)
        with pytest.raises(ValueError, match="double free"):
            pool.free(pid)


# ------------------------------------------------- gather-decode kernel
def _pack_pages(pages: np.ndarray, table: tables.ApackTable):
    """Encode [P, S, E] pages into pooled fixed-capacity planes."""
    p, s, e = pages.shape
    ta = _ref.TableArrays.from_table(table)
    outs = [tuple(np.asarray(x) for x in
                  _ref.encode(jnp.asarray(pages[i]), ta, e, 8))
            for i in range(p)]
    return tuple(np.stack([o[i] for o in outs]) for i in range(5))


class TestGatherDecode:
    def setup_method(self):
        rng = np.random.default_rng(7)
        self.E, self.S, self.P = 32, 4, 5
        self.pages = (rng.normal(40, 25, (self.P, self.S, self.E))
                      .astype(np.int64) & 0xFF)
        self.table = tables.table_for(self.pages[:2].reshape(-1),
                                      is_activation=True)
        self.planes = _pack_pages(self.pages, self.table)

    def test_matches_decompress_np_per_page(self):
        """Interpret-mode kernel output == fastpath.decompress_np of the
        same per-page container."""
        sym, ofs, sb, ob, stored = self.planes
        ta = _ref.TableArrays.from_table(self.table)
        idx = np.asarray([3, 0, 2], np.int32)
        out = np.asarray(gather_decode_pallas(
            jnp.asarray(sym), jnp.asarray(ofs), jnp.asarray(stored),
            jnp.asarray(idx), ta.v_min, ta.ol, ta.cum,
            n_steps=self.E, interpret=True))
        for g, pid in enumerate(idx):
            ws = int(np.max(np.where(stored[pid], 0,
                                     (sb[pid] + 31) // 32), initial=0))
            wo = int(np.max((ob[pid] + 31) // 32, initial=0))
            ct = fmt.CompressedTensor(
                shape=(self.S, self.E), bits=8, table=self.table,
                elems_per_stream=self.E, n_valid=self.S * self.E,
                sym_plane=sym[pid][:ws], ofs_plane=ofs[pid][:wo],
                sym_bits=sb[pid], ofs_bits=ob[pid], stored=stored[pid])
            want = fastpath.decompress_np(ct).astype(np.int64)
            assert np.array_equal(out[g], want)
            assert np.array_equal(out[g], self.pages[pid])

    def test_ref_and_pallas_backends_agree(self):
        sym, ofs, sb, ob, stored = self.planes
        ta = _ref.TableArrays.from_table(self.table)
        idx = jnp.asarray(np.asarray([1, 1, 4, 0], np.int32))
        outs = [np.asarray(gather_decode(
            jnp.asarray(sym), jnp.asarray(ofs), jnp.asarray(stored), idx,
            ta.v_min, ta.ol, ta.cum, n_steps=self.E, backend=b))
            for b in ("ref", "pallas_interpret")]
        assert np.array_equal(outs[0], outs[1])

    def test_gather_bucket(self):
        assert gather_bucket(1) == 1
        assert gather_bucket(3) == 4
        assert gather_bucket(129) == 256
        assert gather_bucket(5000) % 1024 == 0 and gather_bucket(5000) >= 5000


# ------------------------------------------- decode parity vs raw int8 KV
class TestCompressedKVDecodeParity:
    def test_logits_within_int8_bound(self):
        """Teacher-forced decode: the paged/compressed KV path must stay
        within the raw-int8-KV error envelope of tests/test_kv_int8.py
        (0.35 vs bf16), and close to the raw int8 path itself."""
        cfg16 = configs.get_smoke_config("qwen3-1.7b")
        cfg8 = dataclasses.replace(cfg16, kv_cache_dtype="int8")
        cfga = apack_cfg()
        params = M.init_params(cfg16, KEY)
        b, s = 2, 12
        rng = np.random.default_rng(1)
        tokens = jnp.asarray(rng.integers(0, cfg16.vocab_size, (b, s)))
        n_layers = cfga.n_cycles * len(cfga.cycle)
        kv = M.PagedKVCache(cfga, num_pages=n_layers * b * 4, page_size=4,
                            calib_pages=2)
        rids = list(range(b))
        for rid in rids:
            kv.add_request(rid)
        cache16 = M.init_cache(cfg16, b, s)
        cache8 = M.init_cache(cfg8, b, s)
        l16s, l8s, las = [], [], []
        for t in range(s):
            tok = tokens[:, t:t + 1]
            l16, cache16 = M.decode_step(cfg16, params, cache16, tok,
                                         jnp.asarray(t))
            l8, cache8 = M.decode_step(cfg8, params, cache8, tok,
                                       jnp.asarray(t))
            la, new_a = M.decode_step(cfga, params,
                                      kv.materialize(rids, s), tok,
                                      jnp.asarray(t))
            kv.append_step_tokens(new_a, rids, [t] * b)
            l16s.append(l16)
            l8s.append(l8)
            las.append(la)
        d16 = np.asarray(jnp.concatenate(l16s, 1), np.float32)
        d8 = np.asarray(jnp.concatenate(l8s, 1), np.float32)
        da = np.asarray(jnp.concatenate(las, 1), np.float32)
        # compression actually ran (pages sealed + packed, lossless reads)
        assert kv.traffic["kv_pages_packed"] > 0
        assert kv.kv_ratio() < 1.0
        # paged path vs raw int8 path: same quantization family, the only
        # extra error is the page-granular re-quantization of cold pages
        assert np.abs(da - d8).max() < 0.35, np.abs(da - d8).max()
        # and the absolute envelope vs bf16 from test_kv_int8.py holds
        assert np.abs(da - d16).max() < 0.35, np.abs(da - d16).max()

    def test_materialize_is_lossless_for_packed_pages(self):
        """Round-trip through seal+pack+gather-decode reproduces the COLD
        int8 payload bit-exactly (APack is lossless; only the page
        re-quantization is lossy, and that happens before packing)."""
        cfg = apack_cfg()
        n_layers = cfg.n_cycles * len(cfg.cycle)
        kv = M.PagedKVCache(cfg, num_pages=n_layers * 8, page_size=4,
                            calib_pages=1)
        kv.add_request(0)
        rng = np.random.default_rng(3)
        h, dh = cfg.num_kv_heads, cfg.head_dim
        toks = 8                                     # two full pages
        kq = rng.integers(-127, 128, (toks, n_layers, h, dh)).astype(np.int8)
        vq = rng.integers(-127, 128, (toks, n_layers, h, dh)).astype(np.int8)
        ks = rng.uniform(0.01, 0.02, (toks, n_layers, h)).astype(np.float32)
        vs = rng.uniform(0.01, 0.02, (toks, n_layers, h)).astype(np.float32)
        for t in range(toks):
            kv.append_token(0, kq[t], vq[t], ks[t], vs[t])
        assert kv.traffic["kv_pages_packed"] == n_layers * 2
        # reference: what seal() stored before packing scrubbed it
        cache = kv.materialize([0], toks)
        for layer in range(n_layers):
            c, j = layer % len(cfg.cycle), layer // len(cfg.cycle)
            got_k = np.asarray(cache["blocks"][c]["k"])[j, 0]
            got_s = np.asarray(cache["blocks"][c]["k_scale"])[j, 0]
            f = kq[:, layer].astype(np.float32) * ks[:, layer][..., None]
            for pno in range(2):
                page = f[pno * 4:(pno + 1) * 4]
                sc = np.maximum(np.abs(page).max(axis=(0, 2)), 1e-8) / 127.0
                want = np.clip(np.round(page / sc[None, :, None]),
                               -127, 127).astype(np.int8)
                assert np.array_equal(got_k[pno * 4:(pno + 1) * 4], want)
                assert np.allclose(got_s[pno * 4:(pno + 1) * 4],
                                   np.broadcast_to(sc, (4, h)))


# ------------------------------------------------ engine scheduling edges
def _mk_engine(max_batch=2, max_len=32, **kw):
    cfg = apack_cfg()
    params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
    return cfg, ServeEngine(cfg, params, max_batch=max_batch,
                            max_len=max_len, kv_page_size=4,
                            kv_calib_pages=2, **kw)


class TestPagedEngineScheduling:
    def test_paged_generation_drains_and_frees_all_pages(self):
        cfg, eng = _mk_engine(max_batch=3, max_len=32)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 9)
                        .astype(np.int32), max_new_tokens=5)
                for i in range(5)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=200)
        assert all(r.done for r in reqs)
        assert all(len(r.tokens) >= 5 for r in reqs)
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages
        assert eng._reserved_total == 0
        ks = eng.kv_stats()
        assert ks["kv_pages_packed"] > 0
        assert ks["kv_ratio"] < 1.0

    def test_eos_mid_batch_retires_slot_early(self):
        """A request hitting EOS mid-flight retires (frees its pages) while
        its batchmates keep decoding."""
        cfg, eng = _mk_engine(max_batch=2, max_len=48)
        rng = np.random.default_rng(4)
        prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
                   for _ in range(2)]
        # dry run to learn a token request 0 will emit mid-stream
        probe = [Request(rid=i, prompt=p.copy(), max_new_tokens=10)
                 for i, p in enumerate(prompts)]
        for r in probe:
            eng.submit(r)
        eng.run_until_drained(max_steps=200)
        eos = probe[0].tokens[2]                      # emitted at step 3
        cfg2, eng2 = _mk_engine(max_batch=2, max_len=48)
        reqs = [Request(rid=10 + i, prompt=p.copy(), max_new_tokens=10,
                        eos_id=(eos if i == 0 else None))
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng2.submit(r)
        eng2.run_until_drained(max_steps=200)
        assert all(r.done for r in reqs)
        assert len(reqs[0].tokens) <= 4               # retired early on eos
        assert len(reqs[1].tokens) >= 10              # batchmate unaffected
        assert eng2.kv.pool.free_count == eng2.kv.pool.num_pages

    def test_admission_blocks_when_pool_exhausted_then_recovers(self):
        """Free slots but no free pages: requests queue until a retire
        returns pages, and page ids are recycled across waves."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        n_layers = cfg.n_cycles * len(cfg.cycle)
        # pool sized for exactly ONE in-flight request (4 pages/layer)
        eng = ServeEngine(cfg, params, max_batch=4, max_len=16,
                          kv_page_size=4, kv_calib_pages=2,
                          kv_pages=n_layers * 4)
        rng = np.random.default_rng(5)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=4)
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        # first admit: only one request fits despite 4 free slots
        eng._retire()
        eng._admit()
        assert sum(r is not None for r in eng.active) == 1
        assert len(eng.queue) == 2
        assert eng.stats["kv_admission_blocked"] > 0
        eng.run_until_drained(max_steps=300)
        assert all(r.done for r in reqs)
        # serialized waves reused the same page ids: lifetime allocs exceed
        # the pool high-water mark
        assert eng.kv.pool.alloc_count > eng.kv.pool.high_water
        assert eng.kv.pool.free_count == eng.kv.pool.num_pages

    def test_oversized_request_rejected_at_submit(self):
        """A request whose worst-case reservation exceeds the whole pool
        can never be admitted — fail fast instead of spinning forever."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        n_layers = cfg.n_cycles * len(cfg.cycle)
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64,
                          kv_page_size=4, kv_pages=n_layers * 2)
        with pytest.raises(ValueError, match="pages worst-case"):
            eng.submit(Request(rid=0,
                               prompt=np.arange(12, dtype=np.int32),
                               max_new_tokens=8))

    def test_slot_reuse_after_retire_keeps_outputs_correct(self):
        """Batched paged engine == one-at-a-time paged engine (greedy),
        exercising slot+page reuse across admissions."""
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        rng = np.random.default_rng(6)
        prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
                   for _ in range(3)]
        seq_out = []
        for p in prompts:
            eng = ServeEngine(cfg, params, max_batch=1, max_len=24,
                              kv_page_size=4, kv_calib_pages=2)
            r = Request(rid=0, prompt=p, max_new_tokens=4)
            eng.submit(r)
            eng.run_until_drained(max_steps=100)
            seq_out.append(r.tokens[:4])
        eng = ServeEngine(cfg, params, max_batch=2, max_len=24,
                          kv_page_size=4, kv_calib_pages=2)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=200)
        for r, ref_toks in zip(reqs, seq_out):
            assert r.tokens[:4] == ref_toks, (r.tokens, ref_toks)

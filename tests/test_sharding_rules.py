"""Sharding-rule unit tests (no compilation needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    # 1 device is enough: fit_spec only reads axis sizes from the mesh shape
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Axis sizes only — what fit_spec consumes."""
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fit_spec_drops_indivisible():
    m = FakeMesh(data=16, model=16)
    spec = sh.fit_spec(P("data", "model"), (256, 8), m)
    assert spec == P("data", None)           # 8 % 16 != 0
    spec = sh.fit_spec(P(("data", "model"), None), (512, 7), m)
    assert spec == P(("data", "model"), None)
    spec = sh.fit_spec(P(("data", "model"), None), (100, 7), m)
    assert spec == P(None, None)             # 100 % 256 != 0


def test_param_spec_rules():
    f = ("data",)
    mk = lambda nd: jnp.zeros((2,) * nd)   # noqa: E731
    assert sh._param_spec("embed", mk(2), f) == P("model", f)
    # measured-better layout (see sharding.py comment): D over model,
    # V over fsdp — NOT the naive P(None, "model")
    assert sh._param_spec("unembed", mk(2), f) == P("model", f)
    assert sh._param_spec("blocks/0/inner/wq", mk(4), f) == \
        P(None, f, "model", None)
    assert sh._param_spec("blocks/0/inner/wo", mk(4), f) == \
        P(None, "model", None, f)
    assert sh._param_spec("blocks/0/ffn/wi", mk(4), f) == \
        P(None, "model", f, None)
    assert sh._param_spec("blocks/0/norm1", mk(2), f) == P(None, None)


def test_moe_ep_variant_switches_expert_axis():
    f = ("data",)
    mk = lambda nd: jnp.zeros((2,) * nd)   # noqa: E731
    sh.set_mesh_context(None, moe_ep=True)
    try:
        assert sh._param_spec("blocks/0/ffn/wi", mk(3), f) == \
            P(f, "model", None)
        assert sh._param_spec("blocks/0/ffn/wo", mk(3), f) == \
            P(f, None, "model")
    finally:
        sh.set_mesh_context(None)
    assert sh._param_spec("blocks/0/ffn/wi", mk(3), f) == \
        P("model", f, None)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4, 4))
    assert sh.constrain(x, "residual") is x


# ------------------------------------------------- serving plane rules
def test_plane_pspec_rules():
    # page planes shard their page axis over "data" (contiguous per-shard
    # page ranges) and, where a head axis exists, heads over "model"
    assert sh.plane_pspec("tok_k") == P("data", None, "model", None)
    assert sh.plane_pspec("cold_v") == P("data", None, "model", None)
    assert sh.plane_pspec("tok_sk") == P("data", None, "model")
    assert sh.plane_pspec("pscale_v") == P("data", "model")
    # APack streams interleave heads inside the coded words — no head
    # axis to split, so the compressed planes shard pages only
    assert sh.plane_pspec("sym_k") == P("data", None, None)
    assert sh.plane_pspec("ofs_v") == P("data", None, None)
    assert sh.plane_pspec("stored_k") == P("data", None)
    # stacked decode tables replicate (every shard decodes any page)
    assert sh.plane_pspec("vm") == P(None, None)
    assert sh.plane_pspec("ol") == P(None, None)
    assert sh.plane_pspec("cum") == P(None, None)


def test_plane_pspec_unknown_name_raises():
    with pytest.raises(KeyError, match="no plane partition rule"):
        sh.plane_pspec("nope")


def test_plane_pspecs_full_rule_set():
    specs = sh.plane_pspecs()
    assert set(specs) == set(sh._PLANE_RULES)
    fake = {"tok_k": None, "vm": None}
    assert set(sh.plane_pspecs(fake)) == {"tok_k", "vm"}


def test_plane_shardings_drop_indivisible(mesh):
    # the 1x1 fixture mesh divides everything; a fat fake model axis
    # must drop the head axis (replicated heads), never raise
    planes = {"tok_k": jnp.zeros((8, 4, 2, 16), jnp.int8),
              "sym_k": jnp.zeros((8, 2, 32), jnp.uint32),
              "vm": jnp.zeros((4, 256), jnp.uint32)}
    named = sh.plane_shardings(mesh, planes)
    assert set(named) == set(planes)
    assert named["tok_k"].spec == P("data", None, "model", None)
    m = FakeMesh(data=1, model=16)           # 2 heads % 16 != 0
    assert sh.fit_spec(sh.plane_pspec("tok_k"), (8, 4, 2, 16), m) == \
        P("data", None, None, None)

"""Sharding-rule unit tests (no compilation needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.models import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    # 1 device is enough: fit_spec only reads axis sizes from the mesh shape
    return jax.sharding.Mesh(
        jax.numpy.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


class FakeMesh:
    """Axis sizes only — what fit_spec consumes."""
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_fit_spec_drops_indivisible():
    m = FakeMesh(data=16, model=16)
    spec = sh.fit_spec(P("data", "model"), (256, 8), m)
    assert spec == P("data", None)           # 8 % 16 != 0
    spec = sh.fit_spec(P(("data", "model"), None), (512, 7), m)
    assert spec == P(("data", "model"), None)
    spec = sh.fit_spec(P(("data", "model"), None), (100, 7), m)
    assert spec == P(None, None)             # 100 % 256 != 0


def test_param_spec_rules():
    f = ("data",)
    mk = lambda nd: jnp.zeros((2,) * nd)   # noqa: E731
    assert sh._param_spec("embed", mk(2), f) == P("model", f)
    # measured-better layout (see sharding.py comment): D over model,
    # V over fsdp — NOT the naive P(None, "model")
    assert sh._param_spec("unembed", mk(2), f) == P("model", f)
    assert sh._param_spec("blocks/0/inner/wq", mk(4), f) == \
        P(None, f, "model", None)
    assert sh._param_spec("blocks/0/inner/wo", mk(4), f) == \
        P(None, "model", None, f)
    assert sh._param_spec("blocks/0/ffn/wi", mk(4), f) == \
        P(None, "model", f, None)
    assert sh._param_spec("blocks/0/norm1", mk(2), f) == P(None, None)


def test_moe_ep_variant_switches_expert_axis():
    f = ("data",)
    mk = lambda nd: jnp.zeros((2,) * nd)   # noqa: E731
    sh.set_mesh_context(None, moe_ep=True)
    try:
        assert sh._param_spec("blocks/0/ffn/wi", mk(3), f) == \
            P(f, "model", None)
        assert sh._param_spec("blocks/0/ffn/wo", mk(3), f) == \
            P(f, None, "model")
    finally:
        sh.set_mesh_context(None)
    assert sh._param_spec("blocks/0/ffn/wi", mk(3), f) == \
        P("model", f, None)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4, 4))
    assert sh.constrain(x, "residual") is x

"""Serving engine + compressed-weights tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serve import (Request, ServeEngine, compress_params,
                         decompress_params)

KEY = jax.random.PRNGKey(0)


def small_cfg():
    return configs.get_smoke_config("qwen3-1.7b")


class TestCompressedParams:
    def test_roundtrip_quantization_error_only(self):
        cfg = small_cfg()
        params = M.init_params(cfg, KEY)
        cp = compress_params(params, min_size=1024)
        out = decompress_params(cp)
        for (pa, a), (pb, b) in zip(
                sorted(jax.tree_util.tree_leaves_with_path(params),
                       key=lambda kv: str(kv[0])),
                sorted(jax.tree_util.tree_leaves_with_path(out),
                       key=lambda kv: str(kv[0]))):
            a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
            if a.size >= 1024 and a.ndim >= 2:
                # int8 symmetric per-channel error bound
                amax = np.abs(a).max(axis=tuple(range(a.ndim - 1)))
                assert np.abs(a - b).max() <= amax.max() / 127 * 1.01
            else:
                assert np.array_equal(a, b)

    def test_compression_accounting(self):
        cfg = small_cfg()
        params = M.init_params(cfg, KEY)
        cp = compress_params(params, min_size=1024)
        assert cp.ratio > 2.0     # fp32 -> int8+APack is at least ~4x/1.x
        # regression: the old accounting floored total_bits // 8 and
        # dropped the per-channel dequant scale stream — the reported
        # ratio must reconstruct exactly from ceil-bytes + scale bytes
        # + passthrough bytes
        expect = sum(-(-ct.total_bits // 8) + scale.nbytes
                     for ct, scale, _ in cp.containers.values())
        expect += sum(arr.nbytes for arr in cp.passthrough.values())
        assert cp.compressed_bytes == expect
        floored = sum(ct.total_bits // 8
                      for ct, _, _ in cp.containers.values())
        floored += sum(arr.nbytes for arr in cp.passthrough.values())
        assert cp.compressed_bytes > floored   # the bug overstated ratio

    def test_weight_tables_use_weight_mode(self):
        # regression: weight matrices must use the weight-mode partitioning
        # heuristic (paper §IV), not the activation final-adjustment (§VI)
        cfg = small_cfg()
        params = M.init_params(cfg, KEY)
        cp = compress_params(params, min_size=1024)
        assert cp.containers, "expected at least one compressed matrix"
        for ct, _scale, _dtype in cp.containers.values():
            assert ct.table.mode == "weight"


class TestEngine:
    def test_batched_generation_drains(self):
        cfg = small_cfg()
        params = M.init_params(cfg, KEY)
        engine = ServeEngine(cfg, params, max_batch=4, max_len=48)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=6)
                for i in range(6)]
        for r in reqs:
            engine.submit(r)
        engine.run_until_drained(max_steps=200)
        assert all(r.done for r in reqs)
        assert all(len(r.tokens) >= 6 for r in reqs)
        assert engine.stats["completed"] == 6

    def test_engine_matches_sequential_decode(self):
        """Batched engine output == running each request alone (greedy)."""
        cfg = small_cfg()
        params = M.init_params(cfg, KEY)
        rng = np.random.default_rng(1)
        prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
                   for _ in range(3)]
        # sequential reference
        seq_out = []
        for p in prompts:
            eng = ServeEngine(cfg, params, max_batch=1, max_len=32)
            r = Request(rid=0, prompt=p, max_new_tokens=5)
            eng.submit(r)
            eng.run_until_drained()
            seq_out.append(r.tokens[:5])
        # batched
        eng = ServeEngine(cfg, params, max_batch=3, max_len=32)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=5)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        for r, ref in zip(reqs, seq_out):
            assert r.tokens[:5] == ref, (r.tokens, ref)

    def test_staggered_admission(self):
        """Slots freed mid-flight admit queued requests with correct state."""
        cfg = small_cfg()
        params = M.init_params(cfg, KEY)
        rng = np.random.default_rng(2)
        eng = ServeEngine(cfg, params, max_batch=2, max_len=64)
        reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8)
                        .astype(np.int32), max_new_tokens=3 + 2 * i)
                for i in range(4)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=300)
        assert all(r.done for r in reqs)
        assert [len(r.tokens) >= r.max_new_tokens for r in reqs]

"""Adaptive table refresh & page re-pack under drifting serving traffic.

Drift-scenario harness: a synthetic two-phase workload (distribution shift
mid-serve) drives the drift monitors, both refresh triggers (compression
regression vs. calibration-time expectation, and every-M-sealed-pages),
the generation-versioned table pool, and the budgeted atomic re-pack —
asserting losslessness throughout (re-packed pages round-trip bit-exactly,
greedy tokens are identical with and without refresh) and that the
*measured* ``kv_ratio`` improves where the frozen-table control degrades.

Synthetic phases write int8 K/V directly into the paged cache with
*constant* quantization scales so the page-seal re-quantization preserves
the distribution shape: "peaked" tokens live on a 5-point lattice
(~2.3 bits/value under a matched table), "broad" tokens are uniform int8
(~7.2 bits/value) — a peaked-calibrated table degrades toward stored-mode
widths on broad data, which is exactly the drift failure mode.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import configs
from repro.core import format as fmt
from repro.core import tables as ctables
from repro.kernels import fastpath
from repro.kernels import ref as _codec
from repro.kernels.paged_decode import table_row
from repro.models import model as M
from repro.models import modules as m
from repro.serve import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def apack_cfg(arch="qwen3-1.7b", **kw):
    return dataclasses.replace(configs.get_smoke_config(arch),
                               kv_cache_dtype="apack-int8", **kw)


def make_kv(**kw):
    cfg = apack_cfg()
    kw.setdefault("page_size", 4)
    kw.setdefault("calib_pages", 2)
    return M.PagedKVCache(cfg, num_pages=256, **kw)


def synth_token(rng, kv, mode):
    """One synthetic appended token with constant scales (the page-seal
    re-quantization then preserves the value distribution's shape).

    ``peaked``: 5-point lattice, ~2.3 bits/value under a matched table.
    ``shifted``: a *different* 7-point lattice — still highly compressible
    once re-fitted, but its points fall into the peaked table's stolen-
    count ranges (stored-mode widths): the drift that refresh recovers.
    ``broad``: uniform int8 — incompressible by any table (the regression
    trigger's worst case)."""
    h, dh, n = kv.pool.kv_heads, kv.pool.head_dim, kv.n_layers
    if mode == "peaked":
        q = (64 * rng.integers(-2, 3, (n, h, dh))).clip(-127, 127)
    elif mode == "shifted":
        q = (32 * rng.integers(-3, 4, (n, h, dh))).clip(-127, 127)
    else:                                     # broad: uniform int8
        q = rng.integers(-127, 128, (n, h, dh))
    q = q.astype(np.int8)
    s = np.full((n, h), 0.01, np.float32)
    return q, q.copy(), s, s.copy()


def feed(kv, rid, rng, n_tokens, mode):
    for _ in range(n_tokens):
        kv.append_token(rid, *synth_token(rng, kv, mode))


def page_tensor(kv, layer, kind, pid) -> fmt.CompressedTensor:
    """View one PACKED pool page as a ``CompressedTensor`` coded with the
    table generation recorded in ``page_gen`` — the ``decompress_np``
    round-trip oracle for re-pack losslessness."""
    pool = kv.pool
    table = kv._table_at(int(kv.page_gen[pid]), layer, kind)
    return fmt.CompressedTensor(
        shape=(pool.page_size, pool.kv_heads, pool.head_dim),
        bits=8, table=table, elems_per_stream=pool.elems_per_stream,
        n_valid=pool.n_streams * pool.elems_per_stream,
        sym_plane=pool.sym[kind, pid].copy(),
        ofs_plane=pool.ofs[kind, pid].copy(),
        sym_bits=pool.sym_bits[kind, pid].copy(),
        ofs_bits=pool.ofs_bits[kind, pid].copy(),
        stored=pool.stored[kind, pid].copy())


# ---------------------------------------------------------- drift monitor
class TestDriftMonitor:
    def test_sketch_accumulates_only_after_calibration(self):
        kv = make_kv()
        rng = np.random.default_rng(0)
        kv.add_request(0)
        layer = kv.attn_layers[0]
        feed(kv, 0, rng, 2 * kv.page_size * kv.calib_pages, "broad")
        assert kv.tables[layer][0] is not None
        base = int(kv.drift_pages[layer])
        feed(kv, 0, rng, 3 * kv.page_size, "broad")
        assert int(kv.drift_pages[layer]) == base + 3
        # every sealed page contributes exactly page_size*H*dh values/kind
        per_page = kv.page_size * kv.pool.kv_heads * kv.pool.head_dim
        assert kv.drift_hists[layer, 0].sum() == \
            int(kv.drift_pages[layer]) * per_page

    def test_regression_trigger_fires_on_distribution_shift(self):
        """Peaked calibration + broad phase B: expected bits under the
        frozen table regress far past the calibration-time expectation."""
        kv = make_kv(refresh_threshold=0.3, refresh_min_pages=4)
        rng = np.random.default_rng(1)
        kv.add_request(0)
        feed(kv, 0, rng, 24, "peaked")
        assert kv.check_refresh() == []           # in-distribution: quiet
        kv.drift_hists[:] = 0
        kv.drift_pages[:] = 0
        feed(kv, 0, rng, 24, "broad")
        st_ = kv.drift_status(kv.attn_layers[0])
        assert st_["regression"] > 1.3
        due = kv.check_refresh()
        assert set(due) == set(kv.attn_layers)

    def test_every_m_pages_trigger_fires_without_drift(self):
        kv = make_kv(refresh_every_pages=6, refresh_min_pages=2)
        rng = np.random.default_rng(2)
        kv.add_request(0)
        feed(kv, 0, rng, 8 + 6 * kv.page_size, "broad")
        assert set(kv.check_refresh()) == set(kv.attn_layers)

    def test_in_distribution_stays_quiet(self):
        kv = make_kv(refresh_threshold=0.15, refresh_min_pages=4)
        rng = np.random.default_rng(3)
        kv.add_request(0)
        feed(kv, 0, rng, 48, "broad")
        assert kv.check_refresh() == []
        assert kv.maybe_refresh() == []
        assert kv.generation == 0

    def test_refresh_bumps_generation_resets_sketch_queues_repack(self):
        kv = make_kv(refresh_threshold=0.3, refresh_min_pages=4)
        rng = np.random.default_rng(4)
        kv.add_request(0)
        feed(kv, 0, rng, 24, "peaked")
        n_packed = sum(len(s) for s in kv._packed)
        assert n_packed > 0
        feed(kv, 0, rng, 24, "broad")
        due = kv.maybe_refresh()
        assert set(due) == set(kv.attn_layers)
        assert kv.generation == 1
        assert all(int(kv.table_gen[layer]) == 1 for layer in due)
        assert all(int(kv.drift_pages[layer]) == 0 for layer in due)
        # every PACKED page of a refreshed layer is queued exactly once
        assert len(kv._repack_queue) == sum(len(s) for s in kv._packed)
        # mid-refresh state: pages still stamped gen 0, tables stacked
        # with two generations, calibration tables preserved in rows 0
        vm, ol, cm = kv._tables_stacked()
        assert vm.shape[0] == 2 * kv.n_layers * 2
        layer = kv.attn_layers[0]
        old = kv._table_at(0, layer, 0)
        row = table_row(0, layer, 0, kv.n_layers)
        assert np.array_equal(vm[row], np.asarray(old.v_min, np.int32))
        new_row = table_row(1, layer, 0, kv.n_layers)
        assert not np.array_equal(vm[row], vm[new_row])


# --------------------------------------------------------- re-pack (lossless)
class TestRepack:
    def _drifted_kv(self, budget=None):
        kv = make_kv(refresh_threshold=0.3, refresh_min_pages=4)
        rng = np.random.default_rng(5)
        kv.add_request(0)
        feed(kv, 0, rng, 24, "peaked")
        feed(kv, 0, rng, 24, "shifted")
        return kv, rng

    def test_repacked_pages_round_trip_bit_exact_vs_decompress_np(self):
        kv, _ = self._drifted_kv()
        # oracle values of every PACKED page under its pre-refresh table
        want = {}
        for layer in kv.attn_layers:
            for pid in kv._packed[layer]:
                for kind in (0, 1):
                    want[(layer, pid, kind)] = fastpath.decompress_np(
                        page_tensor(kv, layer, kind, pid))
        assert kv.maybe_refresh()
        n = kv.repack_pending()
        assert n == len(want) // 2
        # the size gate migrated the drifted (broad) pages and kept the
        # peaked ones on their old — already optimal — generation
        assert kv.traffic["kv_repack_pages"] > 0
        assert kv.traffic["kv_repack_kept"] > 0
        gens = {int(kv.page_gen[p]) for s in kv._packed for p in s}
        assert gens == {0, 1}
        for (layer, pid, kind), w in want.items():
            got = fastpath.decompress_np(page_tensor(kv, layer, kind, pid))
            assert np.array_equal(got, w), (layer, pid, kind)

    def test_budgeted_repack_mixed_generations_decode_identically(self):
        kv, _ = self._drifted_kv()
        pre = jax.tree.map(np.asarray, kv.materialize([0], 64))
        kv.maybe_refresh()
        kv.repack_pending(budget=3)           # some pages old-gen, some new
        gens = {int(kv.page_gen[p]) for s in kv._packed for p in s}
        assert gens == {0, 1}
        mid = jax.tree.map(np.asarray, kv.materialize([0], 64))
        assert kv.repack_pending() > 0        # drain the rest
        post = jax.tree.map(np.asarray, kv.materialize([0], 64))
        for a, b in ((pre, mid), (mid, post)):
            jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                         a, b)

    def test_repack_skips_freed_and_already_current_pages(self):
        kv, _ = self._drifted_kv()
        kv.maybe_refresh()
        layer = kv.attn_layers[0]
        victim = sorted(kv._packed[layer])[0]
        kv._packed[layer].discard(victim)     # simulate eviction/release
        queued = len(kv._repack_queue)
        done = kv.repack_pending()
        assert done == queued - 1             # exactly the victim skipped
        assert int(kv.page_gen[victim]) == 0  # and left untouched
        assert len(kv._repack_queue) == 0
        # re-queue everything: swapped pages are current (skipped without
        # work), size-gate-kept pages re-evaluate and are kept again —
        # nothing swaps and no generation moves
        swapped = kv.traffic["kv_repack_pages"]
        gens_before = [int(g) for g in kv.page_gen]
        for lyr in kv.attn_layers:
            for pid in kv._packed[lyr]:
                kv._repack_queue.append((lyr, pid))
        redone = kv.repack_pending()
        assert redone == done - swapped       # only kept pages re-evaluate
        assert kv.traffic["kv_repack_pages"] == swapped
        assert [int(g) for g in kv.page_gen] == gens_before

    def test_pool_repack_guards_non_packed_pages(self):
        kv, _ = self._drifted_kv()
        pool = kv.pool
        hot = pool.alloc()                    # fresh page: HOT, unsealed
        z2 = lambda *s: np.zeros((2, *s))
        planes = (z2(pool.sym_words, pool.n_streams),
                  z2(pool.ofs_words, pool.n_streams),
                  z2(pool.n_streams), z2(pool.n_streams),
                  np.zeros((2, pool.n_streams), bool))
        with pytest.raises(ValueError, match="repack of non-PACKED"):
            pool.repack(hot, planes)


# ------------------------------------------- losslessness property (stub ok)
def _table_from_seed(seed: int, peak: int) -> ctables.ApackTable:
    """A random activation-mode table: histogram of a random mixture of a
    peaked lattice and a uniform floor (``peak`` skews the mixture)."""
    rng = np.random.default_rng(seed)
    vals = np.concatenate([
        rng.integers(0, 256, 512),
        np.repeat(rng.integers(0, 256, 4), peak)])
    return ctables.find_table(ctables.histogram(vals), bits=8,
                              is_activation=True)


class TestRepackLosslessProperty:
    @settings(max_examples=8)
    @given(st.integers(0, 2 ** 31), st.integers(0, 2 ** 31),
           st.integers(0, 2 ** 31), st.integers(1, 2000))
    def test_repack_equals_decode_under_old_table(self, s_vals, s_a, s_b,
                                                  peak):
        """For random symbol streams and random table pairs (A, B):
        encoding under A, decoding, re-encoding under B, decoding again
        reproduces the stream exactly — losslessness is table-independent,
        which is the whole reason re-pack can swap tables under live
        pages."""
        rng = np.random.default_rng(s_vals)
        n_streams, e = 2, 32
        vals = rng.integers(0, 256, (n_streams, e)).astype(np.int32)
        ta = _codec.TableArrays.from_table(_table_from_seed(s_a, peak))
        tb = _codec.TableArrays.from_table(_table_from_seed(s_b, peak))
        pa = _codec.encode(jnp.asarray(vals), ta, e, 8)
        dec_a = np.asarray(_codec.decode(pa[0], pa[1], pa[4], ta, e, 8))
        assert np.array_equal(dec_a, vals)
        pb = _codec.encode(jnp.asarray(dec_a.astype(np.int32)), tb, e, 8)
        dec_b = np.asarray(_codec.decode(pb[0], pb[1], pb[4], tb, e, 8))
        assert np.array_equal(dec_b, vals)


# ------------------------------------------------------ re-pack accounting
class TestRepackAccounting:
    def test_repack_does_not_touch_read_stream_ratios(self):
        """The re-pack read+write is its own counter (``kv.repack``): the
        attention-read stream ratios must not double-count the re-coded
        bytes."""
        kv = make_kv(refresh_threshold=0.3, refresh_min_pages=4)
        rng = np.random.default_rng(6)
        kv.add_request(0)
        feed(kv, 0, rng, 24, "peaked")
        feed(kv, 0, rng, 24, "shifted")
        kv.maybe_refresh()
        before = dict(kv.traffic)
        packed_before = before["kv_pages_packed"]
        n = kv.repack_pending()
        assert n > 0
        t = kv.traffic
        for key in ("kv_read_bytes", "kv_raw_bytes", "kv_read_bytes_global",
                    "kv_raw_bytes_global", "kv_read_bytes_local",
                    "kv_raw_bytes_local", "kv_table_bytes"):
            assert t[key] == before[key], key
        # ...and kv_pages_packed counts initial packs only, not re-packs
        assert t["kv_pages_packed"] == packed_before
        assert t["kv_repack_pages"] + t["kv_repack_kept"] == n
        assert t["kv_repack_pages"] > 0
        assert t["kv_repack_read_bytes"] > 0
        assert t["kv_repack_write_bytes"] > 0
        rp = kv.stream_stats()["repack"]
        assert rp["pages"] + rp["kept"] == n and rp["generation"] == 1
        assert rp["pending"] == 0

    def test_engine_kv_stats_exposes_repack_counters(self):
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        eng = ServeEngine(cfg, params, max_batch=1, max_len=16,
                          kv_page_size=4, kv_refresh=True)
        ks = eng.kv_stats()
        assert ks["kv_repack"] == {"read_bytes": 0, "write_bytes": 0,
                                   "pages": 0, "kept": 0, "refreshes": 0,
                                   "generation": 0, "pending": 0}
        assert eng.stats["kv_refreshes"] == 0
        assert eng.stats["kv_pages_repacked"] == 0


# --------------------------------------------- measured ratio: drift harness
class TestSyntheticDriftRatio:
    def test_refresh_improves_ratio_where_frozen_degrades(self):
        """The headline drift scenario at the cache level: phase A on one
        lattice, phase B on a different one.  The frozen control's
        *measured* read ratio degrades from phase A to phase B (its
        peaked tables push the shifted pages toward stored-mode widths);
        the refreshed cache re-fits and its phase-B ratio beats the
        frozen control's on the same traffic."""
        def run(refresh: bool):
            kv = make_kv(refresh_threshold=0.2, refresh_min_pages=4,
                         calib_pages=2)
            rng = np.random.default_rng(7)
            kv.add_request(0)
            windows = []
            for mode in ("peaked", "shifted"):
                t0 = dict(kv.traffic)
                for _ in range(8 * kv.page_size):
                    kv.append_token(0, *synth_token(rng, kv, mode))
                    # a decode step reads the whole working set (what
                    # step_meta/materialize charge every engine step)
                    kv._accrue_read_traffic([0], 256)
                    if refresh:
                        kv.refresh_step(budget=4)
                d = lambda k: kv.traffic[k] - t0[k]
                windows.append((d("kv_read_bytes") + d("kv_table_bytes"))
                               / d("kv_raw_bytes"))
            return kv, windows

        kv_f, (a_f, b_f) = run(False)
        kv_r, (a_r, b_r) = run(True)
        assert kv_f.generation == 0
        assert kv_r.generation >= 1
        assert kv_r.traffic["kv_repack_pages"] > 0
        # frozen control degrades under drift...
        assert b_f > a_f * 1.05, (a_f, b_f)
        # ...refresh recovers: strictly better than frozen on phase B
        assert b_r < b_f, (b_r, b_f)


# --------------------------------------------------- engine drift smoke
def _two_phase_engine(params, cfg, *, refresh: bool, fused: bool = True,
                      every: int | None = 24):
    """Two-phase qwen3 workload: diverse prompts, then a repetitive hot
    prompt (the 'traffic narrows to a hot workload' drift).  Returns
    (engine, [phase ratios incl. table overhead], token streams)."""
    rng = np.random.default_rng(11)
    eng = ServeEngine(cfg, params, max_batch=4, max_len=96, kv_page_size=4,
                      kv_calib_pages=1, kv_fused=fused, kv_refresh=refresh,
                      kv_refresh_every_pages=every, kv_refresh_min_pages=8,
                      kv_repack_budget=32)
    ratios, tokens = [], []
    phases = ([rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
               for _ in range(4)],
              [np.full(9, 7, np.int32) for _ in range(4)])
    for p, prompts in enumerate(phases):
        t0 = dict(eng.kv.traffic)
        reqs = [Request(rid=100 * p + i, prompt=pr, max_new_tokens=24)
                for i, pr in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        d = lambda k: eng.kv.traffic[k] - t0[k]
        ratios.append((d("kv_read_bytes") + d("kv_table_bytes"))
                      / d("kv_raw_bytes"))
        tokens.extend(r.tokens for r in reqs)
    return eng, ratios, tokens


class TestEngineDriftSmoke:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = apack_cfg()
        params = M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)
        return cfg, params

    def test_qwen3_two_phase_refresh_beats_frozen_tokens_identical(
            self, setup):
        cfg, params = setup
        ef, (fa, fb), tf = _two_phase_engine(params, cfg, refresh=False)
        er, (ra, rb), tr = _two_phase_engine(params, cfg, refresh=True)
        # refresh fired and re-packed through the decode loop's budget
        assert er.stats["kv_refreshes"] > 0
        assert er.stats["kv_pages_repacked"] > 0
        assert er.kv.generation >= 1
        # losslessness: greedy tokens bit-identical to the frozen run
        assert tr == tf
        # measured phase-B (post-refresh) ratio strictly better than the
        # frozen-table control on identical traffic, table overhead and
        # all; and better than the refresh run's own pre-refresh phase
        assert rb < fb, (rb, fb)
        assert rb < ra, (rb, ra)

    def test_fused_vs_materialize_identical_across_refresh_boundary(
            self, setup):
        """Greedy tokens must agree between the fused kernel path and the
        materialize oracle while generations mix mid-serve."""
        cfg, params = setup
        e1, _, t1 = _two_phase_engine(params, cfg, refresh=True, fused=True)
        e2, _, t2 = _two_phase_engine(params, cfg, refresh=True,
                                      fused=False)
        assert e1.kv.generation >= 1 and e2.kv.generation >= 1
        assert e1.stats["kv_pages_repacked"] == e2.stats["kv_pages_repacked"]
        assert t1 == t2

    def test_steady_state_zero_device_get_with_refresh_active(
            self, setup, monkeypatch):
        """A repack-carrying decode step is still d2h-free: sketches were
        fed at seal time, re-pack reads the host pool mirror and decode
        runs host-side — the device sees only the h2d plane sync."""
        cfg, params = setup
        rng = np.random.default_rng(12)
        eng = ServeEngine(cfg, params, max_batch=1, max_len=64,
                          kv_page_size=4, kv_calib_pages=1, kv_refresh=True,
                          kv_refresh_every_pages=4, kv_refresh_min_pages=4,
                          kv_repack_budget=1)
        assert eng.fused
        eng.submit(Request(rid=0, prompt=rng.integers(
            0, cfg.vocab_size, 9).astype(np.int32), max_new_tokens=40))
        eng.step()
        # march to a step that re-packs (queue pending) but seals nothing
        for _ in range(200):
            if (eng.kv._repack_queue
                    and int(eng.positions[0]) % 4 != 3):
                break
            eng.step()
        else:
            pytest.fail("never reached a repack-pending steady step")
        calls = []
        real = jax.device_get
        monkeypatch.setattr(jax, "device_get",
                            lambda x: (calls.append(1), real(x))[1])
        d2h_before = eng.kv.transfers["d2h_bytes"]
        repacked_before = eng.stats["kv_pages_repacked"]
        eng.step()
        monkeypatch.setattr(jax, "device_get", real)
        assert eng.stats["kv_pages_repacked"] == repacked_before + 1
        assert calls == [], f"{len(calls)} device_get calls in repack step"
        assert eng.kv.transfers["d2h_bytes"] == d2h_before

"""Fast-path coverage for the multi-bit-renormalization codec and the
decode-once fused matmul.

The multi-bit renorm (kernels/ref.py ``renorm_counts``) replaces the per-bit
WNC loop with closed-form bit arithmetic; these tests pin it against (a) a
direct Python transcription of the per-bit loop and (b) the golden codec's
full streams, across bit-widths, stored-mode fallbacks, and stream counts
that don't tile the 128-lane kernel block.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ac_golden, distributions, format as fmt, tables
from repro.core.ac_golden import HALF, QUARTER, THREEQ, TOP
from repro.kernels import ops, ref
from repro.kernels import decompress_matmul as dm


def _wnc_renorm(low: int, high: int):
    """Per-bit reference of one post-update renormalization run."""
    m = u = 0
    bits = []
    while True:
        if high < HALF:
            bits.append(0)
            m += 1
        elif low >= HALF:
            bits.append(1)
            low -= HALF
            high -= HALF
            m += 1
        elif low >= QUARTER and high < THREEQ:
            u += 1
            low -= QUARTER
            high -= QUARTER
        else:
            break
        low = low * 2
        high = high * 2 + 1
    return m, u, low, high, bits


class TestRenormCounts:
    @settings(max_examples=200, deadline=None)
    @given(st.integers(0, TOP), st.integers(0, TOP))
    def test_matches_per_bit_loop(self, a, b):
        low, high = min(a, b), max(a, b)
        if low == high:
            high = min(high + 1, TOP)
            low = high - 1
        em, eu, elo, ehi, ebits = _wnc_renorm(low, high)
        m, u, lo, hi = ref.renorm_counts(jnp.asarray([low], jnp.int32),
                                         jnp.asarray([high], jnp.int32))
        assert (int(m[0]), int(u[0])) == (em, eu), (low, high)
        assert (int(lo[0]), int(hi[0])) == (elo, ehi), (low, high)
        # emitted bits are the m matched leading bits of low, MSB-first
        prefix = [(low >> (15 - i)) & 1 for i in range(em)]
        assert prefix == ebits

    def test_interval_invariant_restored(self):
        # after renorm the range must exceed QUARTER (WNC invariant)
        rng = np.random.default_rng(0)
        lows = rng.integers(0, TOP, 4096)
        highs = np.minimum(lows + rng.integers(16, TOP, 4096), TOP)
        m, u, lo, hi = ref.renorm_counts(jnp.asarray(lows, jnp.int32),
                                         jnp.asarray(highs, jnp.int32))
        assert bool(jnp.all(hi - lo + 1 > QUARTER))

    def test_bit_helpers(self):
        x = jnp.asarray([0, 1, 2, 0x8000, 0xFFFF], jnp.int32)
        assert np.asarray(ref.bitlen16(x)).tolist() == [0, 1, 2, 16, 16]
        w = jnp.asarray([0x0001, 0x8000, 0x1234], jnp.uint32)
        assert np.asarray(ref.rev16(w)).tolist() == [0x8000, 0x0001, 0x2C48]


def _golden_stream_check(v, table, e):
    """Encode with the jnp kernels and golden; assert bit-identical planes."""
    ct = fmt.compress(v, table, bits=table.bits, elems_per_stream=e)  # golden
    for backend in ("ref", "pallas_interpret"):
        ca = ops.apack_encode(v, table, elems_per_stream=e, backend=backend)
        assert np.array_equal(np.asarray(ca.sym_bits), ct.sym_bits), backend
        assert np.array_equal(np.asarray(ca.ofs_bits), ct.ofs_bits), backend
        assert np.array_equal(np.asarray(ca.stored), ct.stored), backend
        ws, wo = ct.sym_plane.shape[0], ct.ofs_plane.shape[0]
        assert np.array_equal(
            np.asarray(ca.sym_plane[:ws]).astype(np.uint32), ct.sym_plane)
        assert np.array_equal(
            np.asarray(ca.ofs_plane[:wo]).astype(np.uint32), ct.ofs_plane)
        out = ops.apack_decode(ca, backend=backend)
        assert np.array_equal(np.asarray(out).astype(np.int64), v), backend


class TestBitExactVsGolden:
    @pytest.mark.parametrize("bits", [4, 8, 16])
    def test_bitwidth_sweep(self, bits):
        rng = np.random.default_rng(bits)
        base = distributions.gaussian_weights(3000, seed=bits).astype(np.int64)
        v = (base * (1 if bits <= 8 else 257)) & ((1 << bits) - 1)
        t = tables.table_for(v, bits=bits, is_activation=True)
        _golden_stream_check(v, t, e=128)

    def test_stored_mode_fallback_streams(self):
        # uniform values under a uniform table inflate -> stored fallback
        rng = np.random.default_rng(1)
        v = rng.integers(0, 256, 1024).astype(np.int64)
        t = tables.uniform_table()
        ca = ops.apack_encode(v, t, elems_per_stream=128,
                              backend="pallas_interpret")
        assert bool(np.asarray(ca.stored).all())
        _golden_stream_check(v, t, e=128)

    def test_mixed_stored_and_ac_streams(self):
        # half gaussian (compresses), half uniform (stored) in one tensor
        rng = np.random.default_rng(2)
        g = distributions.gaussian_weights(512, seed=3).astype(np.int64)
        u = rng.integers(0, 256, 512).astype(np.int64)
        v = np.concatenate([g, u]) & 0xFF
        t = tables.table_for(g, is_activation=True)
        ca = ops.apack_encode(v, t, elems_per_stream=128,
                              backend="pallas_interpret")
        stored = np.asarray(ca.stored)
        assert stored.any() and not stored.all()
        _golden_stream_check(v, t, e=128)

    @pytest.mark.parametrize("n", [1, 100, 129 * 64, 5000])
    def test_non_multiple_of_128_streams(self, n):
        # stream counts that don't tile BLOCK_STREAMS exercise the padding
        # lanes (garbage-in, discarded-out) around the multi-bit fast path
        v = distributions.gaussian_weights(max(n, 1), seed=n).astype(np.int64) & 0xFF
        t = tables.table_for(v, is_activation=True)
        ca = ops.apack_encode(v, t, elems_per_stream=64,
                              backend="pallas_interpret")
        assert ca.sym_bits.shape[0] == -(-n // 64)
        out = ops.apack_decode(ca, backend="pallas_interpret")
        assert np.array_equal(np.asarray(out).astype(np.int64), v)


class TestDecodeOnceMatmul:
    @pytest.mark.parametrize("block_m", [8, 16, 32])
    def test_output_invariant_to_block_m(self, block_m):
        # m_pad // block_m > 1 for every setting: the decode-under-
        # pl.when(i == 0) + VMEM scratch path must give identical results
        # no matter how many row-blocks reuse the decoded tile.
        rng = np.random.default_rng(7)
        m, k, n = 64, 256, 128
        w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
        x = rng.normal(0, 1, (m, k)).astype(np.float32)
        cw = dm.compress_linear(w, tile_k=128)
        assert m // block_m > 1
        fused = np.asarray(dm.compressed_matmul(jnp.asarray(x), cw,
                                                block_m=block_m))
        oracle = np.asarray(dm.reference_matmul(jnp.asarray(x), cw))
        np.testing.assert_allclose(fused, oracle, rtol=1e-5, atol=1e-5)

    def test_multi_tile_grid(self):
        # nk > 1 and nn > 1 and row-blocks > 1 simultaneously: scratch must
        # be refilled at each (j, kt) tile and reused across i only.
        rng = np.random.default_rng(8)
        m, k, n = 32, 256, 256
        w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
        x = rng.normal(0, 1, (m, k)).astype(np.float32)
        cw = dm.compress_linear(w, tile_k=128)
        fused = np.asarray(dm.compressed_matmul(jnp.asarray(x), cw, block_m=16))
        oracle = np.asarray(dm.reference_matmul(jnp.asarray(x), cw))
        np.testing.assert_allclose(fused, oracle, rtol=1e-5, atol=1e-5)


class TestTableMode:
    def test_find_table_records_mode(self):
        v = distributions.gaussian_weights(4096, seed=0).astype(np.int64) & 0xFF
        assert tables.table_for(v, is_activation=False).mode == "weight"
        assert tables.table_for(v, is_activation=True).mode == "activation"

    def test_weight_mode_gives_empty_ranges_zero_counts(self):
        # only low values present: weight mode must not steal counts for
        # the empty upper ranges, activation mode must
        v = np.zeros(4096, np.int64)
        v[:100] = np.arange(100) % 16
        tw = tables.table_for(v, is_activation=False)
        ta = tables.table_for(v, is_activation=True)
        cw = np.diff(np.asarray(tw.cum))
        ca = np.diff(np.asarray(ta.cum))
        assert (cw == 0).any()
        assert (ca > 0).all()

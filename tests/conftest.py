"""Test config.  IMPORTANT: never set xla_force_host_platform_device_count
here — smoke tests must see 1 device; multi-device tests spawn subprocesses
(tests/test_distributed.py)."""
import pytest
from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci", deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large])
settings.load_profile("ci")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

"""Test config.  IMPORTANT: never set xla_force_host_platform_device_count
here — smoke tests must see 1 device; multi-device tests spawn subprocesses
(tests/test_distributed.py)."""
import sys

import pytest

try:
    from hypothesis import HealthCheck, settings
except ImportError:
    # hypothesis is optional (see requirements.txt).  Install the local
    # stub under the "hypothesis" name so @given property tests still run
    # with a fixed set of deterministic examples.
    import _hypothesis_stub
    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies
    from hypothesis import HealthCheck, settings

settings.register_profile(
    "ci", deadline=None,
    suppress_health_check=[HealthCheck.too_slow,
                           HealthCheck.data_too_large])
settings.load_profile("ci")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")

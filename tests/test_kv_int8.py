"""int8 KV-cache tests (hillclimb feature: halves decode memory traffic)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as M
from repro.models import modules as m

KEY = jax.random.PRNGKey(0)


def test_kv_quant_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(0, 1, (2, 8, 4, 64)), jnp.float32)
    q, s = m._kv_quantize(k)
    out = m._kv_dequantize(q, s)
    amax = np.abs(np.asarray(k)).max(axis=-1, keepdims=True)
    assert np.all(np.abs(np.asarray(out) - np.asarray(k))
                  <= amax / 127 * 1.01)


def test_decode_with_int8_cache_close_to_bf16():
    cfg = dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                              kv_cache_dtype="int8")
    ref_cfg = configs.get_smoke_config("qwen3-1.7b")
    params = M.init_params(ref_cfg, KEY)
    b, s = 2, 12
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, ref_cfg.vocab_size, (b, s)))
    cache8 = M.init_cache(cfg, b, s)
    cache16 = M.init_cache(ref_cfg, b, s)
    assert cache8["blocks"][0]["k"].dtype == jnp.int8
    outs8, outs16 = [], []
    for t in range(s):
        l8, cache8 = M.decode_step(cfg, params, cache8,
                                   tokens[:, t:t + 1], jnp.asarray(t))
        l16, cache16 = M.decode_step(ref_cfg, params, cache16,
                                     tokens[:, t:t + 1], jnp.asarray(t))
        outs8.append(l8)
        outs16.append(l16)
    d8 = np.asarray(jnp.concatenate(outs8, 1), np.float32)
    d16 = np.asarray(jnp.concatenate(outs16, 1), np.float32)
    # int8 KV error is bounded but nonzero; logits track closely.  (Greedy
    # agreement is a weak check on a random-init model whose logits are
    # near-tied; the abs bound is the real criterion.)
    assert np.abs(d8 - d16).max() < 0.35
    agree = (d8.argmax(-1) == d16.argmax(-1)).mean()
    assert agree > 0.7


def test_prefill_emits_int8_cache_then_decodes():
    cfg = dataclasses.replace(configs.get_smoke_config("recurrentgemma-9b"),
                              kv_cache_dtype="int8")
    params = M.init_params(cfg, KEY)
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)))
    logits, caches = M.prefill(cfg, params, {"tokens": tokens}, max_len=32)
    # local-attention layer cache must be int8 with scales
    local_cache = caches["blocks"][2]       # (recurrent, recurrent, local)
    assert local_cache["k"].dtype == jnp.int8
    assert "k_scale" in local_cache
    lg, caches = M.decode_step(cfg, params, caches,
                               tokens[:, -1:], jnp.asarray(16))
    assert np.isfinite(np.asarray(lg, np.float32)).all()

"""Tests for the hot-path invariant analyzer (``repro.analysis``).

Three layers, mirroring the acceptance criteria:

* per-pass fixture tests — known-bad snippets must produce exactly the
  expected finding codes, known-good snippets must be clean;
* live-tree self-check — the real ``src/repro`` matches the committed
  (empty) baseline, with every pass actually running;
* mutation tests — copy the live tree, seed one violation per pass
  (including deleting a ``device_get`` suppression on a hot-path file),
  and assert the CI entry point would fail.
"""

import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = Path(__file__).resolve().parents[1] / "src"
sys.path.insert(0, str(SRC))

from repro.analysis import run_passes                       # noqa: E402
from repro.analysis.framework import (Reporter, SourceTree,  # noqa: E402
                                      write_baseline)
from repro.analysis.runner import DEFAULT_ROOT, PASSES      # noqa: E402


def make_tree(tmp_path: Path, files: dict[str, str]) -> Path:
    root = tmp_path / "tree"
    for rel, src in files.items():
        p = root / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return root


def run_pass(root: Path, pass_id: str) -> list:
    tree = SourceTree(root)
    rep = Reporter(tree)
    PASSES[pass_id](tree, rep)
    rep.check_suppression_keys()
    return rep.findings


def codes(findings) -> list[str]:
    return sorted(f.code for f in findings)


# ---------------------------------------------------------------- boundary
BOUNDARY_BAD = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    # apack: hot-path-root
    def step(pool):
        logits = jnp.argmax(pool)
        toks = np.asarray(logits)             # host-materialize
        n = int(jnp.sum(pool))                # scalar-coerce
        x = jax.device_get(pool)              # device-get
        logits.block_until_ready()            # block-until-ready
        v = jnp.max(pool).item()              # item-call
        return helper(toks, n, x, v)

    def helper(toks, n, x, v):
        y = jnp.exp(x)
        return float(y)                       # scalar-coerce (reachable)
"""

BOUNDARY_GOOD = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    # apack: hot-path-root
    def step(pool, meta):
        arr = np.asarray(meta)                # host value: fine
        n = int(arr.sum())                    # host numpy: fine
        dev = jnp.argmax(pool)
        shape = dev.shape                     # metadata: not tainted
        k = int(shape[0])                     # static: fine
        # apack: allow-transfer(the step's one sanctioned token pull)
        toks = np.asarray(dev)
        return toks, n, k

    def unreachable(pool):
        return jax.device_get(pool)           # not reachable from a root
"""


class TestBoundaryPass:
    def test_bad_fixture_exact_findings(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": BOUNDARY_BAD})
        got = codes(run_pass(root, "boundary"))
        assert got == ["block-until-ready", "device-get", "host-materialize",
                       "item-call", "scalar-coerce", "scalar-coerce"]

    def test_good_fixture_clean(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": BOUNDARY_GOOD})
        assert run_pass(root, "boundary") == []

    def test_traced_root_taints_params(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": """
            import numpy as np

            # apack: hot-path-root(traced)
            def decode_step(q, cfg: ModelConfig, bits: int):
                a = np.asarray(q)             # param is a traced operand
                b = float(cfg.softcap)        # config annotation: static
                c = bits * 2                  # static arg: fine
                return a, b, c
        """})
        assert codes(run_pass(root, "boundary")) == ["host-materialize"]

    def test_shard_map_body_seeded_as_traced_root(self, tmp_path):
        # nothing annotates the body — the pass must seed it from the
        # shard_map(...) call site (params are per-shard device operands)
        root = make_tree(tmp_path, {"m.py": """
            import numpy as np
            from jax.experimental.shard_map import shard_map

            def build(mesh):
                def _body(planes, tokens):
                    return np.asarray(planes)     # host-materialize
                return shard_map(_body, mesh=mesh, in_specs=(),
                                 out_specs=())
        """})
        assert codes(run_pass(root, "boundary")) == ["host-materialize"]

    def test_shard_map_lambda_body_ignored(self, tmp_path):
        # non-Name bodies can't resolve; the pass must skip, not crash
        root = make_tree(tmp_path, {"m.py": """
            from jax.experimental.shard_map import shard_map

            def build(mesh):
                return shard_map(lambda x: x, mesh=mesh, in_specs=(),
                                 out_specs=())
        """})
        assert run_pass(root, "boundary") == []

    def test_suppression_without_reason_is_a_finding(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": """
            import jax

            # apack: hot-path-root
            def step(x):
                # apack: allow-transfer()
                return jax.device_get(x)
        """})
        assert codes(run_pass(root, "boundary")) == ["missing-reason"]


# --------------------------------------------------------------- lifecycle
POOL_HEADER = """
    PAGE_FREE, PAGE_HOT, PAGE_COLD, PAGE_PACKED = 0, 1, 2, 3
    PAGE_TRANSITIONS = {
        "alloc": ((PAGE_FREE, PAGE_HOT),),
        "free":  ((PAGE_HOT, PAGE_FREE), (PAGE_COLD, PAGE_FREE)),
        "seal":  ((PAGE_HOT, PAGE_COLD),),
    }
"""

def pool_src(body: str) -> str:
    """Append a class body to POOL_HEADER at its 4-space base indent."""
    return POOL_HEADER + textwrap.indent(textwrap.dedent(body), "    ")


POOL_GOOD = POOL_HEADER + """
    class Pool:
        def _require_transition(self, pid, edge, dst):
            if (int(self.state[pid]), dst) not in PAGE_TRANSITIONS[edge]:
                raise ValueError(edge)
            return int(self.state[pid])

        def alloc(self, pid):
            self._require_transition(pid, "alloc", PAGE_HOT)
            self.state[pid] = PAGE_HOT

        def free(self, pid):
            self._require_transition(pid, "free", PAGE_FREE)
            self.state[pid] = PAGE_FREE

        def seal(self, pid):
            # hand-rolled raise-guard narrowing, no helper
            if self.state[pid] != PAGE_HOT:
                raise ValueError("bad seal")
            self.state[pid] = PAGE_COLD
"""


class TestLifecyclePass:
    def test_good_fixture_clean(self, tmp_path):
        root = make_tree(tmp_path, {"pool.py": POOL_GOOD})
        assert run_pass(root, "lifecycle") == []

    def test_unguarded_write(self, tmp_path):
        root = make_tree(tmp_path, {"pool.py": pool_src("""
            class Pool:
                def seal(self, pid):
                    self.state[pid] = PAGE_COLD
        """)})
        assert codes(run_pass(root, "lifecycle")) == ["unguarded-state-write"]

    def test_guard_dst_mismatch(self, tmp_path):
        root = make_tree(tmp_path, {"pool.py": pool_src("""
            class Pool:
                def _require_transition(self, pid, edge, dst):
                    pass

                def seal(self, pid):
                    self._require_transition(pid, "seal", PAGE_COLD)
                    self.state[pid] = PAGE_PACKED
        """)})
        assert codes(run_pass(root, "lifecycle")) == ["guard-dst-mismatch"]

    def test_undeclared_edge(self, tmp_path):
        root = make_tree(tmp_path, {"pool.py": pool_src("""
            class Pool:
                def hibernate(self, pid):
                    if self.state[pid] != PAGE_HOT:
                        raise ValueError("nope")
                    self.state[pid] = PAGE_COLD
        """)})
        assert codes(run_pass(root, "lifecycle")) == ["undeclared-edge"]

    def test_undeclared_transition_via_narrowing(self, tmp_path):
        # free's raise-guard admits COLD *and* PACKED sources, but the
        # fixture table only declares HOT/COLD -> FREE
        root = make_tree(tmp_path, {"pool.py": pool_src("""
            class Pool:
                def free(self, pid):
                    if self.state[pid] == PAGE_FREE:
                        raise ValueError("double free")
                    self.state[pid] = PAGE_FREE
        """)})
        assert codes(run_pass(root, "lifecycle")) == ["undeclared-transition"]

    def test_non_symbolic_state(self, tmp_path):
        root = make_tree(tmp_path, {"pool.py": pool_src("""
            class Pool:
                def seal(self, pid):
                    if self.state[pid] != PAGE_HOT:
                        raise ValueError("bad")
                    self.state[pid] = 2
        """)})
        assert codes(run_pass(root, "lifecycle")) == ["non-symbolic-state"]


# ------------------------------------------------------------------ phases
ENGINE_GOOD = """
    class Engine:
        def _step_async(self):
            self._overlap_host_work()
            self._collect()
            self._retire()
            self._dispatch()

        def _overlap_host_work(self):
            self.stats["ticks"] += 1

        def _collect(self):
            self.active[0] = None

        def _retire(self):
            self.active[0] = None
            self.kv.release(0)

        def _dispatch(self):
            pass
"""

ENGINE_BAD = """
    class Engine:
        def _step_async(self):
            self._overlap_host_work()
            self._collect()
            self._retire()

        def _overlap_host_work(self):
            self.active[0] = None           # slot write in overlap
            self.kv.release(0)              # pool mutation in overlap

        def _collect(self):
            pass

        def _retire(self):
            pass
"""


class TestPhasePass:
    def test_good_fixture_clean(self, tmp_path):
        root = make_tree(tmp_path, {"engine.py": ENGINE_GOOD})
        assert run_pass(root, "phase") == []

    def test_overlap_mutations_flagged(self, tmp_path):
        root = make_tree(tmp_path, {"engine.py": ENGINE_BAD})
        assert codes(run_pass(root, "phase")) == [
            "overlap-pool-mutation", "overlap-slot-write"]

    def test_collect_order(self, tmp_path):
        root = make_tree(tmp_path, {"engine.py": """
            class Engine:
                def _step_async(self):
                    self._dispatch()        # dispatch before collect
                    self._collect()

                def _dispatch(self):
                    pass

                def _collect(self):
                    pass
        """})
        assert codes(run_pass(root, "phase")) == ["collect-order"]


# ------------------------------------------------------------------ pallas
PALLAS_GOOD = """
    import functools
    import jax.experimental.pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    def _kernel(s_ref, a_ref, o_ref, acc_ref):
        @pl.when(s_ref[0] == 0)
        def _():
            o_ref[...] = a_ref[...]

    def call(x, s):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            grid=(4, 4), num_scalar_prefetch=1,
            in_specs=[pl.BlockSpec((8, 8), lambda i, j, s: (i, j))],
            out_specs=pl.BlockSpec((8, 8), lambda i, j, s: (i, j)),
            scratch_shapes=[pltpu.VMEM((8, 8), float)])
        return pl.pallas_call(_kernel, grid_spec=grid_spec,
                              out_shape=x)(s, x)
"""


class TestPallasPass:
    def test_good_fixture_clean(self, tmp_path):
        root = make_tree(tmp_path, {"k.py": PALLAS_GOOD})
        assert run_pass(root, "pallas") == []

    def test_index_map_arity(self, tmp_path):
        bad = PALLAS_GOOD.replace("lambda i, j, s: (i, j))],",
                                  "lambda i, j: (i, j))],")
        root = make_tree(tmp_path, {"k.py": bad})
        assert codes(run_pass(root, "pallas")) == ["index-map-arity"]

    def test_kernel_arity(self, tmp_path):
        bad = PALLAS_GOOD.replace("def _kernel(s_ref, a_ref, o_ref, acc_ref):",
                                  "def _kernel(s_ref, a_ref, o_ref):")
        root = make_tree(tmp_path, {"k.py": bad})
        assert codes(run_pass(root, "pallas")) == ["kernel-arity"]

    def test_operand_count(self, tmp_path):
        bad = PALLAS_GOOD.replace("out_shape=x)(s, x)", "out_shape=x)(x)")
        root = make_tree(tmp_path, {"k.py": bad})
        assert codes(run_pass(root, "pallas")) == ["operand-count"]

    def test_unguarded_output_write(self, tmp_path):
        bad = PALLAS_GOOD.replace("""    def _kernel(s_ref, a_ref, o_ref, acc_ref):
        @pl.when(s_ref[0] == 0)
        def _():
            o_ref[...] = a_ref[...]""",
                                  """    def _kernel(s_ref, a_ref, o_ref, acc_ref):
        o_ref[...] = a_ref[...]""")
        root = make_tree(tmp_path, {"k.py": bad})
        assert codes(run_pass(root, "pallas")) == ["unguarded-output-write"]

    def test_scratch_shape(self, tmp_path):
        bad = PALLAS_GOOD.replace("pltpu.VMEM((8, 8), float)",
                                  "(8, 8)")
        root = make_tree(tmp_path, {"k.py": bad})
        assert "scratch-shape" in codes(run_pass(root, "pallas"))

    def test_mesh_op_in_kernel(self, tmp_path):
        # mesh collectives/axis queries inside a kernel body break under
        # shard_map (the kernel runs per shard with no mesh axes bound)
        bad = PALLAS_GOOD.replace(
            "import functools", "import functools\n    import jax")
        bad = bad.replace(
            "o_ref[...] = a_ref[...]",
            'o_ref[...] = a_ref[...] * jax.lax.axis_index("data")')
        root = make_tree(tmp_path, {"k.py": bad})
        assert codes(run_pass(root, "pallas")) == ["mesh-op-in-kernel"]

    def test_mesh_op_outside_kernel_clean(self, tmp_path):
        # axis_index in the *wrapper* (host-side shard_map body) is fine
        good = PALLAS_GOOD.replace(
            "import functools", "import functools\n    import jax")
        good = good.replace(
            "        return pl.pallas_call(_kernel",
            '        d0 = jax.lax.axis_index("data")\n'
            "        return pl.pallas_call(_kernel")
        root = make_tree(tmp_path, {"k.py": good})
        assert run_pass(root, "pallas") == []


# --------------------------------------------------------------- jit-cache
class TestJitCachePass:
    def test_unbucketed_cache_key(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": """
            import jax

            def forward(self, ids):
                s = len(ids)
                key = (s, True)
                if key not in self._prefill_cache:
                    self._prefill_cache[key] = jax.jit(lambda x: x)
                return self._prefill_cache[key]
        """})
        assert codes(run_pass(root, "jit-cache")) == [
            "unbucketed-cache-key", "unbucketed-cache-key"]

    def test_bucketed_key_clean(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": """
            import jax

            def prefill_bucket(s, cap):
                b = 1
                while b < s:
                    b *= 2
                return min(b, cap)

            def forward(self, ids):
                s = len(ids)
                bucket = prefill_bucket(s, self.max_len)
                key = (bucket, s == bucket)
                if key not in self._prefill_cache:
                    self._prefill_cache[key] = jax.jit(lambda x: x)
                return self._prefill_cache[key]
        """})
        assert run_pass(root, "jit-cache") == []

    def test_float_static_arg(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("softcap",))
            def f(x, *, softcap: float = 0.0):
                return x * softcap
        """})
        assert codes(run_pass(root, "jit-cache")) == ["float-static-arg"]

    def test_unhashable_static_arg(self, tmp_path):
        root = make_tree(tmp_path, {"m.py": """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("layers",))
            def f(x, layers=[1, 2]):
                return x
        """})
        assert codes(run_pass(root, "jit-cache")) == ["unhashable-static-arg"]


# ------------------------------------------------------------- live tree
class TestLiveTree:
    def test_matches_committed_baseline(self):
        report = run_passes()
        assert report.ok, "new findings vs baseline:\n" + "\n".join(
            f.render() for f in report.new)
        assert not report.stale, f"stale baseline entries: {report.stale}"

    def test_all_passes_ran(self):
        report = run_passes()
        assert sorted(report.pass_seconds) == sorted(PASSES)

    def test_all_suppressions_used(self):
        # a suppression nothing fires against is dead weight (or a typo'd
        # location) — keep the annotation set tight
        tree = SourceTree(DEFAULT_ROOT)
        rep = Reporter(tree)
        for fn in PASSES.values():
            fn(tree, rep)
        unused = [s for m in tree.modules for s in m.suppressions
                  if not s.used]
        assert not unused, [(s.path, s.line, s.key) for s in unused]

    def test_hot_path_roots_annotated(self):
        tree = SourceTree(DEFAULT_ROOT)
        roots = {f.qualname for f in tree.roots()}
        assert {"ServeEngine.step", "ServeEngine._dispatch",
                "ServeEngine._collect", "decode_step_paged",
                "paged_attention_step"} <= roots


# ------------------------------------------------------------- mutations
@pytest.fixture()
def live_copy(tmp_path):
    dst = tmp_path / "repro"
    shutil.copytree(DEFAULT_ROOT, dst,
                    ignore=shutil.ignore_patterns("__pycache__"))
    return dst


def _edit(root: Path, rel: str, old: str, new: str) -> None:
    p = root / rel
    s = p.read_text()
    assert old in s, f"mutation anchor not found in {rel}"
    p.write_text(s.replace(old, new, 1))


BASELINE = DEFAULT_ROOT / "analysis" / "baseline.json"


class TestSeededViolations:
    """Acceptance: each pass fails on a seeded violation in a copy of the
    live tree, through the same entry point CI uses."""

    def _assert_fails(self, root, pass_id, code):
        report = run_passes(root, baseline=BASELINE)
        got = [(f.pass_id, f.code) for f in report.new]
        assert (pass_id, code) in got, got
        assert not report.ok

    def test_boundary_deleted_device_get_suppression(self, live_copy):
        # deleting the device_get suppression on a hot-path file must
        # fail the CI analysis step
        _edit(live_copy, "models/model.py",
              "    # apack: allow-transfer(sole accounted d2h funnel", "    #")
        self._assert_fails(live_copy, "boundary", "device-get")

    def test_lifecycle_illegal_destination(self, live_copy):
        # seal now claims the 'pack' edge, whose only declared destination
        # is PACKED — writing COLD under it is an undeclared transition
        _edit(live_copy, "models/modules.py",
              "        self._require_transition(pid, \"seal\", PAGE_COLD,",
              "        self._require_transition(pid, \"pack\", PAGE_COLD,")
        self._assert_fails(live_copy, "lifecycle", "undeclared-transition")

    def test_lifecycle_guard_dst_mismatch(self, live_copy):
        # seal's guard still validates ->COLD but the site writes PACKED
        _edit(live_copy, "models/modules.py",
              "        self.state[pid] = PAGE_COLD\n\n    def pack(",
              "        self.state[pid] = PAGE_PACKED\n\n    def pack(")
        self._assert_fails(live_copy, "lifecycle", "guard-dst-mismatch")

    def test_phase_mutation_in_overlap_window(self, live_copy):
        _edit(live_copy, "serve/engine.py",
              "    def _overlap_host_work(self) -> None:",
              "    def _overlap_host_work(self) -> None:\n"
              "        self.kv.release(0)\n")
        self._assert_fails(live_copy, "phase", "overlap-pool-mutation")

    def test_pallas_index_map_arity(self, live_copy):
        _edit(live_copy, "kernels/fused_page_attention.py",
              "lambda i, p, idx, tid:", "lambda i, p, idx:")
        self._assert_fails(live_copy, "pallas", "index-map-arity")

    def test_jit_cache_unbucketed_key(self, live_copy):
        _edit(live_copy, "serve/engine.py",
              "        key = (bucket, exact)", "        key = (s, exact)")
        self._assert_fails(live_copy, "jit-cache", "unbucketed-cache-key")

    def test_cli_exits_nonzero_on_mutated_tree(self, live_copy):
        _edit(live_copy, "models/model.py",
              "    # apack: allow-transfer(sole accounted d2h funnel", "    #")
        out = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--root",
             str(live_copy), "--baseline", str(BASELINE)],
            capture_output=True, text=True, timeout=300,
            env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin"})
        assert out.returncode == 1, out.stdout + out.stderr
        assert "device-get" in out.stdout


# ------------------------------------------------------ runtime guard dedup
class TestRuntimeTransitionGuards:
    """The pool guards now validate against PAGE_TRANSITIONS itself —
    the same table the lifecycle pass consumes."""

    def _pool(self):
        from repro.models import modules as m
        return m, m.KVPagePool(num_pages=4, page_size=2, kv_heads=2,
                               head_dim=8)

    def test_repack_requires_packed(self):
        import numpy as np
        m, pool = self._pool()
        pid = pool.alloc()
        planes = (np.zeros((2, pool.sym_words, pool.n_streams), np.uint32),
                  np.zeros((2, pool.ofs_words, pool.n_streams), np.uint32),
                  np.zeros((2, pool.n_streams), np.int32),
                  np.zeros((2, pool.n_streams), np.int32),
                  np.zeros((2, pool.n_streams), bool))
        with pytest.raises(ValueError, match="repack of non-PACKED"):
            pool.repack(pid, planes)

    def test_illegal_edge_message_names_transition(self):
        m, pool = self._pool()
        pid = pool.alloc()
        pool.free(pid)
        with pytest.raises(ValueError, match="FREE->FREE"):
            pool.free(pid)

    def test_table_covers_every_guarded_method(self):
        from repro.models import modules as m
        for edge in ("alloc", "free", "evict", "spill", "adopt", "seal",
                     "pack", "repack"):
            assert edge in m.PAGE_TRANSITIONS
            assert hasattr(m.KVPagePool, edge)

"""Async event-loop engine tests (ISSUE 7): lockstep sync-vs-async token
parity (including preempt/spill/resume mid-run), chunked-prefill
equivalence vs monolithic ingest, the pool over-commit regression, the
bucketed-prefill recompile-storm guard, monotonic latency clocks, and
fault injection on the overlapped host phase."""
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as M
from repro.serve import FaultInjector, Request, ServeEngine
from repro.serve import engine as serve_engine
from repro.serve.engine import prefill_bucket

KEY = jax.random.PRNGKey(0)


def apack_cfg(**kw):
    return dataclasses.replace(configs.get_smoke_config("qwen3-1.7b"),
                               kv_cache_dtype="apack-int8", **kw)


def hetero_cfg(**kw):
    return dataclasses.replace(configs.get_hetero_smoke_config(),
                               kv_cache_dtype="apack-int8", **kw)


@pytest.fixture(scope="module")
def qwen_params():
    return M.init_params(configs.get_smoke_config("qwen3-1.7b"), KEY)


@pytest.fixture(scope="module")
def hetero_params():
    return M.init_params(configs.get_hetero_smoke_config(), KEY)


# deliberately non-power-of-two lengths: every prompt exercises the
# padded+masked bucket path, not the exact-length fast path
PROMPT_LENS = [5, 11, 9, 20, 6]


def _mk_requests(cfg, lens, max_new, seed=3, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, L)
                    .astype(np.int32),
                    max_new_tokens=max_new, **kw)
            for i, L in enumerate(lens)]


def _run(cfg, params, scheduler, *, lens=PROMPT_LENS, max_new=10,
         max_batch=2, max_len=48, preempt_at=None, **ekw):
    """Serve one wave; optionally preempt-with-spill slot 0 after the
    ``preempt_at``-th decode step (mid-run spill -> readahead -> resume)."""
    eng = ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                      kv_page_size=4, kv_calib_pages=2,
                      scheduler=scheduler, **ekw)
    reqs = _mk_requests(cfg, lens, max_new)
    for r in reqs:
        eng.submit(r)
    if preempt_at is not None:
        for _ in range(500):
            eng.step()
            if eng.stats["steps"] >= preempt_at:
                break
        assert eng.active[0] is not None
        eng.preempt(0, spill=True, requeue="tail")
    eng.run_until_drained(max_steps=2000)
    for r in reqs:
        assert r.done and not r.error, (r.rid, r.error)
    return eng, reqs


class TestAsyncSyncParity:
    def test_qwen3_with_preempt_spill_resume(self, qwen_params):
        """Greedy tokens bit-identical between the sync and async
        engines on varied-length traffic, including a mid-run
        preempt-with-spill + readahead resume in BOTH engines (the async
        one must drain its in-flight step before snapshotting)."""
        cfg = apack_cfg()
        es, rs = _run(cfg, qwen_params, "sync", preempt_at=3)
        ea, ra = _run(cfg, qwen_params, "async", preempt_at=3)
        assert es.stats["preempted"] >= 1 and ea.stats["preempted"] >= 1
        assert es.stats["spilled_requests"] >= 1
        for a, b in zip(rs, ra):
            assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        # the async run actually pumped chunked prefills
        assert ea.stats["prefill_chunks"] > 0

    def test_hetero_with_preempt_spill_resume(self, hetero_params):
        """Same lockstep parity on the heterogeneous smoke config
        (global + rolling + recurrent-kind layers): pad masking must
        freeze recurrent state and build the rolling ring correctly for
        every layer kind."""
        cfg = hetero_cfg()
        es, rs = _run(cfg, hetero_params, "sync", preempt_at=3)
        ea, ra = _run(cfg, hetero_params, "async", preempt_at=3)
        assert ea.stats["preempted"] >= 1
        for a, b in zip(rs, ra):
            assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)

    def test_chunked_prefill_equivalence(self, qwen_params):
        """A long prompt ingested in tiny chunks interleaved with decode
        steps produces the same pages — greedy tokens bit-identical to
        the sync engine's monolithic ``ingest_prefill``."""
        cfg = apack_cfg()
        lens = [20, 7, 23]
        es, rs = _run(cfg, qwen_params, "sync", lens=lens, max_new=6)
        ea, ra = _run(cfg, qwen_params, "async", lens=lens, max_new=6,
                      prefill_chunk_tokens=3)
        for a, b in zip(rs, ra):
            assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        # ceil(20/3) + ceil(7/3) + ceil(23/3) when fully paced; idle-time
        # draining can merge steps but each prompt takes >= 1 chunk
        assert ea.stats["prefill_chunks"] >= len(lens)

    def test_async_requires_fused_paged_kv(self, qwen_params):
        cfg = configs.get_smoke_config("qwen3-1.7b")   # dense KV
        with pytest.raises(ValueError, match="scheduler='async'"):
            ServeEngine(cfg, qwen_params, max_batch=2, max_len=32,
                        scheduler="async")
        with pytest.raises(ValueError, match="unknown scheduler"):
            ServeEngine(cfg, qwen_params, max_batch=2, max_len=32,
                        scheduler="overlapped")


class TestPaddedPrefill:
    def test_padded_forward_matches_exact(self, qwen_params):
        """Model-level masking check: a zero-padded prompt with
        ``true_len`` produces the same last-token logits as the exact
        unpadded forward (pads excluded from attention, logits sliced at
        the true position)."""
        cfg = configs.get_smoke_config("qwen3-1.7b")
        rng = np.random.default_rng(9)
        s, bucket = 11, 16
        toks = rng.integers(0, cfg.vocab_size, s).astype(np.int32)
        exact, _, _ = M.forward(cfg, qwen_params,
                                {"tokens": jnp.asarray(toks[None])},
                                remat=False, collect_cache=True,
                                last_only=True)
        padded_toks = np.zeros((1, bucket), np.int32)
        padded_toks[0, :s] = toks
        padded, _, _ = M.forward(cfg, qwen_params,
                                 {"tokens": jnp.asarray(padded_toks)},
                                 remat=False, collect_cache=True,
                                 last_only=True,
                                 true_len=jnp.asarray(s, jnp.int32))
        np.testing.assert_allclose(np.asarray(exact), np.asarray(padded),
                                   rtol=2e-4, atol=2e-5)

    def test_prefill_bucket_values(self):
        assert prefill_bucket(5, 64) == 8
        assert prefill_bucket(8, 64) == 8           # exact power of two
        assert prefill_bucket(9, 64) == 16
        assert prefill_bucket(40, 48) == 48         # capped at max_len

    def test_recompile_storm_warns(self, monkeypatch, caplog):
        monkeypatch.setattr(serve_engine, "_seen_prefill_buckets", set())
        monkeypatch.setattr(serve_engine,
                            "PREFILL_BUCKET_WARN_THRESHOLD", 3)
        with caplog.at_level(logging.WARNING, logger="repro.serve"):
            for s in (1, 2, 4):
                prefill_bucket(s, 64)
            assert not caplog.records          # at threshold: quiet
            prefill_bucket(8, 64)              # 4th distinct size: warn
            assert len(caplog.records) == 1
            assert "recompile storm" in caplog.records[0].message
            prefill_bucket(8, 64)              # repeat size: no new warn
            assert len(caplog.records) == 1


class TestAdmissionAccounting:
    def test_head_never_its_own_pressure_victim(self, qwen_params):
        """Over-commit regression (pre-fix this FAILS): the queue head —
        preempted but still holding its reservation — must never be
        selected by ``_relieve_pressure``'s parked-victim scan.  Spilling
        the head releases the very reservation the caller's ``need=0``
        was computed against, so the head would resume unreserved and
        ``_reserved_total`` would under-count the pool forever after."""
        cfg = apack_cfg()
        eng = ServeEngine(cfg, qwen_params, max_batch=2, max_len=32,
                          kv_page_size=4, kv_calib_pages=2)
        reqs = _mk_requests(cfg, [8, 8], max_new=8)
        for r in reqs:
            eng.submit(r)
        for _ in range(20):
            if all(a is not None for a in eng.active):
                break
            eng.step()
        head = eng.active[1]
        eng.preempt(1, spill=False, requeue="head")
        assert head.rid in eng._preempted
        assert head.rid in eng._reserved        # reservation survives
        # the stale-need scenario: relief requested on the head's behalf
        relieved = eng._relieve_pressure(head, 0)
        assert not relieved, "head was spilled to relieve itself"
        assert head.rid in eng._reserved
        assert head.rid not in eng._spilled
        eng.run_until_drained(max_steps=500)
        assert all(r.done and not r.error for r in reqs)
        # reservation accounting drained back to zero — no over-commit
        assert eng._reserved_total == 0 and not eng._reserved

    def test_slo_priority_admission(self, qwen_params):
        """EDF-over-FIFO: with the pool sized for one request, a
        late-submitted request with a tight SLO is admitted before
        earlier FIFO traffic; SLO-free traffic stays pure FIFO."""
        cfg = apack_cfg()
        n_layers = cfg.n_cycles * len(cfg.cycle)
        eng = ServeEngine(cfg, qwen_params, max_batch=4, max_len=16,
                          kv_page_size=4, kv_calib_pages=2,
                          kv_pages=n_layers * 4)
        reqs = _mk_requests(cfg, [8, 8], max_new=4)
        urgent = _mk_requests(cfg, [8], max_new=4, slo_ms=1.0)[0]
        urgent.rid = 99
        for r in reqs:
            eng.submit(r)
        eng.submit(urgent)
        eng._retire()
        eng._admit()
        active_rids = [r.rid for r in eng.active if r is not None]
        assert active_rids == [99], active_rids
        eng.run_until_drained(max_steps=500)
        assert all(r.done for r in reqs) and urgent.done


class TestClocksAndFaults:
    def test_monotonic_latency_clocks(self, qwen_params, monkeypatch):
        """Request timing must not touch the wall clock: with
        ``time.time`` frozen (NTP-step stand-in), latencies stay
        positive and the percentile stats populate."""
        monkeypatch.setattr(time, "time", lambda: 1.0e9)
        cfg = configs.get_smoke_config("qwen3-1.7b")   # dense KV: fast
        eng = ServeEngine(cfg, qwen_params, max_batch=2, max_len=32)
        reqs = _mk_requests(cfg, [8, 8], max_new=4)
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=200)
        for r in reqs:
            assert r.t_done > r.t_submit > 0.0
            assert r.t_admit >= r.t_submit
        lat = eng.latency_stats()
        assert lat["n"] == 2
        assert lat["e2e_p50"] > 0.0
        assert lat["queue_wait_p99"] >= 0.0
        assert eng.stats["e2e_p99_ms"] > 0.0

    def test_host_delay_fault_degrades_latency_not_tokens(self,
                                                          qwen_params):
        """``delay_host_work`` lands on the async engine's overlapped
        phase: the injected stalls are consumed there, the sync engine
        ignores them, and greedy tokens are unaffected."""
        cfg = apack_cfg()
        inj = FaultInjector()
        inj.delay_host_work(0.02, n=3)
        ea, ra = _run(cfg, qwen_params, "async", lens=[9, 6], max_new=5,
                      faults=inj)
        assert inj.stats["host_work_delayed"] == 3
        inj2 = FaultInjector()
        inj2.delay_host_work(0.02, n=3)
        es, rs = _run(cfg, qwen_params, "sync", lens=[9, 6], max_new=5,
                      faults=inj2)
        assert inj2.stats["host_work_delayed"] == 0   # no overlap phase
        for a, b in zip(ra, rs):
            assert a.tokens == b.tokens
